"""Serving requests + the shared arch-aware prompt/batch construction.

One ``Request`` is a prompt (token ids plus the arch's extra prefill inputs
for VLM/audio), a stop condition (``max_new_tokens`` and an optional
``eos_id``), a sampling policy (``temperature``; 0 = greedy, and a
per-request ``seed`` so sampled continuations are reproducible no matter
which engine slot the request lands in), and an open-loop ``arrival_s``
timestamp assigned by the traffic generator.

This module is also the single home of the random prompt/batch construction
that ``launch/serve.py`` and ``examples/serve_decode.py`` used to duplicate
(~50 lines each), and of the ONE throughput definition both report:

    generated tokens = n_sequences * n_new_tokens

where ``n_new_tokens`` INCLUDES the token produced from the prefill logits
(the first sampled token) — the old drivers disagreed (one counted
``batch*(tokens-1)``, the other reported bare ``steps/s``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    """One decode request.  ``tokens`` is the prompt (prompt_len,) int32;
    ``extras`` carries per-request prefill-only inputs without a batch dim
    (VLM ``image_embeds`` (n_image_tokens, d); audio ``frames`` (F, d))."""

    rid: int
    tokens: np.ndarray
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: int = 0
    arrival_s: float = 0.0
    extras: Optional[Dict[str, np.ndarray]] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def replace(self, **kw) -> "Request":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Arch-aware prompt construction (the deduplicated driver code)
# ---------------------------------------------------------------------------

def extra_inputs(cfg, batch: int, rng: np.random.Generator,
                 *, batched: bool = True) -> Dict[str, np.ndarray]:
    """The non-token prefill inputs each arch family needs (stub frontends,
    matching the training pipeline's conventions)."""
    out: Dict[str, np.ndarray] = {}
    if cfg.arch_type == "vlm":
        out["image_embeds"] = rng.normal(
            0, 0.1, (batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.arch_type == "audio":
        out["frames"] = rng.normal(
            0, 0.1, (batch, cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
    if not batched:
        out = {k: v[0] for k, v in out.items()}
    return out


def prompt_batch(cfg, batch: int, prompt_len: int,
                 rng: np.random.Generator) -> Dict[str, jnp.ndarray]:
    """Random prompt batch for ``make_prefill_step``: tokens (B, S) plus the
    arch's extra inputs.  Token ids start at 5, clear of special ids."""
    b = {"tokens": jnp.asarray(
        rng.integers(5, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    for k, v in extra_inputs(cfg, batch, rng).items():
        b[k] = jnp.asarray(v)
    return b


def synthetic_requests(cfg, n: int, prompt_len: int,
                       rng: np.random.Generator, *,
                       max_new_tokens: int = 16,
                       min_new_tokens: int = 0,
                       eos_id: Optional[int] = None,
                       temperature: float = 0.0,
                       seed: int = 0) -> List[Request]:
    """n seeded requests with fixed ``prompt_len`` and per-request
    ``max_new_tokens`` drawn uniformly from [min_new_tokens or max,
    max_new_tokens] — heterogeneous decode lengths are what continuous
    batching exploits (a static batch runs every row to the longest)."""
    reqs = []
    for i in range(n):
        toks = rng.integers(5, cfg.vocab_size, (prompt_len,)).astype(np.int32)
        lo = min_new_tokens or max_new_tokens
        mx = int(rng.integers(lo, max_new_tokens + 1))
        reqs.append(Request(
            rid=i, tokens=toks, max_new_tokens=mx, eos_id=eos_id,
            temperature=temperature, seed=seed + i,
            extras=extra_inputs(cfg, 1, rng, batched=False) or None))
    return reqs


def request_batch(cfg, requests: List[Request]) -> Dict[str, jnp.ndarray]:
    """Stack equal-length requests into one batched prefill input."""
    lens = {r.prompt_len for r in requests}
    if len(lens) != 1:
        raise ValueError(f"static batch needs equal prompt lengths, got {lens}")
    b = {"tokens": jnp.asarray(np.stack([r.tokens for r in requests]))}
    if requests[0].extras:
        for k in requests[0].extras:
            b[k] = jnp.asarray(np.stack([r.extras[k] for r in requests]))
    return b


# ---------------------------------------------------------------------------
# The one throughput definition
# ---------------------------------------------------------------------------

def generated_tokens(n_sequences: int, n_new_tokens: int) -> int:
    """Tokens produced for ``n_sequences`` sequences of ``n_new_tokens`` new
    tokens each — the first of which comes from the PREFILL logits, the
    remaining ``n_new_tokens - 1`` from decode steps.  Both drivers count
    with this (no more ``batch*(tokens-1)`` vs ``steps/s`` mismatch)."""
    return int(n_sequences) * int(n_new_tokens)


def tokens_per_s(n_tokens: int, seconds: float) -> float:
    """Throughput over the interval that produced ``n_tokens`` — for a
    prefill+decode run the interval covers BOTH phases (the prefill-produced
    token is in the numerator, so prefill time belongs in the denominator)."""
    return n_tokens / max(seconds, 1e-9)
