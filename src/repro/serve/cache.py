"""Slot-addressed KV/SSM-state cache pool.

The pool stacks ``n_slots`` independent single-request caches (each exactly
the tree ``repro.models.model.cache_struct(cfg, batch=1, cache_len)``
builds — ring KV for sliding windows, conv+SSD state for mamba, wkv state
for rwkv, grouped self+cross KV for VLM, ...) along a new leading slot
axis.  Every slot is fully self-contained, per-slot ``index`` included, so:

  * the decode program is the SINGLE-request program vmapped over the slot
    axis (``make_slot_serve_step``) — per-slot positions come for free and
    the program compiles once for the pool shape, never again;
  * admit is a tree-scatter of a freshly prefilled batch=1 cache into a
    slot, evict is a tree-gather of that slot to host memory, and readmit
    scatters the snapshot back into ANY free slot — the slot id appears
    nowhere inside the cache values, which is why evict-and-readmit is
    bitwise identical to uninterrupted decode (pinned in tests/test_serve).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import cache_struct
from repro.nn import param as P


def _pool_write(pool, slot, slot_cache):
    return jax.tree.map(lambda pl, l: pl.at[slot].set(l), pool, slot_cache)


def _pool_read(pool, slot):
    return jax.tree.map(lambda pl: pl[slot], pool)


class SlotCachePool:
    """``n_slots`` stacked batch=1 caches; leaves (n_slots, *leaf.shape).

    ``slot_tokens`` is each slot's admissible KV length: ``min(cache_len,
    sliding_window)`` on windowed attention (the ring), ``cache_len``
    otherwise.  SSM/hybrid state caches are O(1) in sequence length — their
    occupancy is still reported against ``cache_len`` (positions consumed
    of the slot's decode budget)."""

    def __init__(self, cfg, n_slots: int, cache_len: int, dtype=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.cache_len = int(cache_len)
        self.slot_tokens = (min(cache_len, cfg.sliding_window)
                            if cfg.sliding_window else cache_len)
        struct = cache_struct(cfg, 1, cache_len, dtype)
        self.pool = jax.tree.map(
            lambda b: jnp.zeros((self.n_slots,) + b.value.shape,
                                b.value.dtype),
            struct, is_leaf=P.is_box)
        self._write = jax.jit(_pool_write)
        self._read = jax.jit(_pool_read)

    def write(self, slot: int, slot_cache: Any) -> None:
        """Scatter a batch=1 cache tree into ``slot`` (admit / readmit)."""
        self.pool = self._write(self.pool, jnp.int32(slot), slot_cache)

    def read(self, slot: int) -> Any:
        """The slot's batch=1 cache tree (device arrays)."""
        return self._read(self.pool, jnp.int32(slot))

    def extract(self, slot: int) -> Dict[str, Any]:
        """Host-side snapshot of the slot (evict): bitwise copies."""
        return jax.tree.map(np.asarray, self.read(slot))

    def insert(self, slot: int, snapshot: Dict[str, Any]) -> None:
        """Scatter a host snapshot back into a (possibly different) slot."""
        self.write(slot, jax.tree.map(jnp.asarray, snapshot))

    def positions(self) -> np.ndarray:
        """(n_slots,) int32 — each slot's token count (its cache index)."""
        return np.asarray(self.pool["index"])

    def tokens_used(self, active: np.ndarray) -> int:
        """Real cache positions held by ``active`` slots (occupancy
        numerator): per-slot min(index, slot_tokens)."""
        pos = np.minimum(self.positions(), self.slot_tokens)
        return int(pos[np.asarray(active, bool)].sum())
