"""Serve the model FDAPT just trained: direct parameter loading from
``repro.checkpoint`` archives.

``FedSession`` round checkpoints store ``{"params": ..., "server": ...}``
(global params plus the strategy's server state) with a ``FederatedState``
JSON sidecar.  The loader restores ONLY the params subtree — the server
state and RNG bit-state are training concerns — against an allocation-free
template derived from the arch config, and cross-checks the sidecar's plan
fingerprint (``train.py`` records the arch name there) so a qwen2 server
never silently deserializes a distilbert checkpoint that happens to share
leaf names.

Bare params snapshots (``save_checkpoint(dir, step, params)`` with no
wrapper) load too: ``archive_keys`` sniffs whether the archive uses the
``params|`` prefix.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.checkpoint import (archive_keys, latest_step, restore_checkpoint,
                              restore_extra)
from repro.checkpoint.npz import FederatedState
from repro.models.model import init_model
from repro.nn import param as P


def params_template(cfg) -> Any:
    """Unboxed params tree as ShapeDtypeStructs — no allocation."""
    boxed = jax.eval_shape(lambda k: init_model(k, cfg),
                           jax.random.PRNGKey(0))
    return P.unbox(boxed)


def checkpoint_arch(ckpt_dir: str, step: Optional[int] = None
                    ) -> Optional[str]:
    """Arch name recorded in the checkpoint's plan fingerprint (None when
    the sidecar is absent or was written without ``fingerprint_extra``)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None
    meta = restore_extra(ckpt_dir, step)
    if not meta:
        return None
    plan = FederatedState.from_json(meta).plan or {}
    extra = plan.get("extra") or {}
    return extra.get("arch")


def load_serving_params(ckpt_dir: str, cfg, step: Optional[int] = None,
                        *, check_arch: bool = True
                        ) -> Tuple[Any, int, Optional[FederatedState]]:
    """-> (params, step, FederatedState sidecar or None).

    ``step`` defaults to the newest checkpoint in ``ckpt_dir``.  Params
    restore BITWISE (the archive stores exact bytes; the template dtype
    matches the arch config, so the cast is the identity) — the served
    model IS the aggregated global model round ``step`` produced."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    if check_arch:
        arch = checkpoint_arch(ckpt_dir, step)
        if arch is not None and arch != cfg.name:
            raise ValueError(
                f"checkpoint {step} in {ckpt_dir!r} was trained as "
                f"{arch!r}, not {cfg.name!r} — pass the matching --arch "
                f"(or check_arch=False to force)")
    template = params_template(cfg)
    wrapped = any(k.startswith("params|") for k in archive_keys(ckpt_dir, step))
    if wrapped:
        params = restore_checkpoint(ckpt_dir, step,
                                    {"params": template})["params"]
    else:
        params = restore_checkpoint(ckpt_dir, step, template)
    meta = restore_extra(ckpt_dir, step)
    fed = FederatedState.from_json(meta) if meta else None
    return params, step, fed
