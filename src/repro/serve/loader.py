"""Serve the model FDAPT just trained: direct parameter loading from
``repro.checkpoint`` archives.

``FedSession`` round checkpoints store ``{"params": ..., "server": ...}``
(global params plus the strategy's server state) with a ``FederatedState``
JSON sidecar.  The loader restores ONLY the params subtree — the server
state and RNG bit-state are training concerns — against an allocation-free
template derived from the arch config, and cross-checks the sidecar's plan
fingerprint (``train.py`` records the arch name there) so a qwen2 server
never silently deserializes a distilbert checkpoint that happens to share
leaf names.

Bare params snapshots (``save_checkpoint(dir, step, params)`` with no
wrapper) load too: ``archive_keys`` sniffs whether the archive uses the
``params|`` prefix.

PEFT checkpoints (low-rank ``RoundPlan.param_space`` runs) store
``{"params": {"base": ..., "peft": ...}}`` — sniffed via the
``params|base|`` key prefix.  The loader rebuilds the ParamSpace from the
sidecar's ``param_space`` fingerprint, restores base + bank, and returns
the MERGED tree, so the decode engine serves adapter-FDAPT checkpoints
unchanged.  The arch guard extends to the bank: a wrong base arch raises
exactly as before, and a caller that knows which space it expects
(``expect_space=``) gets a raise on a rank/kind mismatch instead of a
silently different model.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.checkpoint import (archive_keys, latest_step, restore_checkpoint,
                              restore_extra)
from repro.checkpoint.npz import FederatedState
from repro.models.model import init_model
from repro.nn import param as P


def params_template(cfg) -> Any:
    """Unboxed params tree as ShapeDtypeStructs — no allocation."""
    boxed = jax.eval_shape(lambda k: init_model(k, cfg),
                           jax.random.PRNGKey(0))
    return P.unbox(boxed)


def checkpoint_arch(ckpt_dir: str, step: Optional[int] = None
                    ) -> Optional[str]:
    """Arch name recorded in the checkpoint's plan fingerprint (None when
    the sidecar is absent or was written without ``fingerprint_extra``)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None
    meta = restore_extra(ckpt_dir, step)
    if not meta:
        return None
    plan = FederatedState.from_json(meta).plan or {}
    extra = plan.get("extra") or {}
    return extra.get("arch")


def checkpoint_param_space(ckpt_dir: str, step: Optional[int] = None):
    """ParamSpace recorded in the checkpoint's plan fingerprint (None for
    full/implicit-FFDAPT runs or when the sidecar is absent)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None
    meta = restore_extra(ckpt_dir, step)
    if not meta:
        return None
    from repro.peft import ParamSpace
    plan = FederatedState.from_json(meta).plan or {}
    return ParamSpace.from_json(plan.get("param_space"))


def load_serving_params(ckpt_dir: str, cfg, step: Optional[int] = None,
                        *, check_arch: bool = True, expect_space=None
                        ) -> Tuple[Any, int, Optional[FederatedState]]:
    """-> (params, step, FederatedState sidecar or None).

    ``step`` defaults to the newest checkpoint in ``ckpt_dir``.  Params
    restore BITWISE (the archive stores exact bytes; the template dtype
    matches the arch config, so the cast is the identity) — the served
    model IS the aggregated global model round ``step`` produced.  PEFT
    checkpoints restore base + adapter bank and return the exact merge the
    training eval saw; ``expect_space`` (a ``repro.peft.ParamSpace``)
    optionally pins the bank's kind/rank/targets."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    if check_arch:
        arch = checkpoint_arch(ckpt_dir, step)
        if arch is not None and arch != cfg.name:
            raise ValueError(
                f"checkpoint {step} in {ckpt_dir!r} was trained as "
                f"{arch!r}, not {cfg.name!r} — pass the matching --arch "
                f"(or check_arch=False to force)")
    template = params_template(cfg)
    keys = archive_keys(ckpt_dir, step)
    meta = restore_extra(ckpt_dir, step)
    fed = FederatedState.from_json(meta) if meta else None
    if any(k.startswith("params|base|") for k in keys):
        space = checkpoint_param_space(ckpt_dir, step)
        if space is None or not space.low_rank:
            raise ValueError(
                f"checkpoint {step} in {ckpt_dir!r} stores a PEFT bank "
                f"(params|base|... archive layout) but its sidecar records "
                f"no low-rank param_space — cannot rebuild the merge")
        if expect_space is not None and expect_space != space:
            raise ValueError(
                f"checkpoint {step} in {ckpt_dir!r} was trained in param "
                f"space {space.to_json()}, not {expect_space.to_json()}")
        bank_t = jax.eval_shape(
            lambda p: space.inject(p, jax.random.PRNGKey(0)), template)
        tree = restore_checkpoint(
            ckpt_dir, step, {"params": {"base": template, "peft": bank_t}})
        params = space.merge(tree["params"]["base"], tree["params"]["peft"])
    elif expect_space is not None and expect_space.low_rank:
        raise ValueError(
            f"expected a {expect_space.kind} (rank {expect_space.rank}) "
            f"checkpoint but {ckpt_dir!r} step {step} stores full params")
    elif any(k.startswith("params|") for k in keys):
        params = restore_checkpoint(ckpt_dir, step,
                                    {"params": template})["params"]
    else:
        params = restore_checkpoint(ckpt_dir, step, template)
    return params, step, fed
