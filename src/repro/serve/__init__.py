"""repro.serve: continuous-batching decode over the jitted serve programs.

Public surface:

  * ``DecodeEngine`` / ``EngineConfig`` — fixed-slot continuous batching
    (``engine``), plus ``run_static`` as the static-batch reference path;
  * ``SlotCachePool`` — slot-addressed KV/SSM-state pool (``cache``);
  * ``Request`` / ``synthetic_requests`` / ``prompt_batch`` — request model
    and the shared arch-aware prompt construction (``requests``);
  * ``FIFOScheduler`` / ``PoissonArrivals`` / ``WallClock`` /
    ``VirtualClock`` — admission order, open-loop traffic, time
    (``scheduler``);
  * ``ServeMetrics`` / ``FiniteTrace`` / ``write_bench`` — per-request
    latency accounting and the BENCH_serve.json schema (``metrics``);
  * ``load_serving_params`` — params from ``repro.checkpoint`` archives
    (``loader``).
"""

from repro.serve.cache import SlotCachePool  # noqa: F401
from repro.serve.engine import (DecodeEngine, EngineConfig,  # noqa: F401
                                run_static)
from repro.serve.loader import (checkpoint_arch, load_serving_params,  # noqa: F401
                                params_template)
from repro.serve.metrics import (BENCH_MODE_KEYS, FiniteTrace,  # noqa: F401
                                 RequestRecord, ServeMetrics, percentiles,
                                 write_bench)
from repro.serve.requests import (Request, extra_inputs,  # noqa: F401
                                  generated_tokens, prompt_batch,
                                  request_batch, synthetic_requests,
                                  tokens_per_s)
from repro.serve.scheduler import (FIFOScheduler, PoissonArrivals,  # noqa: F401
                                   VirtualClock, WallClock)
