"""Continuous-batching decode engine over fixed-shape jitted programs.

``DecodeEngine`` owns a ``SlotCachePool`` of ``n_slots`` per-request caches
and two programs:

  * prefill — the batch=1 ``make_prefill_step`` program (one trace per
    distinct prompt length; admission runs it and scatters the filled cache
    into a free slot);
  * decode — ``make_slot_serve_step``: the batch=1 serve step vmapped over
    the slot axis, compiled ONCE for the pool shape.  Requests are admitted
    and evicted by scattering cache trees in and out of slots; the decode
    program itself never sees shapes change, so it never recompiles
    (``decode_cache_size()`` stays 1 — pinned in tests/test_serve.py).

Admission is prefill-prioritized: before every decode step the engine
drains arrived requests into free slots.  Each request stops on its own
``max_new_tokens`` or ``eos_id``; finished slots free immediately and the
next waiting request takes them mid-flight — that is the whole continuous-
batching win over a static batch, which must run every sequence to the
longest stop and wait for whole batches to form.

Sampling is greedy (temperature 0, ``argmax``) or temperature-scaled
categorical with a per-request key ``fold_in(PRNGKey(seed), position)`` —
the key depends on the request and the absolute token position only, never
on the slot or the step the engine happened to run, so engine outputs are
BITWISE identical to ``run_static`` (the batched static-shape reference
path) for the same requests, including across an evict/readmit cycle.

Per-slot logits finiteness is accumulated every step on device (one flag
vector, no sync in the loop) and checked when a request completes — a
mid-sequence NaN names its request instead of surfacing N steps later.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.steps import (make_prefill_step, make_serve_step,
                                make_slot_serve_step)
from repro.obs.metrics import registry as _obs_registry
from repro.obs.trace import span as _obs_span
from repro.serve.cache import SlotCachePool
from repro.serve.metrics import FiniteTrace, RequestRecord, ServeMetrics
from repro.serve.requests import Request, prompt_batch, request_batch
from repro.serve.scheduler import FIFOScheduler, VirtualClock, WallClock

_PAD_ID = 5          # benign token id parked in inactive slots


def _sample_one(logits, seed, pos, temp):
    """One token from one row of final logits.  temp==0 -> argmax; else
    categorical at ``logits/temp`` under ``fold_in(PRNGKey(seed), pos)`` —
    a function of (request, absolute position) only, so the draw is the
    same whatever slot or engine path produced the logits."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
    safe = jnp.where(temp > 0, temp, 1.0)
    samp = jax.random.categorical(
        key, logits.astype(jnp.float32) / safe).astype(jnp.int32)
    return jnp.where(temp > 0, samp, greedy)


# one process-wide sampler: the engine and the static reference path run
# the IDENTICAL compiled program, which is half of the bitwise-parity story
_SAMPLER = jax.jit(jax.vmap(_sample_one))


def _make_decode_kernel(cfg, impl: str):
    """The engine's per-step device program, fused into ONE dispatch: the
    slot-vmapped serve step, per-slot sampling, and the finiteness
    accumulation.  Sampling positions are the post-step cache indices
    (each slot's ``index`` equals prompt_len + n_generated right after the
    step).  Fusing ``_sample_one`` here is safe for the bitwise-parity
    guarantee: its math is elementwise + argmax, which XLA compiles to the
    same per-row results fused or standalone, any batch size (checked in
    tests/test_serve.py against the static path's ``_SAMPLER``).  One jit
    dispatch + one device sync per decode step is what makes the engine's
    per-step host overhead match the static loop's."""
    vserve = make_slot_serve_step(cfg, impl=impl)

    def kernel(params, tokens, pool, finite, active, seeds, temps):
        logits, pool = vserve(params, {"tokens": tokens}, pool)
        lg = logits[:, 0, :]                                  # (slots, V)
        ok = jnp.all(jnp.isfinite(lg), axis=-1)
        finite = jnp.where(active, finite & ok, finite)
        pos = pool["index"].astype(jnp.int32)                 # (slots,)
        toks = jax.vmap(_sample_one)(lg, seeds, pos, temps)
        return toks, finite, pool

    return kernel


def _make_admit_kernel(cfg, cache_len: int, impl: str):
    """Admission's device program (one trace per distinct prompt length):
    batch=1 prefill + first-token sample (at pos = prompt_len) + logits
    finiteness, in one dispatch."""
    prefill = make_prefill_step(cfg, cache_len, impl=impl)

    def kernel(params, batch, seed, pos, temp):
        logits, cache1 = prefill(params, batch)               # (1, V)
        tok = jax.vmap(_sample_one)(logits, seed[None], pos[None],
                                    temp[None])[0]
        fin = jnp.all(jnp.isfinite(logits))
        return tok, fin, cache1

    return kernel


@dataclasses.dataclass
class EngineConfig:
    """Static engine shape: ``n_slots`` concurrent requests, ``cache_len``
    positions per slot (>= prompt_len + max_new_tokens of any admitted
    request on full attention; the ring keeps ``sliding_window``)."""

    n_slots: int = 4
    cache_len: int = 128
    impl: str = "xla"
    cache_dtype: Any = None
    check_finite: bool = True


@dataclasses.dataclass
class _Slot:
    request: Request
    out: List[int]
    n_generated: int
    admit_s: float
    first_token_s: float
    evictions: int = 0


class _ZeroClock:
    """Default clock for low-level admit/decode_step calls: time stands
    still (records carry zeros; run() supplies a real clock)."""

    def now(self) -> float:
        return 0.0

    def tick(self) -> None:
        pass


class DecodeEngine:
    def __init__(self, cfg, params, engine: Optional[EngineConfig] = None,
                 **kw):
        if cfg.arch_type == "mlm":
            raise ValueError("mlm is encoder-only: nothing to decode")
        self.cfg = cfg
        self.params = params
        self.engine = engine or EngineConfig(**kw)
        ec = self.engine
        self.pool = SlotCachePool(cfg, ec.n_slots, ec.cache_len,
                                  ec.cache_dtype)
        self._admit = jax.jit(_make_admit_kernel(cfg, ec.cache_len,
                                                 impl=ec.impl))
        self._kernel = jax.jit(_make_decode_kernel(cfg, ec.impl))
        self.slots: List[Optional[_Slot]] = [None] * ec.n_slots
        # per-slot metadata stays on HOST (tiny arrays, shipped with each
        # kernel call): the serving loop never runs an eager device op, so
        # each decode step is exactly one dispatch + one result fetch
        self._next_np = np.full((ec.n_slots, 1, 1), _PAD_ID, np.int32)
        self._finite = np.ones(ec.n_slots, bool)
        self._active = np.zeros(ec.n_slots, bool)
        self._seeds = np.zeros(ec.n_slots, np.int32)
        self._temps = np.zeros(ec.n_slots, np.float32)
        self.outputs: Dict[int, np.ndarray] = {}
        self.metrics = ServeMetrics(ec.n_slots, self.pool.slot_tokens)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def decode_cache_size(self) -> int:
        """Compiled-program count of the decode kernel jit — the
        no-recompilation invariant says this stays 1 forever."""
        return self._kernel._cache_size()

    def prefill_cache_size(self) -> int:
        """One trace per distinct admitted prompt length."""
        return self._admit._cache_size()

    # ------------------------------------------------------------------
    # Admission / decode / eviction
    # ------------------------------------------------------------------

    def _check_capacity(self, request: Request) -> None:
        need = request.prompt_len + request.max_new_tokens
        if not self.cfg.sliding_window and need > self.engine.cache_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} + "
                f"max_new {request.max_new_tokens} exceeds cache_len "
                f"{self.engine.cache_len}")

    def admit(self, request: Request, clock=None) -> int:
        """Prefill the request (batch=1) into a free slot; samples the
        first token (from the prefill logits) before returning."""
        clock = clock or _ZeroClock()
        free = self.free_slots()
        if not free:
            raise RuntimeError("admit with no free slot")
        self._check_capacity(request)
        slot = free[0]
        with _obs_span("serve.admit", cat="serve", rid=request.rid,
                       slot=slot, prompt_len=request.prompt_len):
            t_admit = clock.now()
            batch = {"tokens": jnp.asarray(request.tokens[None])}
            if request.extras:
                for k, v in request.extras.items():
                    batch[k] = jnp.asarray(v[None])
            tok, fin, cache1 = self._admit(
                self.params, batch, jnp.int32(request.seed),
                jnp.int32(request.prompt_len),
                jnp.float32(request.temperature))
            tok_i, fin_b = jax.device_get((tok, fin))        # syncs
            tok_i = int(tok_i)
            self._finite[slot] = bool(fin_b)
            self._active[slot] = True
            self._seeds[slot] = request.seed
            self._temps[slot] = request.temperature
            self.pool.write(slot, cache1)
            t_first = clock.now()
            self.slots[slot] = _Slot(request=request, out=[tok_i],
                                     n_generated=1, admit_s=t_admit,
                                     first_token_s=t_first)
            self._next_np[slot, 0, 0] = tok_i
            if self._stopped(request, tok_i, 1):
                self._complete(slot, t_first)
        _obs_registry().counter("serve.admits").inc()
        return slot

    @staticmethod
    def _stopped(request: Request, tok: int, n_generated: int) -> bool:
        return (n_generated >= request.max_new_tokens
                or (request.eos_id is not None and tok == request.eos_id))

    def decode_step(self, clock=None) -> None:
        """One lockstep decode over the whole pool (no-op when idle)."""
        clock = clock or _ZeroClock()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        with _obs_span("serve.decode_step", cat="serve", active=len(active)):
            toks_d, fin_d, self.pool.pool = self._kernel(
                self.params, self._next_np, self.pool.pool, self._finite,
                self._active, self._seeds, self._temps)
            toks, fin = jax.device_get((toks_d, fin_d))      # syncs
            self._finite = np.array(fin)        # device_get is read-only
            clock.tick()
            now = clock.now()
            used = sum(min(self.slots[i].request.prompt_len
                           + self.slots[i].n_generated,
                           self.pool.slot_tokens)
                       for i in active)
            for i in active:
                s = self.slots[i]
                tok_i = int(toks[i])
                s.out.append(tok_i)
                s.n_generated += 1
                self._next_np[i, 0, 0] = tok_i
                if self._stopped(s.request, tok_i, s.n_generated):
                    self._complete(i, now)
            self.metrics.on_step(len(active), used)
        _obs_registry().counter("serve.decode_steps").inc()

    def _complete(self, slot: int, now: float) -> None:
        s = self.slots[slot]
        if self.engine.check_finite and not self._finite[slot]:
            raise FloatingPointError(
                f"request {s.request.rid}: non-finite logits during decode "
                f"(caught at completion; slot {slot})")
        self.outputs[s.request.rid] = np.asarray(s.out, np.int32)
        self.metrics.finish(RequestRecord(
            rid=s.request.rid, arrival_s=s.request.arrival_s,
            admit_s=s.admit_s, first_token_s=s.first_token_s, finish_s=now,
            prompt_len=s.request.prompt_len, n_generated=s.n_generated,
            evictions=s.evictions))
        self.slots[slot] = None
        self._next_np[slot, 0, 0] = _PAD_ID
        self._finite[slot] = True
        self._active[slot] = False

    def evict(self, slot: int) -> Dict[str, Any]:
        """Preempt a live request: host snapshot of everything needed to
        resume it bitwise — cache state, generated tokens, next input
        token, finiteness flag."""
        s = self.slots[slot]
        if s is None:
            raise ValueError(f"slot {slot} is empty")
        with _obs_span("serve.evict", cat="serve", slot=slot,
                       rid=s.request.rid):
            snap = {
                "cache": self.pool.extract(slot),
                "request": s.request,
                "out": list(s.out),
                "n_generated": s.n_generated,
                "next_token": int(self._next_np[slot, 0, 0]),
                "finite": bool(self._finite[slot]),
                "admit_s": s.admit_s,
                "first_token_s": s.first_token_s,
                "evictions": s.evictions + 1,
            }
            self.slots[slot] = None
            self._next_np[slot, 0, 0] = _PAD_ID
            self._finite[slot] = True
            self._active[slot] = False
        _obs_registry().counter("serve.evictions").inc()
        return snap

    def readmit(self, snap: Dict[str, Any]) -> int:
        """Resume an evicted request in any free slot.  The snapshot is
        self-contained, so the continuation is bitwise identical to the
        uninterrupted decode regardless of the new slot id."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("readmit with no free slot")
        slot = free[0]
        with _obs_span("serve.readmit", cat="serve", slot=slot,
                       rid=snap["request"].rid):
            self.pool.insert(slot, snap["cache"])
            self.slots[slot] = _Slot(
                request=snap["request"], out=list(snap["out"]),
                n_generated=snap["n_generated"], admit_s=snap["admit_s"],
                first_token_s=snap["first_token_s"],
                evictions=snap["evictions"])
            self._next_np[slot, 0, 0] = snap["next_token"]
            self._finite[slot] = snap["finite"]
            self._active[slot] = True
            self._seeds[slot] = snap["request"].seed
            self._temps[slot] = snap["request"].temperature
        _obs_registry().counter("serve.readmits").inc()
        return slot

    # ------------------------------------------------------------------
    # Driver
    # ------------------------------------------------------------------

    def warmup(self, prompt_lens) -> None:
        """Compile the admit kernel (per distinct prompt length), the
        decode kernel, and the pool gather/scatter programs before the
        clock starts; engine state is untouched (warmup results are
        discarded)."""
        rng = np.random.default_rng(0)
        for L in sorted(set(int(x) for x in prompt_lens)):
            batch = prompt_batch(self.cfg, 1, L, rng)
            self._admit(self.params, batch, jnp.int32(0), jnp.int32(L),
                        jnp.float32(0.0))
        # identity round-trip on slot 0 warms the pool gather/scatter jits
        # (otherwise the first admit pays their compile on the clock)
        self.pool.write(0, self.pool.read(0))
        toks, _, _ = self._kernel(self.params, self._next_np, self.pool.pool,
                                  self._finite, self._active, self._seeds,
                                  self._temps)
        jax.block_until_ready(toks)

    def run(self, requests: List[Request], *, clock=None,
            warmup: bool = True) -> Tuple[Dict[int, np.ndarray],
                                          Dict[str, Any]]:
        """Serve ``requests`` to completion under their arrival times.
        -> ({rid: generated token ids}, metrics summary dict)."""
        clock = clock if clock is not None else WallClock()
        sched = FIFOScheduler(requests)
        if warmup:
            self.warmup([r.prompt_len for r in requests])
        clock.start()
        while sched.waiting or self.n_active():
            now = clock.now()
            while self.free_slots():
                r = sched.next_ready(now)
                if r is None:
                    break
                self.admit(r, clock)
                now = clock.now()
            if not self.n_active():
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                clock.advance_to(nxt)
                continue
            self.decode_step(clock)
        return dict(self.outputs), self.metrics.summary()


# ---------------------------------------------------------------------------
# Static-batch reference path
# ---------------------------------------------------------------------------

def run_static(cfg, params, requests: List[Request], *, n_slots: int,
               cache_len: int, impl: str = "xla", clock=None,
               check_finite: bool = True, warmup: bool = True
               ) -> Tuple[Dict[int, np.ndarray], Dict[str, Any]]:
    """The baseline the engine is measured against: requests are served in
    arrival order in fixed batches of ``n_slots`` through the BATCHED
    prefill/serve programs.  A batch only starts once its last member has
    arrived and the previous batch finished, and decodes until its longest
    request stops (finished rows ride along, their outputs truncated) —
    faithful static-batch semantics.

    Per-request sampling is the same ``_SAMPLER`` program at the same
    (seed, position) inputs as the engine, which is why engine outputs
    match this path bitwise.  Logit finiteness is accumulated across the
    WHOLE decode (``FiniteTrace``) — a mid-sequence NaN is reported at the
    step it appeared."""
    clock = clock if clock is not None else WallClock()
    window = cfg.sliding_window
    slot_tokens = min(cache_len, window) if window else cache_len
    metrics = ServeMetrics(n_slots, slot_tokens)
    prefill = jax.jit(make_prefill_step(cfg, cache_len, impl=impl))
    serve = jax.jit(make_serve_step(cfg, impl=impl))
    order = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    groups = [order[i:i + n_slots] for i in range(0, len(order), n_slots)]
    for r in order:
        need = r.prompt_len + r.max_new_tokens
        if not window and need > cache_len:
            raise ValueError(f"request {r.rid} exceeds cache_len {cache_len}")

    if warmup:
        rng = np.random.default_rng(0)
        for g in groups:
            G, L = len(g), g[0].prompt_len
            lgw, _ = prefill(params, prompt_batch(cfg, G, L, rng))
            jnp.all(jnp.isfinite(lgw))     # warm the FiniteTrace eager ops
            z = jnp.zeros(G)
            jax.block_until_ready(
                _SAMPLER(lgw, z.astype(jnp.int32), z.astype(jnp.int32),
                         z.astype(jnp.float32)))
        sizes = sorted(set(len(g) for g in groups))
        for G in sizes:
            cache = prefill(params, prompt_batch(
                cfg, G, groups[0][0].prompt_len, rng))[1]
            jax.block_until_ready(serve(
                params, {"tokens": jnp.full((G, 1), _PAD_ID, jnp.int32)},
                cache)[0])

    outputs: Dict[int, np.ndarray] = {}
    ftrace = FiniteTrace()
    clock.start()
    for g in groups:
        clock.advance_to(max(r.arrival_s for r in g))   # batch formation
        t_admit = clock.now()
        G = len(g)
        batch = request_batch(cfg, g)
        seeds = jnp.asarray([r.seed for r in g], jnp.int32)
        temps = jnp.asarray([r.temperature for r in g], jnp.float32)
        n_gen = np.zeros(G, np.int32)
        pos = np.asarray([r.prompt_len for r in g], np.int32)
        logits, cache = prefill(params, batch)
        ftrace.update(logits)
        toks = np.asarray(_SAMPLER(logits, seeds, jnp.asarray(pos), temps))
        t_first = clock.now()
        outs = [[int(t)] for t in toks]
        n_gen += 1
        done = np.array([DecodeEngine._stopped(r, int(t), 1)
                         for r, t in zip(g, toks)])
        recs = [RequestRecord(
            rid=r.rid, arrival_s=r.arrival_s, admit_s=t_admit,
            first_token_s=t_first, finish_s=t_first, prompt_len=r.prompt_len,
            n_generated=1) for r in g]
        cur = toks.reshape(G, 1).astype(np.int32)
        while not done.all():
            logits, cache = serve(params, {"tokens": jnp.asarray(cur)}, cache)
            ftrace.update(logits)
            pos_now = np.asarray([r.prompt_len for r in g], np.int32) + n_gen
            toks = np.asarray(_SAMPLER(logits, seeds, jnp.asarray(pos_now),
                                       temps))
            clock.tick()
            now = clock.now()
            n_active = int((~done).sum())
            used = sum(min(g[i].prompt_len + int(n_gen[i]), slot_tokens)
                       for i in range(G) if not done[i])
            for i in range(G):
                if done[i]:
                    continue
                tok_i = int(toks[i])
                outs[i].append(tok_i)
                n_gen[i] += 1
                cur[i, 0] = tok_i
                if DecodeEngine._stopped(g[i], tok_i, int(n_gen[i])):
                    done[i] = True
                    recs[i].finish_s = now
                    recs[i].n_generated = int(n_gen[i])
            metrics.on_step(n_active, used)
        for i, r in enumerate(g):
            outputs[r.rid] = np.asarray(outs[i], np.int32)
            metrics.finish(recs[i])
    if check_finite:
        ftrace.assert_finite("static decode")
    return outputs, metrics.summary()
