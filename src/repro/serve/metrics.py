"""Serving metrics: per-request latency records, step-level occupancy, the
``BENCH_serve.json`` payload, and the accumulated finiteness trace.

Every completed request leaves a ``RequestRecord`` (arrival -> admit ->
first token -> finish); ``ServeMetrics.summary()`` reduces the records plus
the per-step occupancy samples to the benchmark schema:

    tokens_per_s, generated_tokens, wall_s, n_decode_steps,
    ttft_s{mean,p50,p99}, latency_s{mean,p50,p99},
    slot_occupancy, cache_occupancy

``FiniteTrace`` is the accumulated replacement for the old final-step-only
``assert isfinite(logits)``: it banks one device-side flag per decode step
(no host sync in the loop) and, at the end, names the FIRST step whose
logits went non-finite — a mid-sequence NaN is reported where it happened
instead of being noticed (or masked) 30 steps later.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricsRegistry, summary_stats
from repro.serve.requests import tokens_per_s


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps (seconds on the run's clock) for one request."""

    rid: int
    arrival_s: float
    admit_s: float
    first_token_s: float
    finish_s: float
    prompt_len: int
    n_generated: int
    evictions: int = 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


def percentiles(xs: List[float]) -> Dict[str, float]:
    """mean/p50/p99 via the repo's single pinned rule
    (:func:`repro.obs.metrics.summary_stats` — exact linear interpolation,
    immune to numpy percentile-method changes)."""
    return summary_stats(xs)


class ServeMetrics:
    """Accumulates request records and per-step occupancy samples on a
    PRIVATE :class:`MetricsRegistry` (one per engine — parity tests run
    two engines in one process, so the process-wide registry would
    cross-contaminate their summaries; the engine's span/counter
    instrumentation feeds the global registry separately)."""

    def __init__(self, n_slots: int, slot_tokens: int):
        self.n_slots = int(n_slots)
        self.slot_tokens = int(slot_tokens)   # KV/state capacity per slot
        self.records: List[RequestRecord] = []
        self.registry = MetricsRegistry()

    def on_step(self, n_active: int, cache_tokens_used: int) -> None:
        """One decode step over the slot pool: ``n_active`` slots held live
        requests; ``cache_tokens_used`` cache positions held real tokens."""
        self.registry.counter("serve.decode_steps").inc()
        self.registry.histogram("serve.slot_occupancy").observe(
            n_active / max(self.n_slots, 1))
        cap = self.n_slots * max(self.slot_tokens, 1)
        self.registry.histogram("serve.cache_occupancy").observe(
            cache_tokens_used / cap)

    def finish(self, record: RequestRecord) -> None:
        self.records.append(record)
        self.registry.counter("serve.requests").inc()
        self.registry.counter("serve.generated_tokens").inc(
            record.n_generated)
        self.registry.histogram("serve.ttft_s").observe(record.ttft_s)
        self.registry.histogram("serve.latency_s").observe(record.latency_s)

    @property
    def _steps(self) -> int:
        return int(self.registry.counter("serve.decode_steps").value)

    def summary(self) -> Dict[str, Any]:
        recs = sorted(self.records, key=lambda r: r.rid)
        total_tokens = sum(r.n_generated for r in recs)
        if recs:
            span = (max(r.finish_s for r in recs)
                    - min(r.arrival_s for r in recs))
        else:
            span = 0.0
        slot = self.registry.histogram("serve.slot_occupancy").summary()
        cache = self.registry.histogram("serve.cache_occupancy").summary()
        return {
            "n_requests": len(recs),
            "generated_tokens": total_tokens,
            "wall_s": span,
            "n_decode_steps": self._steps,
            "tokens_per_s": tokens_per_s(total_tokens, span),
            "ttft_s": percentiles([r.ttft_s for r in recs]),
            "latency_s": percentiles([r.latency_s for r in recs]),
            "slot_occupancy": slot["mean"],
            "cache_occupancy": cache["mean"],
        }


# The keys scripts/serve_smoke.sh (and the docs) hold the schema to.
BENCH_MODE_KEYS = ("n_requests", "generated_tokens", "wall_s",
                   "n_decode_steps", "tokens_per_s", "ttft_s", "latency_s",
                   "slot_occupancy", "cache_occupancy")


def write_bench(path: str, payload: Dict[str, Any]) -> str:
    """Write a BENCH_*.json perf-trajectory file (sorted keys, trailing
    newline — two identical runs produce byte-identical files)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


class FiniteTrace:
    """Accumulated per-step finiteness check.

    ``update(logits)`` banks one device-side boolean per decode step (the
    all-finite reduction stays on device; nothing syncs inside the loop).
    ``first_failure()`` pulls the flags once and returns the index of the
    first non-finite step, or None.  ``assert_finite()`` raises naming that
    step — where the NaN happened, not where it was finally looked at."""

    def __init__(self):
        self._flags = []

    def update(self, logits) -> None:
        self._flags.append(jnp.all(jnp.isfinite(logits)))

    def __len__(self) -> int:
        return len(self._flags)

    def first_failure(self) -> Optional[int]:
        if not self._flags:
            return None
        flags = np.asarray(jnp.stack(self._flags))
        bad = np.flatnonzero(~flags)
        return int(bad[0]) if bad.size else None

    def assert_finite(self, what: str = "decode") -> None:
        bad = self.first_failure()
        if bad is not None:
            raise FloatingPointError(
                f"non-finite logits first appeared at {what} step {bad} "
                f"of {len(self._flags)}")
