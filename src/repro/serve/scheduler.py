"""Request queue + open-loop traffic generation + serving clocks.

``PoissonArrivals`` stamps requests with open-loop arrival times (exponential
inter-arrivals at ``rate_rps``, seeded — the generator never waits for the
server, which is what "heavy traffic" means: load keeps coming whether or
not slots are free).  ``FIFOScheduler`` holds the stamped requests and
releases them in arrival order once their timestamp has passed.

Clocks decouple the engine loop from real time: ``WallClock`` is
``time.perf_counter`` anchored at ``start()`` (``advance_to`` sleeps, so an
idle engine honestly waits for the next open-loop arrival), and
``VirtualClock`` advances only when told (a fixed ``step_s`` per decode
step) — the deterministic clock the tests and the bitwise parity checks run
under.
"""

from __future__ import annotations

import time
from collections import deque
from typing import List, Optional

import numpy as np

from repro.serve.requests import Request


class PoissonArrivals:
    """Open-loop Poisson arrival process: ``assign`` stamps each request's
    ``arrival_s`` with a seeded exponential inter-arrival draw at
    ``rate_rps`` requests/second (rate 0 = everything arrives at t=0)."""

    def __init__(self, rate_rps: float, seed: int = 0):
        if rate_rps < 0:
            raise ValueError(f"rate_rps must be >= 0, got {rate_rps}")
        self.rate_rps = float(rate_rps)
        self.seed = int(seed)

    def times(self, n: int) -> np.ndarray:
        if self.rate_rps == 0:
            return np.zeros(n)
        rng = np.random.default_rng(self.seed)
        return np.cumsum(rng.exponential(1.0 / self.rate_rps, size=n))

    def assign(self, requests: List[Request]) -> List[Request]:
        ts = self.times(len(requests))
        return [r.replace(arrival_s=float(t)) for r, t in zip(requests, ts)]


class FIFOScheduler:
    """FIFO over arrived requests.  The engine drains ``next_ready`` into
    free slots BEFORE each decode step (prefill-prioritized admission: a
    waiting request never idles behind decode work while a slot is open)."""

    def __init__(self, requests: List[Request]):
        order = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        self._pending = deque(order)     # not yet arrived (time-sorted)
        self._ready: deque = deque()     # arrived, waiting for a slot

    def poll(self, now: float) -> None:
        while self._pending and self._pending[0].arrival_s <= now:
            self._ready.append(self._pending.popleft())

    def next_ready(self, now: float) -> Optional[Request]:
        self.poll(now)
        return self._ready.popleft() if self._ready else None

    def next_arrival(self) -> Optional[float]:
        """Earliest not-yet-arrived timestamp (None when all arrived)."""
        return self._pending[0].arrival_s if self._pending else None

    @property
    def waiting(self) -> int:
        return len(self._pending) + len(self._ready)

    def __len__(self) -> int:
        return self.waiting


class WallClock:
    """Real time, anchored at ``start()``; ``advance_to`` sleeps until the
    target (the engine is idle and the next open-loop arrival is ahead)."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance_to(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)

    def tick(self) -> None:            # decode steps advance real time alone
        pass


class VirtualClock:
    """Deterministic clock: ``tick()`` (one decode step) advances ``step_s``,
    ``advance_to`` jumps.  Engine runs under it are exactly reproducible —
    the parity tests pin engine-vs-static outputs bitwise under this."""

    def __init__(self, step_s: float = 1.0):
        self.step_s = float(step_s)
        self._now = 0.0

    def start(self) -> None:
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        self._now = max(self._now, t)

    def tick(self) -> None:
        self._now += self.step_s
