"""Static analyzer for partitioned HLO text -> roofline terms.

Why not ``compiled.cost_analysis()`` alone: XLA's aggregate cost counts a
while-loop body ONCE, but a scanned L-layer stack executes it L times — the
dominant share of a transformer step.  Unrolling every stack for the dry-run
is exact but costs 10-30 min of compile per big arch on this 1-core host.

This analyzer instead walks the HLO text's computation call graph:
  * builds a per-computation symbol table (%name -> shape),
  * finds ``while`` ops, extracts trip counts from their condition
    computations (the scan length constant),
  * propagates execution multiplicity ENTRY=1 down through while bodies
    (x trip count), conditionals / fusions / calls (x1),
  * counts per computation: dot FLOPs (2*M*N*K from result shape x
    contracting dims), collective result bytes by kind, and HBM traffic
    (operand + result bytes of every top-level op — fusion internals are
    hidden, which mirrors what a fused TPU executable actually reads/writes).

Validated against ``cost_analysis`` on fully-unrolled programs (see
tests/test_hlo_analysis.py): dot-FLOP totals agree within a few percent.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},\d]+(?:\[[\d,]*\])?(?:{[^}]*})?)\s+([\w\-]+)\((.*)$")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    result: str            # result type text
    opcode: str
    rest: str               # operand list + attrs


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]   # %name -> result type text


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers start at column 0, contain '->', end with '{'
            if line and not line[0].isspace() and "->" in line \
                    and line.endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.symtab[op.name] = op.result
    return comps


_CALLED_RE = re.compile(
    r"(?:condition|body|calls|to_apply|branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


def _called(op: Op) -> List[str]:
    names: List[str] = []
    for m in _CALLED_RE.finditer(op.rest):
        for n in m.group(1).split(","):
            names.append(n.strip().lstrip("%"))
    return names


def _while_parts(op: Op) -> Tuple[Optional[str], Optional[str]]:
    cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    body = re.search(r"body=%?([\w\.\-]+)", op.rest)
    return (cond.group(1) if cond else None, body.group(1) if body else None)


def trip_count(cond: Computation) -> int:
    """Scan conditions compare the induction var against a constant — take
    the largest integer constant in the condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def multiplicities(comps: Dict[str, Computation], entry: str
                   ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Returns (flop_mult, byte_mult).

    flop_mult descends everywhere (dots inside fused computations count);
    byte_mult descends only through control flow (while/conditional) — a
    fusion's internal buffers never touch HBM, only the fusion op's own
    operands/results do (counted at its call site)."""
    flop_mult: Dict[str, float] = {}
    byte_mult: Dict[str, float] = {}

    def visit(name: str, m: float, fused: bool):
        if name not in comps:
            return
        flop_mult[name] = flop_mult.get(name, 0.0) + m
        if not fused:
            byte_mult[name] = byte_mult.get(name, 0.0) + m
        c = comps[name]
        for op in c.ops:
            if op.opcode == "while":
                cond_n, body_n = _while_parts(op)
                t = trip_count(comps[cond_n]) if cond_n in comps else 1
                if cond_n in comps:
                    visit(cond_n, m * (t + 1), fused)
                if body_n in comps:
                    visit(body_n, m * t, fused)
            elif op.opcode == "conditional":
                for child in _called(op):
                    visit(child, m, fused)
            else:
                for child in _called(op):
                    visit(child, m, True)

    visit(entry, 1.0, False)
    return flop_mult, byte_mult


def _entry_name(comps: Dict[str, Computation], hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_n = 1
    for d in _shape_dims(op.result):
        out_n *= d
    operands = [o.strip().lstrip("%") for o in
                op.rest.split(")", 1)[0].split(",")]
    lhs = operands[0] if operands else None
    k = 1
    m = _CONTRACT_RE.search(op.rest)
    if m and lhs in symtab:
        dims = _shape_dims(symtab[lhs])
        for i in m.group(1).split(","):
            if i and int(i) < len(dims):
                k *= dims[int(i)]
    return 2.0 * out_n * k


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


def _operands(op: Op) -> List[str]:
    head = op.rest.split(")", 1)[0]
    return [o.strip().lstrip("%") for o in head.split(",") if o.strip()]


def _op_bytes(op: Op, symtab: Dict[str, str]) -> float:
    """HBM traffic attributed to one top-level op.  Dynamic (update-)slices
    only move the slice, not the buffer they index into."""
    if op.opcode == "dynamic-slice":
        return 2.0 * _shape_bytes(op.result)
    if op.opcode == "dynamic-update-slice":
        ops = _operands(op)
        upd = _shape_bytes(symtab.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd
    operand_b = sum(_shape_bytes(symtab[o]) for o in _operands(op)
                    if o in symtab)
    return float(_shape_bytes(op.result) + operand_b)


def top_contributors(hlo: str, kind: str = "bytes", n: int = 15):
    """Diagnosis: the n largest (computation, opcode, result, mult, total)
    contributors to the chosen roofline term."""
    comps = parse_computations(hlo)
    entry = _entry_name(comps, hlo)
    flop_mult, byte_mult = multiplicities(comps, entry)
    rows = []
    mult = flop_mult if kind == "flops" else byte_mult
    for cname, m in mult.items():
        c = comps[cname]
        for op in c.ops:
            if kind == "flops":
                if op.opcode in ("dot", "convolution"):
                    rows.append((cname, op.opcode, op.result, m,
                                 m * _dot_flops(op, c.symtab)))
            elif kind == "collective":
                if any(op.opcode.startswith(k) for k in _COLLECTIVES):
                    rows.append((cname, op.opcode, op.result, m,
                                 m * _shape_bytes(op.result)))
            else:
                if op.opcode not in _SKIP_BYTES:
                    rows.append((cname, op.opcode, op.result, m,
                                 m * _op_bytes(op, c.symtab)))
    rows.sort(key=lambda r: -r[-1])
    return rows[:n]


def analyze(hlo: str) -> HloStats:
    comps = parse_computations(hlo)
    entry = _entry_name(comps, hlo)
    flop_mult, byte_mult = multiplicities(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    for cname, m in flop_mult.items():
        c = comps[cname]
        for op in c.ops:
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, c.symtab)
    for cname, m in byte_mult.items():
        c = comps[cname]
        for op in c.ops:
            for kind in _COLLECTIVES:
                if op.opcode == kind or op.opcode == kind + "-start":
                    coll[kind] += m * _shape_bytes(op.result)
            if op.opcode not in _SKIP_BYTES:
                hbm += m * _op_bytes(op, c.symtab)
    return HloStats(dot_flops=flops, hbm_bytes=hbm, collective_bytes=coll)
