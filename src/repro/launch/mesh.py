"""Production meshes (TPU v5e-256 pods).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).
"""

from __future__ import annotations

import jax

# v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_BYTES = 16 * 2**30            # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Degenerate mesh over the local devices (CPU tests)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, min(model, n // data))),
                         ("data", "model"))
