import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, report memory / FLOPs / collective schedule -> roofline terms.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, an unsupported collective, or a spec that
cannot partition fails HERE.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all          # the full 40-pair matrix
Writes one JSON artifact per run under benchmarks/results/dryrun/.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import optim, telemetry
from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import SHAPES, input_specs, shape_config
from repro.launch import mesh as meshlib
from repro.models.model import init_model
from repro.models.steps import (abstract_train_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.nn import param as P
from repro.sharding.ctx import activation_sharding
from repro.sharding.rules import (DECODE_RULES, DEFAULT_RULES,
                                  LONG_CONTEXT_RULES, OPT_RULES,
                                  tree_shardings)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


@dataclasses.dataclass
class Knobs:
    """Per-run tunables the §Perf hillclimb iterates on."""
    microbatches: int = 1
    opt_state_dtype: Optional[str] = None     # None -> param dtype
    remat: Optional[bool] = None              # None -> config default
    opt_rules: bool = False                   # OPT_RULES (context-parallel attn)
    impl: str = "xla"                         # "chunked": blockwise SSM scans
    frozen_frac: float = 0.0                  # FFDAPT window fraction (train)
    moe_groups: int = 0                       # local (per-group) MoE dispatch


def count_params_split(cfg):
    """(total, moe_expert) param counts from abstract init — no allocation."""
    boxed = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    total = P.count_params(boxed)
    moe = 0
    layers = boxed.get("layers")
    if isinstance(layers, dict) and "moe" in layers:
        for name in ("wi_gate", "wi_up", "wo"):
            v = layers["moe"][name].value
            n = 1
            for d in v.shape:
                n *= d
            moe += n
    return total, moe


def model_flops(cfg, spec) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training (N = active params for MoE),
    2*N*D for inference shapes.  Global (all chips)."""
    total, moe = count_params_split(cfg)
    active = total - moe + (moe * cfg.top_k // max(cfg.n_experts, 1))
    if spec.kind == "train":
        d_tokens = spec.global_batch * spec.seq_len
        return 6.0 * active * d_tokens
    if spec.kind == "prefill":
        return 2.0 * active * spec.global_batch * spec.seq_len
    return 2.0 * active * spec.global_batch          # decode: one token




def lower_pair(arch: str, shape: str, *, multi_pod: bool = False,
               knobs: Knobs = Knobs()) -> Dict[str, Any]:
    """Lower+compile one (arch, shape) on the production mesh; return the
    roofline record."""
    spec = SHAPES[shape]
    cfg = shape_config(get_config(arch), shape)
    if knobs.remat is not None:
        cfg = cfg.replace(remat=knobs.remat)
    if knobs.moe_groups:
        cfg = cfg.replace(moe_local_dispatch=True)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = {"long_500k": LONG_CONTEXT_RULES,
             "decode_32k": DECODE_RULES}.get(shape, DEFAULT_RULES)
    if knobs.opt_rules:
        rules = OPT_RULES

    ins = input_specs(cfg, shape)
    batch_sh = tree_shardings(ins["batch"], mesh, rules)
    batch_sds = P.unbox(ins["batch"])

    t0 = time.perf_counter()
    ctx = activation_sharding(mesh, rules)
    ctx.__enter__()
    if spec.kind == "train":
        sdt = jnp.dtype(knobs.opt_state_dtype) if knobs.opt_state_dtype else None
        optimizer = optim.adam(5e-5, state_dtype=sdt)
        params_b, opt_b = abstract_train_state(cfg, optimizer, boxed=True)
        p_sh = tree_shardings(params_b, mesh, rules)
        o_sh = tree_shardings(opt_b, mesh, rules)
        frozen = None
        if knobs.frozen_frac:
            from repro.models.model import n_freeze_units
            from repro.nn.stack import freeze_window_mask
            n = n_freeze_units(cfg)
            frozen = freeze_window_mask(n, (0, int(n * knobs.frozen_frac)))
        step = make_train_step(cfg, optimizer, microbatches=knobs.microbatches,
                               impl=knobs.impl, frozen=frozen)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, batch_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(P.unbox(params_b), P.unbox(opt_b), batch_sds)
    elif spec.kind == "prefill":
        params_b = jax.eval_shape(lambda k: init_model(k, cfg),
                                  jax.random.PRNGKey(0))
        p_sh = tree_shardings(params_b, mesh, rules)
        from repro.models.model import cache_struct
        cache_b = cache_struct(cfg, spec.global_batch, spec.seq_len)
        # the filled cache is decode-layout: seq over "model" (kv heads
        # rarely divide it), or it costs 16x cache memory at 32k
        c_sh = tree_shardings(cache_b, mesh,
                              DECODE_RULES if not knobs.opt_rules else rules)
        step = make_prefill_step(cfg, spec.seq_len, impl=knobs.impl)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(P.unbox(params_b), batch_sds)
    else:  # decode
        params_b = jax.eval_shape(lambda k: init_model(k, cfg),
                                  jax.random.PRNGKey(0))
        p_sh = tree_shardings(params_b, mesh, rules)
        cache_b = ins["cache"]
        c_sh = tree_shardings(cache_b, mesh, rules)
        step = make_serve_step(cfg, impl=knobs.impl)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = jitted.lower(P.unbox(params_b), batch_sds, P.unbox(cache_b))

    ctx.__exit__(None, None, None)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = telemetry.xla_cost(compiled)
    # scan-aware static analysis of the partitioned HLO (cost_analysis counts
    # a while body once; the analyzer multiplies by trip count)
    stats = telemetry.analyze(compiled.as_text())
    coll = {k: int(v) for k, v in stats.collective_bytes.items()}

    flops = float(stats.dot_flops)
    bytes_hbm = float(stats.hbm_bytes)
    coll_total = float(stats.collective_total)
    model_fl = model_flops(cfg, spec)

    record = {
        "arch": arch, "shape": shape, "kind": spec.kind,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "knobs": dataclasses.asdict(knobs),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_estimate_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)
                                       or (getattr(mem, "argument_size_in_bytes", 0)
                                           + getattr(mem, "output_size_in_bytes", 0)
                                           + getattr(mem, "temp_size_in_bytes", 0)
                                           - getattr(mem, "alias_size_in_bytes", 0))),
        },
        # analyzer terms are per-device for the partitioned program
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll,
        "cost_analysis_flops_per_device": float(cost.get("flops", 0.0)),
        "model_flops_global": model_fl,
        # how much compiled compute is useful (remat/replication waste shows
        # up here): 6ND (or 2ND inference) / (per-device dots x chips)
        "model_vs_hlo_flops": model_fl / max(flops * n_chips, 1.0),
        "roofline_s": {
            "compute": flops / meshlib.PEAK_FLOPS_BF16,
            "memory": bytes_hbm / meshlib.HBM_BW,
            "collective": coll_total / meshlib.ICI_BW,
        },
    }
    r = record["roofline_s"]
    record["bottleneck"] = max(r, key=r.get)
    return record


def lower_fed_round(arch: str = "distilbert-mlm", *, clients: int = 2,
                    local_steps: int = 4, seq_len: int = 4096,
                    global_batch: int = 256) -> Dict[str, Any]:
    """Lower + compile ONE FFDAPT federated round on the 2-pod mesh: clients
    pinned to pods (FED_RULES), local epochs in parallel, FedAvg = the
    cross-pod weighted all-reduce.  The production form of the paper's
    technique."""
    from repro.core.rounds import make_fed_round_program
    from repro.models.model import n_freeze_units
    from repro.sharding.rules import FED_RULES

    cfg = get_config(arch)
    mesh = meshlib.make_production_mesh(multi_pod=True)
    optimizer = optim.adam(5e-5)
    K = clients
    B_local = global_batch // K
    n_units = n_freeze_units(cfg)

    def full(key):
        p = init_model(key, cfg)
        return p, optimizer.init(p)

    pb, ob = jax.eval_shape(full, jax.random.PRNGKey(0))

    def stack_boxed(tree):
        return jax.tree.map(
            lambda b: P.Box(jax.ShapeDtypeStruct((K,) + b.value.shape,
                                                 b.value.dtype),
                            (P.CLIENT,) + tuple(b.axes)) if P.is_box(b)
            else jax.ShapeDtypeStruct((K,) + b.shape, b.dtype),
            tree, is_leaf=P.is_box)

    spb, sob = stack_boxed(pb), stack_boxed(ob)
    p_sh = tree_shardings(spb, mesh, FED_RULES)
    o_sh = tree_shardings(sob, mesh, FED_RULES)
    bshape = (K, local_steps, B_local, seq_len)
    bax = (P.CLIENT, None, P.BATCH, P.SEQ)
    batch = {
        "tokens": P.Box(jax.ShapeDtypeStruct(bshape, jnp.int32), bax),
        "targets": P.Box(jax.ShapeDtypeStruct(bshape, jnp.int32), bax),
        "loss_mask": P.Box(jax.ShapeDtypeStruct(bshape, jnp.float32), bax),
    }
    b_sh = tree_shardings(batch, mesh, FED_RULES)
    fmasks = jax.ShapeDtypeStruct((K, n_units), jnp.float32)
    sizes = jax.ShapeDtypeStruct((K,), jnp.float32)

    prog = make_fed_round_program(cfg, optimizer)
    t0 = time.perf_counter()
    with activation_sharding(mesh, FED_RULES):
        lowered = jax.jit(prog, in_shardings=(p_sh, o_sh, b_sh, None, None),
                          out_shardings=(p_sh, None),
                          donate_argnums=(0, 1)).lower(
            P.unbox(spb), P.unbox(sob), P.unbox(batch), fmasks, sizes)
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    stats = telemetry.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    coll = {k: int(v) for k, v in stats.collective_bytes.items()}
    return {
        "program": "fed_round_ffdapt", "arch": arch, "clients": K,
        "local_steps": local_steps, "seq_len": seq_len,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(stats.dot_flops),
        "hbm_bytes_per_device": float(stats.hbm_bytes),
        "collective_bytes_per_device": coll,
        "memory_peak_gib": float(getattr(mem, "peak_memory_in_bytes", 0)) / 2**30,
        "roofline_s": {
            "compute": stats.dot_flops / meshlib.PEAK_FLOPS_BF16,
            "memory": stats.hbm_bytes / meshlib.HBM_BW,
            "collective": stats.collective_total / meshlib.ICI_BW,
        },
        "status": "ok",
    }


def run_and_save(arch: str, shape: str, *, multi_pod: bool,
                 knobs: Knobs = Knobs(), tag: str = "") -> Dict[str, Any]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}{tag}"
    try:
        rec = lower_pair(arch, shape, multi_pod=multi_pod, knobs=knobs)
        rec["status"] = "ok"
    except Exception as e:  # a failure here is a sharding bug — record it
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-state-dtype", default=None)
    ap.add_argument("--opt-rules", action="store_true")
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--frozen-frac", type=float, default=0.0)
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--fed", action="store_true",
                    help="lower the FFDAPT federated-round program (2 pods)")
    ap.add_argument("--fed-steps", type=int, default=4)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.fed:
        rec = lower_fed_round(args.arch or "distilbert-mlm",
                              local_steps=args.fed_steps)
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR,
                               f"fed_round__{rec['arch']}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline_s"]
        print(f"OK  fed_round {rec['arch']} K={rec['clients']} "
              f"steps={rec['local_steps']} compile={rec['compile_s']}s "
              f"compute={r['compute']:.3e}s memory={r['memory']:.3e}s "
              f"coll={r['collective']:.3e}s")
        return

    knobs = Knobs(microbatches=args.microbatches,
                  opt_state_dtype=args.opt_state_dtype,
                  opt_rules=args.opt_rules, impl=args.impl,
                  frozen_frac=args.frozen_frac, moe_groups=args.moe_groups)
    pairs = []
    if args.all:
        pairs = [(a, s, mp) for a in ASSIGNED for s in SHAPES
                 for mp in (False, True)]
    else:
        pairs = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in pairs:
        rec = run_and_save(arch, shape, multi_pod=mp, knobs=knobs, tag=args.tag)
        if rec["status"] == "ok":
            r = rec["roofline_s"]
            print(f"OK  {arch:22s} {shape:12s} pods={2 if mp else 1} "
                  f"compile={rec['compile_s']:7.1f}s "
                  f"mem={rec['memory']['peak_estimate_bytes']/2**30:6.2f}GiB "
                  f"compute={r['compute']:.3e}s memory={r['memory']:.3e}s "
                  f"coll={r['collective']:.3e}s -> {rec['bottleneck']}")
        else:
            print(f"ERR {arch:22s} {shape:12s} pods={2 if mp else 1} "
                  f"{rec['error']}")


if __name__ == "__main__":
    main()
