"""Batched serving driver: prefill a prompt batch, then greedy-decode.

Exercises the decode-shape program (``serve_step``: one token against the KV
cache) that the dry-run lowers at production scale.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.models.steps import make_prefill_step, make_serve_step
from repro.nn import param as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    if cfg.arch_type == "mlm":
        raise SystemExit("mlm is encoder-only: no decode step (see DESIGN.md)")

    cache_len = args.prompt_len + args.tokens
    params = P.unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
    prefill = jax.jit(make_prefill_step(cfg, cache_len, impl=args.impl))
    serve = jax.jit(make_serve_step(cfg, impl=args.impl))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(5, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 0.1, (args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.1, (args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        step_batch = {"tokens": tok}
        logits, cache = serve(params, step_batch, cache)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out_tokens, axis=1)
    tps = args.batch * (args.tokens - 1) / max(dt, 1e-9)
    print(f"decode: {args.tokens-1} steps, {tps:.1f} tok/s "
          f"({dt/(args.tokens-1)*1e3:.1f} ms/step)")
    print("sample token ids:", np.asarray(seq[0, :16]))
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"


if __name__ == "__main__":
    main()
