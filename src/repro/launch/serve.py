"""Serving CLI over ``repro.serve``: continuous batching by default, the
static-batch baseline behind ``--static``.

Serves either fresh-initialized params (default, a shape/perf exercise) or a
real FDAPT checkpoint::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --ckpt-dir runs/fed/checkpoints            # serve the global model
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --static \
        --bench-out BENCH_static.json              # baseline + metrics dump

Traffic is an open-loop Poisson process (``--rate`` requests/s, seeded):
arrivals never wait for the server, so queueing shows up in the latency
percentiles instead of being hidden by closed-loop backpressure.  Stops per
request on ``--tokens`` (max new tokens) or ``--eos-id``.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.nn import param as P
from repro.serve import (DecodeEngine, EngineConfig, PoissonArrivals,
                         load_serving_params, run_static, synthetic_requests,
                         write_bench)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve params from a repro.checkpoint archive "
                         "(a FedSession round checkpoint or bare snapshot)")
    ap.add_argument("--ckpt-step", type=int, default=None,
                    help="checkpoint step (default: newest in --ckpt-dir)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (static mode: batch size)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32,
                    help="max new tokens per request (incl. the "
                         "prefill-produced token)")
    ap.add_argument("--min-tokens", type=int, default=None,
                    help="per-request stop lengths drawn uniform "
                         "[min,--tokens] (default: all equal --tokens)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, requests/s (0 = all at t=0)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window variant (ring KV cache)")
    ap.add_argument("--static", action="store_true",
                    help="static-batch baseline instead of the engine")
    ap.add_argument("--bench-out", default=None,
                    help="write the metrics summary as JSON")
    ap.add_argument("--trace-out", default="",
                    help="enable the span tracer and write a Chrome "
                         "trace-event JSON here (admit/decode/evict spans "
                         "+ compile events; load in Perfetto)")
    ap.add_argument("--metrics-out", default="",
                    help="write the process-wide metrics registry as JSONL")
    ap.add_argument("--drift-out", default="",
                    help="join the measured mean decode-step seconds "
                         "against a --drift-device roofline prediction and "
                         "write the ratio ledger (JSON) here")
    ap.add_argument("--drift-device", default="rtx2080ti",
                    help="device preset pricing the decode step for "
                         "--drift-out (see repro.sim.fleet.PRESETS)")
    ap.add_argument("--drift-warn", type=float, default=4.0,
                    help="drift warn threshold: warn when "
                         "measured/predicted falls outside [1/W, W]")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    from repro import obs
    if args.trace_out:
        obs.enable()
        obs.capture_compiles()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    if cfg.arch_type == "mlm":
        raise SystemExit("mlm is encoder-only: no decode step (see DESIGN.md)")

    if args.ckpt_dir:
        params, step, _ = load_serving_params(args.ckpt_dir, cfg,
                                              args.ckpt_step)
        print(f"params: checkpoint step {step} from {args.ckpt_dir}")
    else:
        params = P.unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
        print("params: fresh init (pass --ckpt-dir to serve a trained model)")

    cache_len = args.prompt_len + args.tokens
    rng = np.random.default_rng(args.seed)
    requests = synthetic_requests(
        cfg, args.requests, prompt_len=args.prompt_len, rng=rng,
        max_new_tokens=args.tokens, min_new_tokens=args.min_tokens,
        eos_id=args.eos_id, temperature=args.temperature, seed=args.seed)
    requests = PoissonArrivals(args.rate, seed=args.seed).assign(requests)

    mode = "static" if args.static else "continuous"
    if args.static:
        outputs, summary = run_static(cfg, params, requests,
                                      n_slots=args.slots,
                                      cache_len=cache_len, impl=args.impl)
    else:
        engine = DecodeEngine(cfg, params, EngineConfig(
            n_slots=args.slots, cache_len=cache_len, impl=args.impl))
        outputs, summary = engine.run(requests)
        print(f"compiled programs: decode={engine.decode_cache_size()} "
              f"prefill={engine.prefill_cache_size()}")

    print(f"{cfg.name} ({cfg.arch_type}) {mode}: "
          f"{summary['n_requests']} requests, "
          f"{summary['generated_tokens']} tokens, "
          f"{summary['tokens_per_s']:.1f} tok/s, "
          f"TTFT p50 {summary['ttft_s']['p50']*1e3:.1f} ms, "
          f"latency p99 {summary['latency_s']['p99']*1e3:.1f} ms, "
          f"slot occupancy {summary['slot_occupancy']:.2f}")
    rid0 = min(outputs)
    print(f"request {rid0} tokens: {outputs[rid0][:16]}")
    if args.bench_out:
        write_bench(args.bench_out, {
            "benchmark": "serve", "arch": cfg.name, "mode": mode,
            "workload": {"requests": args.requests,
                         "prompt_len": args.prompt_len,
                         "max_new_tokens": args.tokens,
                         "rate_rps": args.rate, "seed": args.seed},
            "engine": {"n_slots": args.slots, "cache_len": cache_len,
                       "impl": args.impl},
            "metrics": summary,
        })
        print(f"wrote {args.bench_out}")
    else:
        print(json.dumps(summary, indent=2, sort_keys=True))

    if args.drift_out:
        from repro.sim.clock import device_roofline_s
        from repro.sim.fleet import PRESETS
        from repro.telemetry import decode_step_cost
        dev = PRESETS[args.drift_device]
        cost = decode_step_cost(cfg, args.slots, cache_len, impl=args.impl)
        terms = device_roofline_s(cost.flops, cost.hbm_bytes,
                                  cost.collective_bytes, dev)
        predicted = max(terms["compute"], terms["memory"]) + terms["collective"]
        # measured per-step seconds: the tracer's spans when tracing, else
        # the run's wall seconds over its decode steps
        spans = [e.dur_us / 1e6 for e in obs.get_tracer().events()
                 if e.name == "serve.decode_step"]
        if spans:
            measured = sum(spans) / len(spans)
        else:
            measured = (summary["wall_s"]
                        / max(summary["n_decode_steps"], 1))
        mon = obs.DriftMonitor(warn_ratio=args.drift_warn)
        mon.observe(0, "decode_step", measured, predicted,
                    source=f"device:{dev.name}")
        print("\n".join(mon.lines()))
        print("drift ledger:", mon.export(args.drift_out))
    if args.trace_out:
        print("chrome trace:", obs.get_tracer().export(args.trace_out))
    if args.metrics_out:
        print("metrics:", obs.registry().export_jsonl(args.metrics_out))


if __name__ == "__main__":
    main()
