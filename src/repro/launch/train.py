"""Federated DAPT training driver (the paper's Stage-2 pipeline, end to end).

Runs FDAPT / FFDAPT on the synthetic biomedical corpus with any arch from the
zoo.  On this CPU container it defaults to the reduced config (the full
configs are exercised by the dry-run); on a real TPU fleet the same driver
runs the full config with the production mesh.

    PYTHONPATH=src python -m repro.launch.train \
        --arch distilbert-mlm --clients 8 --skew length --rounds 15 --ffdapt \
        --strategy fedprox --compress topk --participation 0.5
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import optim
from repro.checkpoint import latest_step, tree_digest
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan
from repro.core.strategy import COMPRESSORS, STRATEGIES, make_strategy
from repro.sim import FLEETS, make_fleet
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="distilbert-mlm")
    ap.add_argument("--clients", type=int, default=2,
                    help="client population size; with --client-pool this "
                         "can go to 100k-1M (clients are virtual and only "
                         "sampled cohorts materialize data)")
    ap.add_argument("--client-pool", type=int, default=0,
                    help="mega-cohort mode: back --clients VIRTUAL clients "
                         "with this many lazily-built data shards (client k "
                         "trains shard k %% pool); 0 = materialize every "
                         "client's batches up front")
    ap.add_argument("--cohort-shard", type=int, default=0,
                    help="parallel engine: process the sampled cohort in "
                         "shards of this many clients (O(shard) live "
                         "memory; bitwise-identical to the full-width "
                         "round at any value); 0 = one full-cohort shard")
    ap.add_argument("--skew", default="iid",
                    choices=("iid", "quantity", "length", "vocab"))
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--ffdapt", action="store_true")
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--epsilon", type=int, default=0)
    ap.add_argument("--param-space", default="",
                    choices=["", "full", "frozen_window", "lora", "adapter"],
                    help="trainable subspace (repro.peft): lora/adapter "
                         "train+ship only a low-rank bank (orders of "
                         "magnitude less upload); frozen_window names the "
                         "--ffdapt masking explicitly; default: implicit")
    ap.add_argument("--lora-rank", type=int, default=4,
                    help="LoRA rank r (--param-space lora)")
    ap.add_argument("--lora-alpha", type=float, default=0.0,
                    help="LoRA merge scale alpha (0 = alpha=r, scale 1)")
    ap.add_argument("--adapter-dim", type=int, default=8,
                    help="adapter bottleneck (--param-space adapter)")
    ap.add_argument("--peft-targets", default="attn,mlp",
                    help="comma list of projection groups to adapt")
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "parallel"))
    ap.add_argument("--strategy", default="fedavg", choices=STRATEGIES)
    ap.add_argument("--compress", default="none", choices=COMPRESSORS,
                    help="client-upload delta compression")
    ap.add_argument("--mu", type=float, default=0.01,
                    help="FedProx proximal coefficient")
    ap.add_argument("--server-beta", type=float, default=0.9,
                    help="FedAvgM server momentum")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="fraction of delta entries kept by --compress topk")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled each round")
    ap.add_argument("--fleet", default="",
                    help="simulate wall-clock on a named device fleet "
                         f"(one of {FLEETS}); empty = no simulation")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="with --fleet: also simulate deadline-based "
                         "over-selection (seconds per round)")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="with --fleet: also simulate FedBuff-style async "
                         "aggregation with this buffer size")
    ap.add_argument("--async-alpha", type=float, default=0.5,
                    help="staleness discount exponent for --strategy "
                         "asyncfedavg / the async simulation report")
    ap.add_argument("--sim-seed", type=int, default=0,
                    help="seed for the fleet's availability process")
    ap.add_argument("--overlap", action="store_true",
                    help="with --fleet: pipelined clock (download/compute "
                         "and compute/upload overlap; only latencies stay "
                         "serial) instead of the sequential phase sum")
    ap.add_argument("--calibrated", action="store_true",
                    help="with --fleet: use the measurement-calibrated "
                         "device registry (repro.sim.calibrate, anchored "
                         "to the paper's 2x RTX 2080 Ti datapoint) instead "
                         "of datasheet presets")
    ap.add_argument("--docs", type=int, default=240)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-5)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) arch config")
    ap.add_argument("--max-steps-per-round", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="crash-safe round checkpoints: the session writes "
                         "the full run state (params + server state + RNG "
                         "+ FFDAPT pointer + history) here")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="with --ckpt-dir: checkpoint every N completed "
                         "rounds (the final round always checkpoints)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(bitwise identical to the uninterrupted run); "
                         "starts fresh when the directory is empty")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulated preemption: halt after this many "
                         "completed rounds (a checkpoint is written first "
                         "when --ckpt-dir is set); the resume smoke uses it")
    ap.add_argument("--ledger-out", default="",
                    help="write the deterministic run ledger (per-round "
                         "history minus wall-clock fields + a params "
                         "sha256) to this JSON file — two bitwise-equal "
                         "runs produce byte-equal files; wall-clock fields "
                         "go to a <ledger>.timing.json sidecar instead")
    ap.add_argument("--trace-out", default="",
                    help="enable the span tracer and write a Chrome "
                         "trace-event JSON here (load in Perfetto / "
                         "chrome://tracing): round/dispatch/aggregate/"
                         "checkpoint spans, compile events, and — with "
                         "--fleet — the simulated timeline side-by-side")
    ap.add_argument("--metrics-out", default="",
                    help="write the process-wide metrics registry "
                         "(counters/gauges/histograms) as JSONL here")
    ap.add_argument("--drift-out", default="",
                    help="run the measured-vs-predicted drift monitor over "
                         "the round history and write its ratio ledger "
                         "(JSON) here; predictions come from --fleet when "
                         "set, else the recorded sim_round_s")
    ap.add_argument("--drift-warn", type=float, default=4.0,
                    help="drift warn threshold: a round warns when "
                         "measured/predicted falls outside [1/W, W]")
    ap.add_argument("--jax-profile", default="",
                    help="also capture a jax.profiler device trace into "
                         "this directory (TensorBoard/xprof format)")
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    from repro import obs
    if args.trace_out:
        obs.enable()
        obs.capture_compiles()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} ({cfg.arch_type}) layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")

    from repro.data.corpus import split_holdout
    docs, held_docs = split_holdout(generate_corpus(args.docs, seed=args.seed))
    ds = None
    if args.client_pool:
        from repro.core.noniid import make_client_pool
        batches = make_client_pool(docs, cfg, n_clients=args.clients,
                                   pool=args.client_pool, skew=args.skew,
                                   batch=args.batch_size, seq=args.seq_len,
                                   seed=args.seed,
                                   limit=args.max_steps_per_round)
        sizes = batches.sizes
        print(f"client pool: {args.clients:,} virtual clients over "
              f"{args.client_pool} lazily-built data shards")
    else:
        ds = make_client_datasets(docs, cfg, k=args.clients, skew=args.skew,
                                  batch=args.batch_size, seq=args.seq_len,
                                  seed=args.seed)
        batches = ds["batches"]
        if args.max_steps_per_round:
            batches = [b[:args.max_steps_per_round] for b in batches]
        sizes = ds["sizes"]
        print("per-client local steps:", [len(b) for b in batches])
        print("data skew sigmas:", json.dumps(
            {k: round(v["sigma"], 2) for k, v in ds["stats"].items()}))

    params = P.unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
    print(f"params: {sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params)):,}")

    strategy = make_strategy(args.strategy, compress=args.compress,
                             mu=args.mu, beta=args.server_beta,
                             frac=args.topk_frac, alpha=args.async_alpha)
    pspace = None
    if args.param_space:
        from repro.peft import make_param_space
        pspace = make_param_space(
            args.param_space, rank=args.lora_rank, alpha=args.lora_alpha,
            adapter_dim=args.adapter_dim,
            targets=tuple(t for t in args.peft_targets.split(",") if t))
        if pspace.low_rank and args.ffdapt:
            ap.error(f"--param-space {args.param_space} does not compose "
                     f"with --ffdapt (both claim the update mask)")
        if pspace.kind == "frozen_window" and not args.ffdapt:
            ap.error("--param-space frozen_window names the --ffdapt "
                     "schedule — pass --ffdapt (with --gamma/--epsilon) too")
        print(f"param space: {pspace.to_json()}")
    plan = RoundPlan(n_rounds=args.rounds, engine=args.engine,
                     strategy=strategy,
                     cohort_shard=args.cohort_shard or None,
                     param_space=pspace,
                     ffdapt=FFDAPTConfig(epsilon=args.epsilon,
                                         gamma=args.gamma) if args.ffdapt
                     else None,
                     participation=args.participation, seed=args.seed,
                     client_sizes=sizes,
                     simulate=(make_fleet(args.fleet, args.clients,
                                          seed=args.seed,
                                          calibrated=args.calibrated)
                               if args.fleet else None),
                     overlap=args.overlap,
                     checkpoint_dir=args.ckpt_dir or None,
                     checkpoint_every=args.ckpt_every,
                     stop_after_round=args.stop_after or None,
                     # identity the session cannot introspect (optimizer
                     # closures, data pipeline) — a resume under different
                     # values raises instead of silently diverging
                     fingerprint_extra={
                         "arch": cfg.name, "lr": args.lr,
                         "batch": args.batch_size, "seq": args.seq_len,
                         "docs": args.docs, "skew": args.skew,
                         "max_steps": args.max_steps_per_round,
                         "client_pool": args.client_pool,
                         "fleet": args.fleet, "calibrated": args.calibrated,
                         "sim_seed": args.sim_seed})
    shard_note = (f" cohort_shard={args.cohort_shard}"
                  if args.cohort_shard else "")
    print(f"strategy={strategy.name} engine={args.engine} "
          f"participation={args.participation}{shard_note}")
    if args.resume and args.ckpt_dir:
        at = latest_step(args.ckpt_dir)
        print("resume: "
              + (f"round checkpoint {at} found" if at is not None
                 else "no checkpoint on disk, starting fresh"))
    t0 = time.perf_counter()
    with obs.jax_profile(args.jax_profile or None):
        params, hist = FedSession(cfg, optim.adam(args.lr), plan).run(
            params, batches, resume=args.resume)
    wall = time.perf_counter() - t0

    for h in hist:
        w = f" windows={h.windows}" if h.windows else ""
        c = ""
        if h.clients is not None and len(h.clients) < args.clients:
            c = (f" clients={h.clients}" if len(h.clients) <= 32
                 else f" cohort={len(h.clients):,}")
        s = f"  sim {h.sim_round_s:7.1f}s" if args.fleet else ""
        print(f"round {h.round:3d}  loss {h.loss:7.4f}  {h.round_time_s:6.2f}s"
              f"{s}  up {h.upload_bytes / 2**20:7.1f}MB  "
              f"comm {h.comm_bytes / 2**20:7.1f}MB  "
              f"{h.flops_estimate / 1e9:8.2f} GFLOP  "
              f"{h.tokens_per_s:8.0f} tok/s{w}{c}")
    print(f"total {wall:.1f}s; mean round "
          f"{np.mean([h.round_time_s for h in hist]):.2f}s; upload "
          f"{sum(h.upload_bytes for h in hist) / 2**20:.1f}MB; comm "
          f"{sum(h.comm_bytes for h in hist) / 2**20:.1f}MB; compute "
          f"{sum(h.flops_estimate for h in hist) / 1e12:.3f} TFLOP (ledger)")

    if args.fleet:
        from repro.sim import ledger_lines, simulate
        fleet = plan.simulate
        cal = " (calibrated)" if args.calibrated else ""
        print(f"fleet {args.fleet}{cal}: {fleet.counts()}")
        reports = [simulate(hist, fleet, mode="sync", seed=args.sim_seed,
                            overlap=args.overlap)]
        if args.deadline > 0:
            reports.append(simulate(hist, fleet, mode="deadline",
                                    deadline_s=args.deadline,
                                    seed=args.sim_seed,
                                    overlap=args.overlap))
        if args.async_buffer > 0:
            # thread the partition's FULL per-epoch step schedule into the
            # async replay (not the possibly --max-steps-per-round-truncated
            # training schedule): staleness then correlates with client data
            # volume (quantity skew) even on the parallel engine's padded
            # ledger
            reports.append(simulate(hist, fleet, mode="async",
                                    buffer_size=args.async_buffer,
                                    seed=args.sim_seed,
                                    overlap=args.overlap,
                                    client_steps=(ds["steps"] if ds
                                                  else None)))
        for rep in reports:
            print("\n".join(ledger_lines(rep)))
        if args.trace_out:
            # replay the sync report onto the tracer: the simulated
            # timeline lands in its own Perfetto process lane next to the
            # measured rounds
            from repro.sim import emit_spans
            n = emit_spans(reports[0])
            print(f"trace: {n} synthetic sim spans emitted")

    if args.ledger_out:
        # the deterministic ledger: everything a resumed run must reproduce
        # bitwise (wall-clock fields excluded — they measure the host, not
        # the math).  scripts/resume_smoke.sh diffs two of these.
        wall_fields = {"round_time_s", "tokens_per_s"}
        rows = [{k: v for k, v in h.to_json().items()
                 if k not in wall_fields} for h in hist]
        with open(args.ledger_out, "w") as f:
            json.dump({"params_sha256": tree_digest(params), "rounds": rows},
                      f, indent=1, sort_keys=True)
        print("ledger:", args.ledger_out)
        # the stripped wall-clock fields go to a sidecar: the main ledger
        # stays byte-equal across bitwise-equal runs, the timing lives on
        import os
        base, _ = os.path.splitext(args.ledger_out)
        timing_path = base + ".timing.json"
        with open(timing_path, "w") as f:
            json.dump({"total_wall_s": wall,
                       "rounds": [{"round": h.round,
                                   "round_time_s": h.round_time_s,
                                   "tokens_per_s": h.tokens_per_s}
                                  for h in hist]},
                      f, indent=1, sort_keys=True)
        print("timing sidecar:", timing_path)

    stopped_early = args.stop_after and args.stop_after < args.rounds
    if not stopped_early:
        eval_step = jax.jit(make_eval_step(cfg))
        heldout = make_client_datasets(held_docs,
                                       cfg, k=1, batch=args.batch_size,
                                       seq=args.seq_len)["batches"][0][:4]
        losses = [float(eval_step(params, b)["loss"]) for b in heldout]
        print(f"held-out eval loss: {np.mean(losses):.4f}")

    if args.ckpt_dir:
        at = latest_step(args.ckpt_dir)
        print(f"checkpoints: {args.ckpt_dir} (latest round {at})")

    if args.drift_out:
        mon = obs.from_history(
            hist, fleet=plan.simulate, overlap=args.overlap,
            warn_ratio=args.drift_warn,
            tracer=obs.get_tracer() if args.trace_out else None)
        print("\n".join(mon.lines()))
        print("drift ledger:", mon.export(args.drift_out))
    if args.trace_out:
        print("chrome trace:", obs.get_tracer().export(args.trace_out))
    if args.metrics_out:
        print("metrics:", obs.registry().export_jsonl(args.metrics_out))


if __name__ == "__main__":
    main()
