"""Learning-rate schedules (count -> lr, fp32 scalars)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(count):
        return jnp.asarray(lr, jnp.float32)
    return f


def linear_warmup(lr: float, warmup_steps: int):
    def f(count):
        c = count.astype(jnp.float32)
        return lr * jnp.minimum(1.0, c / max(warmup_steps, 1))
    return f


def cosine_decay(lr: float, total_steps: int, warmup_steps: int = 0,
                 min_ratio: float = 0.1):
    def f(count):
        c = count.astype(jnp.float32)
        warm = jnp.minimum(1.0, c / max(warmup_steps, 1)) if warmup_steps else 1.0
        t = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return f
