"""Optimizers from scratch (no optax): Adam / AdamW / SGD.

Interface is optax-shaped: ``state = opt.init(params)``, ``updates, state =
opt.update(grads, state, params)``, ``params = apply_updates(params, updates)``.

``init`` accepts a *boxed* or plain parameter tree.  Given boxes, the returned
moment trees are boxed with the same logical axes — so the sharding layer can
resolve optimizer-state PartitionSpecs identically to the parameters (ZeRO-
style: m/v shard wherever the param shards).  ``state_dtype`` lets the 340B
config keep moments in bf16 (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.nn import param as P

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]     # (grads, state, params)


def _zeros_like_tree(tree, dtype=None):
    def one(b):
        if P.is_box(b):
            v = b.value
            return P.Box(jnp.zeros(v.shape, dtype or v.dtype), b.axes)
        return jnp.zeros(b.shape, dtype or b.dtype)
    return jax.tree.map(one, tree, is_leaf=P.is_box)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda l: l * scale.astype(l.dtype), tree), n


def adam(lr: Schedule = 5e-5, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, state_dtype=None) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0, state_dtype=state_dtype)


def adamw(lr: Schedule = 5e-5, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          state_dtype=None) -> Optimizer:
    """AdamW; the paper's pre-training setup is plain Adam (wd=0), lr 5e-5."""

    def init(params):
        return {
            "m": _zeros_like_tree(params, state_dtype),
            "v": _zeros_like_tree(params, state_dtype),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = _lr_at(lr, count)
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def mom(m, g):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype)

        def vel(v, g):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32).astype(v.dtype)

        m = jax.tree.map(mom, state["m"], grads)
        v = jax.tree.map(vel, state["v"], grads)

        def upd(m_, v_, p):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            u = -lr_t * mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def sgd(lr: Schedule = 1e-2, momentum: float = 0.0) -> Optimizer:
    def init(params):
        st = {"count": jnp.zeros((), jnp.int32)}
        if momentum:
            st["m"] = _zeros_like_tree(params)
        return st

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = _lr_at(lr, count)
        new = {"count": count}
        if momentum:
            m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(m_.dtype),
                             state["m"], grads)
            new["m"] = m
            updates = jax.tree.map(lambda m_: -lr_t * m_.astype(jnp.float32), m)
        else:
            updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, new

    return Optimizer(init, update)
