"""Static compute/comm telemetry: scan-aware roofline analysis of compiled
HLO (:mod:`repro.telemetry.cost` over :mod:`repro.telemetry.hlo`) and cached
per-client-step costs for the federated round ledger
(:mod:`repro.telemetry.step`)."""

from repro.telemetry.cost import (COLLECTIVES, HloStats, analyze,
                                  collective_kind, conv_flops, dot_flops,
                                  multiplicities, op_hbm_bytes,
                                  top_contributors, xla_cost, xla_flops)
from repro.telemetry.hlo import (DTYPE_BYTES, Computation, Op,
                                 cond_trip_count, entry_name, parse_op,
                                 parse_computations, shape_bytes, shape_dims,
                                 trip_count, while_parts)
from repro.telemetry.step import (StepCost, batch_struct, client_step_cost,
                                  client_step_costs, decode_step_cost,
                                  shard_epoch_cost, train_batch_struct)

__all__ = [
    "COLLECTIVES", "DTYPE_BYTES", "Computation", "HloStats", "Op",
    "StepCost", "analyze", "batch_struct", "client_step_cost",
    "client_step_costs",
    "collective_kind", "cond_trip_count", "conv_flops", "decode_step_cost",
    "dot_flops",
    "entry_name", "multiplicities", "op_hbm_bytes", "parse_computations",
    "parse_op", "shape_bytes", "shape_dims", "shard_epoch_cost",
    "top_contributors",
    "train_batch_struct", "trip_count", "while_parts", "xla_cost",
    "xla_flops",
]
