"""Roofline cost rules over parsed HLO: dot FLOPs, HBM bytes, collective
bytes, with while-trip-aware execution multiplicities.

Why not ``compiled.cost_analysis()`` alone: XLA's aggregate cost counts a
while-loop body ONCE, but a scanned L-layer stack executes it L times — the
dominant share of a transformer step.  Unrolling every stack for analysis is
exact but costs 10-30 min of compile per big arch on a 1-core host.  This
module instead propagates execution multiplicity down the computation call
graph (ENTRY=1, while bodies x trip count) and applies per-op rules:

  * ``dot``: 2 x result elements x product(lhs contracting dims) — the
    contracting dims come from the lhs operand's own printed type, so batch
    dims (in the result once) and contracting dims are each counted exactly
    once.  ``convolution``: 2 x result elements x (kernel elements /
    output-feature dim), from ``dim_labels``.
  * HBM traffic: result + operand bytes of every top-level op; fusion
    internals are hidden (a fused TPU executable only reads its operands and
    writes its result); dynamic (update-)slices move the slice, not the
    buffer they index.
  * Collectives: result bytes by kind (ring all-reduce moves ~2x this on the
    wire — callers annotate when they need the wire figure).

Validated against ``cost_analysis`` on fully-unrolled programs
(tests/test_hlo_analysis.py, tests/test_telemetry.py): dot-FLOP totals agree
within a few percent.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.telemetry.hlo import (Computation, Op, called_computations,
                                 entry_name, parse_computations, shape_bytes,
                                 shape_dims, trip_count, while_parts)

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# ops whose "result" is a view/constant/bookkeeping — no HBM traffic
SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "iota", "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


# ---------------------------------------------------------------------------
# Execution multiplicities
# ---------------------------------------------------------------------------

def multiplicities(comps: Dict[str, Computation], entry: str
                   ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Returns ``(flop_mult, byte_mult)`` per computation.

    ``flop_mult`` descends everywhere (dots inside fused computations still
    execute); ``byte_mult`` descends only through control flow
    (while/conditional) — a fusion's internal buffers never touch HBM, only
    the fusion op's own operands/results do (counted at its call site)."""
    flop_mult: Dict[str, float] = {}
    byte_mult: Dict[str, float] = {}

    def visit(name: str, m: float, fused: bool):
        if name not in comps:
            return
        flop_mult[name] = flop_mult.get(name, 0.0) + m
        if not fused:
            byte_mult[name] = byte_mult.get(name, 0.0) + m
        for op in comps[name].ops:
            if op.opcode == "while":
                cond_n, body_n = while_parts(op)
                t = trip_count(op, comps)
                if cond_n in comps:
                    visit(cond_n, m * (t + 1), fused)
                if body_n in comps:
                    visit(body_n, m * t, fused)
            elif op.opcode == "conditional":
                for child in called_computations(op):
                    visit(child, m, fused)
            else:
                for child in called_computations(op):
                    visit(child, m, True)

    visit(entry, 1.0, False)
    return flop_mult, byte_mult


# ---------------------------------------------------------------------------
# Per-op rules
# ---------------------------------------------------------------------------

def _elements(text: str) -> int:
    n = 1
    for d in shape_dims(text):
        n *= d
    return n


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=[\w?]+_([\w?]+)->")
_GROUPS_RE = re.compile(r"feature_group_count=(\d+)")


def dot_flops(op: Op, comp: Computation) -> float:
    """2 x result elements x K.  K = product of the lhs contracting dims
    (each free/batch dim is in the result exactly once, each contracting dim
    exactly once in K)."""
    out_n = _elements(op.result)
    k = 1
    m = _CONTRACT_RE.search(op.rest)
    lhs_dims = shape_dims(comp.operand_type(op, 0))
    if m and lhs_dims:
        for i in m.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                k *= lhs_dims[int(i)]
    return 2.0 * out_n * k


def conv_flops(op: Op, comp: Computation) -> float:
    """2 x result elements x (kernel elements / output features) / groups:
    each output element contracts the kernel's spatial x input-feature dims."""
    out_n = _elements(op.result)
    kdims = shape_dims(comp.operand_type(op, 1))
    if not kdims:
        return 2.0 * out_n
    k = 1
    for d in kdims:
        k *= d
    m = _DIM_LABELS_RE.search(op.rest)
    if m and "o" in m.group(1) and m.group(1).index("o") < len(kdims):
        k //= max(kdims[m.group(1).index("o")], 1)
    g = _GROUPS_RE.search(op.rest)
    if g:
        k //= max(int(g.group(1)), 1)
    return 2.0 * out_n * max(k, 1)


def op_flops(op: Op, comp: Computation) -> float:
    if op.opcode == "dot":
        return dot_flops(op, comp)
    if op.opcode == "convolution":
        return conv_flops(op, comp)
    return 0.0


def op_hbm_bytes(op: Op, comp: Computation,
                 comps: Optional[Dict[str, Computation]] = None) -> float:
    """HBM traffic attributed to one top-level op: operand reads + result
    writes.  Dynamic (update-)slices only move the slice, not the buffer
    they index into — and a fusion whose root is a dynamic-update-slice (a
    scatter loop body: embedding-gradient accumulation) is the same in-place
    update, so it moves the slice too, NOT the whole buffer it rewrites.
    Without that rule an unrolled train step over-counts HBM by ~10x (the
    full embedding table charged once per scatter row)."""
    if op.opcode in SKIP_BYTES:
        return 0.0
    if op.opcode == "dynamic-slice":
        return 2.0 * shape_bytes(op.result)
    if op.opcode == "dynamic-update-slice":
        upd = shape_bytes(comp.operand_type(op, 1))
        return 2.0 * upd
    if op.opcode == "fusion" and comps is not None:
        called = called_computations(op)
        callee = comps.get(called[0]) if called else None
        root = callee.root() if callee is not None else None
        if root is not None and root.opcode == "dynamic-update-slice":
            return 2.0 * shape_bytes(callee.operand_type(root, 1))
    operand_b = sum(shape_bytes(comp.operand_type(op, i))
                    for i in range(len(op.operand_names)))
    return float(shape_bytes(op.result) + operand_b)


def collective_kind(op: Op) -> str:
    """The collective family of an op ("" if not a collective).  ``-start``
    variants count; ``-done`` halves are skipped (same buffer)."""
    for kind in COLLECTIVES:
        if op.opcode == kind or op.opcode == kind + "-start":
            return kind
    return ""


# ---------------------------------------------------------------------------
# Whole-module analysis
# ---------------------------------------------------------------------------

def analyze(hlo: str) -> HloStats:
    comps = parse_computations(hlo)
    entry = entry_name(comps, hlo)
    flop_mult, byte_mult = multiplicities(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    for cname, m in flop_mult.items():
        comp = comps[cname]
        for op in comp.ops:
            flops += m * op_flops(op, comp)
    for cname, m in byte_mult.items():
        comp = comps[cname]
        for op in comp.ops:
            kind = collective_kind(op)
            if kind:
                coll[kind] += m * shape_bytes(op.result)
            hbm += m * op_hbm_bytes(op, comp, comps)
    return HloStats(dot_flops=flops, hbm_bytes=hbm, collective_bytes=coll)


def top_contributors(hlo: str, kind: str = "bytes", n: int = 15
                     ) -> List[Tuple[str, str, str, float, float]]:
    """Diagnosis: the n largest (computation, opcode, result, mult, total)
    contributors to the chosen roofline term (``flops|bytes|collective``)."""
    comps = parse_computations(hlo)
    entry = entry_name(comps, hlo)
    flop_mult, byte_mult = multiplicities(comps, entry)
    rows = []
    mult = flop_mult if kind == "flops" else byte_mult
    for cname, m in mult.items():
        comp = comps[cname]
        for op in comp.ops:
            if kind == "flops":
                f = op_flops(op, comp)
                if f:
                    rows.append((cname, op.opcode, op.result, m, m * f))
            elif kind == "collective":
                if collective_kind(op):
                    rows.append((cname, op.opcode, op.result, m,
                                 m * shape_bytes(op.result)))
            else:
                b = op_hbm_bytes(op, comp, comps)
                if b:
                    rows.append((cname, op.opcode, op.result, m, m * b))
    rows.sort(key=lambda r: -r[-1])
    return rows[:n]


# ---------------------------------------------------------------------------
# XLA cost_analysis normalization
# ---------------------------------------------------------------------------

def xla_cost(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return one dict, newer ones a one-per-partition list."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def xla_flops(compiled) -> float:
    return float(xla_cost(compiled).get("flops", 0.0))
