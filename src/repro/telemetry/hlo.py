"""HLO text parsing: computations, ops, typed operands, while trip counts.

The compiled-module dump (``compiled.as_text()``) is the one artifact every
backend produces; this module turns it into a small object model the cost
rules (:mod:`repro.telemetry.cost`) walk.  Parsing notes that matter for
correctness:

  * Operands are printed WITH their types in full dumps
    (``dot(f32[17,33]{1,0} %Arg_0.1, ...)``), and tuple-typed operands nest
    parentheses (``get-tuple-element((s32[], f32[4]{0}) %arg, ...)``), so the
    operand list must be split with a balanced-delimiter scan — a first-``)``
    split silently drops every operand type, which zeroes both the dot
    contracting dims and the operand HBM bytes.
  * While trip counts come from the op's own
    ``backend_config={"known_trip_count":{"n":...}}`` when the compiler
    recorded one (it does for ``lax.scan``), with the seed heuristic — the
    largest integer constant in the condition computation — as fallback.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
               "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
               "c64": 8, "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(text: str) -> int:
    """Total byte size of every typed shape literal in ``text`` (a result or
    operand type — tuple types sum their elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(text: str) -> List[int]:
    """Dims of the FIRST shape literal in ``text`` ([] for scalars/no match)."""
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    result: str                  # result type text (may be a tuple type)
    opcode: str
    rest: str                    # operand list + attributes after "opcode("
    is_root: bool = False
    operand_names: List[str] = dataclasses.field(default_factory=list)
    operand_types: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    symtab: Dict[str, str] = dataclasses.field(default_factory=dict)

    def operand_type(self, op: Op, i: int) -> str:
        """Type text of operand ``i``: inline type if printed, else symtab."""
        if i >= len(op.operand_names):
            return ""
        return op.operand_types[i] or self.symtab.get(op.operand_names[i], "")

    def root(self) -> Optional[Op]:
        for op in self.ops:
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None


_OPEN = {"(": ")", "[": "]", "{": "}"}
_CLOSE = {")", "]", "}"}


def _balanced_span(text: str, start: int = 0) -> int:
    """Index one past the ``)`` matching the ``(`` at ``text[start]``."""
    depth = 0
    for i in range(start, len(text)):
        ch = text[i]
        if ch in _OPEN:
            depth += 1
        elif ch in _CLOSE:
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def _split_top_commas(text: str) -> List[str]:
    """Split on commas at delimiter depth 0 (layout/tuple commas stay put)."""
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(text):
        if ch in _OPEN:
            depth += 1
        elif ch in _CLOSE:
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(text[start:i])
            start = i + 1
    parts.append(text[start:])
    return [p.strip() for p in parts if p.strip()]


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"^([\w\-]+)\(")


def parse_op(line: str) -> Optional[Op]:
    """One instruction line -> Op, or None for non-instruction lines."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    is_root = line.lstrip().startswith("ROOT ")
    name, rest = m.group(1), line[m.end():]
    if rest.startswith("("):                    # tuple-shaped result
        end = _balanced_span(rest)
        result, rest = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        result, rest = rest[:sp], rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(rest)
    if not m:
        return None
    opcode = m.group(1)
    rest = rest[m.end():]                       # operands..) , attributes
    op = Op(name, result, opcode, rest, is_root)
    # operand list: everything up to the ")" that closes the opcode's "("
    end = _balanced_span("(" + rest) - 1        # index into rest
    for tok in _split_top_commas(rest[:max(end - 1, 0)]):
        # "<type> %name" | "%name" | literal (skipped)
        pct = tok.rfind("%")
        if pct < 0:
            continue
        op.operand_names.append(tok[pct + 1:].strip())
        op.operand_types.append(tok[:pct].strip())
    return op


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers start at column 0, declare "->", end in "{"
            if line and not line[0].isspace() and "->" in line \
                    and line.endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = parse_op(line)
        if op is not None:
            cur.ops.append(op)
            cur.symtab[op.name] = op.result
    return comps


def entry_name(comps: Dict[str, Computation], hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


# ---------------------------------------------------------------------------
# Control flow: called computations and while trip counts
# ---------------------------------------------------------------------------

_CALLED_RE = re.compile(
    r"(?:condition|body|calls|to_apply|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def called_computations(op: Op) -> List[str]:
    names: List[str] = []
    for m in _CALLED_RE.finditer(op.rest):
        for n in m.group(1).split(","):
            names.append(n.strip().lstrip("%"))
    return names


def while_parts(op: Op) -> Tuple[Optional[str], Optional[str]]:
    cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
    body = re.search(r"body=%?([\w.\-]+)", op.rest)
    return (cond.group(1) if cond else None, body.group(1) if body else None)


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_SCALAR_CONST_RE = re.compile(r"^\s*(-?\d+)\s*\)")


def cond_trip_count(cond: Computation) -> int:
    """Fallback heuristic: scan conditions compare the induction variable
    against the scan length — take the largest scalar integer constant."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = _SCALAR_CONST_RE.match(op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    """Trip count of one ``while`` op: the compiler-recorded
    ``known_trip_count`` when present, else the condition-constant heuristic."""
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    cond, _ = while_parts(op)
    if cond in comps:
        return cond_trip_count(comps[cond])
    return 1
