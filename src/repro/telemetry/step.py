"""Per-client-step compute/comm costs, cached per (config, strategy, window).

``client_step_cost`` lowers + compiles the strategy's client train step on
abstract inputs (ShapeDtypeStructs — no allocation) and runs the scan-aware
HLO analyzer over the compiled text.  The result is cached process-wide on
the program's identity — (cfg, optimizer, strategy client-step key, frozen
window, masked, impl, batch shapes) — so a federated session pays one
analysis per distinct compiled program (the same cardinality as the engine's
own step cache), and repeated sessions and benchmarks pay zero.

Estimates are static: they describe the compiled program, not a measured
run.  On CPU/interpret hosts the numbers are per-(single-)device; on a real
sharded mesh they are per-device terms of the partitioned program.

Cost note: this is a SECOND compile of the engine's program family — jax
exposes no way to read the HLO text back out of a jitted function's own
executable cache, and ``jit(f).lower().compile()`` does not pre-populate it.
The price is one extra compile per (cfg, strategy, window, impl) family,
amortized across every round, session, and benchmark in the process;
``RoundPlan(telemetry=False)`` skips it for compile-time-sensitive sweeps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.telemetry.cost import analyze


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Roofline terms of ONE compiled client train step."""

    flops: float                      # analyzer dot/conv FLOPs
    hbm_bytes: float                  # analyzer HBM traffic
    collective_bytes: float           # intra-program collective result bytes


def train_batch_struct(cfg, batch: int, seq: int) -> Dict[str, Any]:
    """Abstract train batch for any arch in the zoo (mirrors the concrete
    batches ``repro.core.noniid`` builds)."""
    ids = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    out = {"tokens": ids, "targets": ids,
           "loss_mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32)}
    if cfg.arch_type == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.arch_type == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    return out


def batch_struct(batch: Dict[str, Any]) -> Dict[str, Any]:
    """Concrete batch -> abstract template (shape/dtype only)."""
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(jnp.shape(l),
                                                       jnp.asarray(l).dtype),
                        batch)


def _batch_key(batch_sds: Dict[str, Any]) -> Tuple:
    leaves, treedef = jax.tree.flatten(batch_sds)
    return (str(treedef),) + tuple((l.shape, str(l.dtype)) for l in leaves)


_COST_CACHE: Dict[Tuple, StepCost] = {}


def client_step_cost(cfg, optimizer, strategy, batch_sds: Dict[str, Any], *,
                     frozen: Optional[Tuple[bool, ...]] = None,
                     masked: bool = False, impl: str = "xla",
                     space=None) -> StepCost:
    """Analyze (cached) the compiled client step a round engine would run.

    ``frozen``/``masked``/``impl``/``space`` mirror
    ``strategy.make_client_step``; the cache key holds strong refs to
    cfg/optimizer (same discipline as the engines' step cache — an
    id()-keyed entry could alias after GC).  A low-rank ``space`` prices the
    PEFT step: optimizer state over the bank, the base as a frozen input —
    the merged forward costs the same dot FLOPs but the backward dW shrinks
    to the bank's factors."""
    key = (cfg, optimizer, strategy.client_step_key(), strategy.needs_anchor,
           frozen, masked, impl, space, _batch_key(batch_sds))
    if key in _COST_CACHE:
        return _COST_CACHE[key]

    from repro.models.steps import abstract_train_state
    params_sds, opt_sds = abstract_train_state(cfg, optimizer)
    peft = space is not None and space.low_rank
    if peft:
        bank_sds = jax.eval_shape(
            lambda p: space.inject(p, jax.random.PRNGKey(0)), params_sds)
        opt_sds = jax.eval_shape(optimizer.init, bank_sds)
        step = strategy.make_client_step(cfg, optimizer, impl=impl,
                                         space=space)
        args = [bank_sds, opt_sds, params_sds]
        if strategy.needs_anchor:
            args.append(bank_sds)
        args.append(batch_sds)
    else:
        step = strategy.make_client_step(cfg, optimizer, frozen=frozen,
                                         masked=masked, impl=impl)
        args = [params_sds, opt_sds]
        if strategy.needs_anchor:
            args.append(params_sds)
        args.append(batch_sds)
        if masked:
            from repro.models.model import n_freeze_units
            args.append(jax.ShapeDtypeStruct((n_freeze_units(cfg),),
                                             jnp.float32))
    compiled = jax.jit(step).lower(*args).compile()
    stats = analyze(compiled.as_text())
    cost = StepCost(flops=float(stats.dot_flops),
                    hbm_bytes=float(stats.hbm_bytes),
                    collective_bytes=float(stats.collective_total))
    _COST_CACHE[key] = cost
    return cost


def shard_epoch_cost(cfg, optimizer, strategy, batch_sds: Dict[str, Any], *,
                     shard: int, steps: int, masked: bool = False,
                     impl: str = "xla") -> StepCost:
    """Analyze ONE compiled cohort-scan shard program (cached): ``shard``
    clients vmapped, ``steps`` local steps scanned per client, plus the
    streaming aggregation fold into the round carry — the exact program
    family ``FedSession``'s parallel engine runs per shard.

    The scan-aware analyzer multiplies every loop body by its trip count,
    so the result prices the WHOLE shard epoch: the compute terms land at
    ``shard x steps x client_step_cost`` (plus the O(params) fold, which is
    FLOP-free under the dot/conv metric) — the multiplicity identity
    tests/test_cohort.py pins, and the reason the round ledger may price a
    cohort as ``n_steps x client_step_cost`` regardless of shard size."""
    key = ("shard_epoch", cfg, optimizer, strategy.client_step_key(),
           strategy.needs_anchor, shard, steps, masked, impl,
           _batch_key(batch_sds))
    if key in _COST_CACHE:
        return _COST_CACHE[key]

    from repro.core.fedavg import broadcast_clients, scalar_fold
    from repro.models.model import n_freeze_units
    from repro.models.steps import abstract_train_state
    from repro.nn import param as P

    params_sds, _ = abstract_train_state(cfg, optimizer)
    step = strategy.make_client_step(cfg, optimizer, masked=masked, impl=impl)
    needs_anchor = strategy.needs_anchor

    bsub = jax.tree.map(lambda l: jax.ShapeDtypeStruct(
        (shard, steps) + l.shape, l.dtype), batch_sds)
    fm_sds = jax.ShapeDtypeStruct((shard, n_freeze_units(cfg)), jnp.float32)
    w_sds = jax.ShapeDtypeStruct((shard,), jnp.float32)
    partial_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params_sds)
    sc = jax.ShapeDtypeStruct((), jnp.float32)

    def shard_epoch(gp, partial, loss_acc, tok_acc, bs_all, fmasks,
                    w_agg, w_loss):
        stacked = broadcast_clients(gp, shard)
        opts = jax.vmap(lambda p: P.unbox(optimizer.init(p)))(stacked)

        def client_epoch(p, o, bs, fm):
            def one(carry, b):
                p_, o_ = carry
                args = (p_, o_)
                if needs_anchor:
                    args += (gp,)
                args += (b,)
                if masked:
                    args += (fm,)
                p_, o_, m = step(*args)
                return (p_, o_), (m["loss"], m["tokens"])

            (p, o), (ls, toks) = jax.lax.scan(one, (p, o), bs)
            return p, jnp.mean(ls), jnp.sum(toks)

        p_k, losses, toks = jax.vmap(client_epoch)(stacked, opts, bs_all,
                                                   fmasks)
        partial = strategy.aggregate_partial(gp, p_k, w_agg, partial)
        return (partial, scalar_fold(loss_acc, losses * w_loss),
                scalar_fold(tok_acc, toks))

    compiled = jax.jit(shard_epoch).lower(
        params_sds, partial_sds, sc, sc, bsub, fm_sds, w_sds, w_sds).compile()
    stats = analyze(compiled.as_text())
    cost = StepCost(flops=float(stats.dot_flops),
                    hbm_bytes=float(stats.hbm_bytes),
                    collective_bytes=float(stats.collective_total))
    _COST_CACHE[key] = cost
    return cost


def decode_step_cost(cfg, n_slots: int, cache_len: int, *,
                     impl: str = "xla") -> StepCost:
    """Analyze (cached) ONE slot-vmapped decode step — the exact program
    family ``DecodeEngine``'s fused kernel dispatches per token, minus the
    sampling epilogue (elementwise + argmax: FLOP-free under the dot/conv
    metric and a rounding error in HBM terms).  The serve driver prices a
    decode step on a device roofline from this for its drift monitor."""
    key = ("decode_step", cfg, n_slots, cache_len, impl)
    if key in _COST_CACHE:
        return _COST_CACHE[key]

    from repro.models.model import cache_struct, init_model
    from repro.models.steps import make_slot_serve_step
    from repro.nn import param as P

    vserve = make_slot_serve_step(cfg, impl=impl)
    struct = cache_struct(cfg, 1, cache_len)
    pool_sds = jax.tree.map(
        lambda b: jax.ShapeDtypeStruct((n_slots,) + b.value.shape,
                                       b.value.dtype),
        struct, is_leaf=P.is_box)
    toks_sds = jax.ShapeDtypeStruct((n_slots, 1, 1), jnp.int32)
    params_sds = jax.eval_shape(
        lambda k: P.unbox(init_model(k, cfg)), jax.random.PRNGKey(0))
    compiled = jax.jit(
        lambda p, t, pool: vserve(p, {"tokens": t}, pool)).lower(
            params_sds, toks_sds, pool_sds).compile()
    stats = analyze(compiled.as_text())
    cost = StepCost(flops=float(stats.dot_flops),
                    hbm_bytes=float(stats.hbm_bytes),
                    collective_bytes=float(stats.collective_total))
    _COST_CACHE[key] = cost
    return cost


def client_step_costs(cfg, optimizer, strategy,
                      batch_sds_list: Sequence[Dict[str, Any]], *,
                      frozen_list: Optional[Sequence[Optional[Tuple[bool, ...]]]] = None,
                      masked: bool = False, impl: str = "xla"
                      ) -> List[StepCost]:
    """Per-client costs for ONE federated round: element i is the cost of
    client i's step under its freeze window.  Pure cache fan-out — an FFDAPT
    rotation reuses at most N distinct windows, so a whole session's
    (round x client) matrix resolves to at most N analyses (the round
    engines and ``benchmarks/wallclock.py`` both feed the simulator through
    this)."""
    frozen_list = (list(frozen_list) if frozen_list is not None
                   else [None] * len(batch_sds_list))
    if len(frozen_list) != len(batch_sds_list):
        raise ValueError(f"{len(frozen_list)} windows for "
                         f"{len(batch_sds_list)} clients")
    return [client_step_cost(cfg, optimizer, strategy, sds, frozen=fr,
                             masked=masked, impl=impl)
            for sds, fr in zip(batch_sds_list, frozen_list)]
