from repro.checkpoint.npz import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, FederatedState)
