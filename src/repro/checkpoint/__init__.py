from repro.checkpoint.npz import (  # noqa: F401
    save_checkpoint, restore_checkpoint, restore_extra, latest_step,
    archive_keys, tree_digest, FederatedState)
