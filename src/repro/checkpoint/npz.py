"""Pytree checkpointing to .npz (offline stand-in for orbax/tensorstore).

Trees are flattened to ``path/to/leaf`` keys; restore rebuilds against a
template tree (structure is authoritative from the template, values from the
archive).  ``FederatedState`` wraps the full FDAPT run state — global params,
round counter, and the FFDAPT pointer — so a federated run resumes
mid-schedule.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":       # npz has no native bf16
            key, arr = key + "::bf16", arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None,
                    *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **_flatten(tree))
    if extra is not None:
        with open(path.replace(".npz", ".json"), "w") as f:
            json.dump(extra, f)
    _rotate(directory, keep)
    return path


def _rotate(directory: str, keep: int) -> None:
    ckpts = sorted(f for f in os.listdir(directory)
                   if re.fullmatch(r"ckpt_\d+\.npz", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        meta = os.path.join(directory, old.replace(".npz", ".json"))
        if os.path.exists(meta):
            os.remove(meta)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, template: Any) -> Any:
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_paths:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key + "::bf16" in flat:
            import ml_dtypes
            arr = flat[key + "::bf16"].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_extra(directory: str, step: int) -> Optional[Dict[str, Any]]:
    path = os.path.join(directory, f"ckpt_{step:08d}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


@dataclasses.dataclass
class FederatedState:
    """Resumable FDAPT state: round counter + FFDAPT rotation pointer."""
    round: int = 0
    ffdapt_start: int = 0

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FederatedState":
        return cls(**d)
