"""Pytree checkpointing to .npz (offline stand-in for orbax/tensorstore).

Trees are flattened to ``path/to/leaf`` keys; restore rebuilds against a
template tree (structure is authoritative from the template, values from the
archive).  Arrays round-trip BITWISE: float leaves are stored as their exact
bytes (bf16 via a uint16 view) and ``restore_checkpoint`` casts back to the
template dtype, which is the identity when dtypes match.

``FederatedState`` wraps the full FDAPT run state the round engines need to
resume mid-schedule: the next round to run, the FFDAPT rotation pointer at
that round, the client-sampling ``numpy.random.Generator`` bit-state, the
serialized ``RoundResult`` history (losses, ledgers, client selections — so
post-hoc ``repro.sim`` replays survive restarts), and a plan fingerprint the
resume path verifies.  The array side of the run state — global params plus
the strategy's server-state pytree (``FederatedStrategy.state_to_tree``) —
rides in the same ``save_checkpoint`` archive; ``FederatedState`` is its
``extra`` JSON sidecar.  ``FedSession.run(..., resume=True)``
(``repro.core.rounds``) writes and consumes both: a run killed after round r
and resumed is bitwise identical to the uninterrupted run.

Low-rank ``RoundPlan.param_space`` runs (repro.peft) extend the contract
without new machinery: the archive's ``params`` subtree becomes
``{"base": <frozen base model>, "peft": <adapter bank>}`` (leaf keys
``params|base|...`` / ``params|peft|...|a``), the server state is the
strategy's state over the BANK, and the sidecar plan fingerprint carries a
``param_space`` entry — resume and ``serve/loader.py`` both key on it, so
a rank-4 LoRA archive can neither resume as rank-8 nor serve unmerged.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":       # npz has no native bf16
            key, arr = key + "::bf16", arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None,
                    *, keep: int = 3) -> str:
    """Write one checkpoint ATOMICALLY: both files land under temp names
    and are renamed into place, sidecar first, archive last.  The archive
    is what ``latest_step`` keys on, so a preemption at any instant leaves
    either the complete new checkpoint or no trace of it — a visible
    ``ckpt_N.npz`` always has its full contents and its sidecar, and
    ``resume`` can never pick up a torn write."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten(tree))
    if extra is not None:
        meta, mtmp = path.replace(".npz", ".json"), path + ".json.tmp"
        with open(mtmp, "w") as f:
            json.dump(extra, f)
        os.replace(mtmp, meta)
    os.replace(tmp, path)
    _rotate(directory, keep)
    return path


def _rotate(directory: str, keep: int) -> None:
    names = set(os.listdir(directory))
    # debris from preempted saves: temp files were never renamed into
    # place, and an orphan sidecar means the archive rename never happened
    ckpts = sorted(f for f in names if re.fullmatch(r"ckpt_\d+\.npz", f))
    for f in names:
        stray = (f.startswith("ckpt_") and f.endswith(".tmp")) or (
            re.fullmatch(r"ckpt_\d+\.json", f)
            and f.replace(".json", ".npz") not in ckpts)
        if stray:
            os.remove(os.path.join(directory, f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        meta = os.path.join(directory, old.replace(".npz", ".json"))
        if os.path.exists(meta):
            os.remove(meta)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, template: Any) -> Any:
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_paths:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key + "::bf16" in flat:
            import ml_dtypes
            arr = flat[key + "::bf16"].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def archive_keys(directory: str, step: int) -> List[str]:
    """Flat leaf keys stored in a checkpoint archive (``::bf16`` markers
    stripped).  Lets a reader discover the archive's layout — e.g. whether
    params live under a ``params|`` prefix (a ``FedSession`` round
    checkpoint) or at the root (a bare params snapshot) — without loading
    any array data."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        return [k[:-len("::bf16")] if k.endswith("::bf16") else k
                for k in data.files]


def restore_extra(directory: str, step: int) -> Optional[Dict[str, Any]]:
    path = os.path.join(directory, f"ckpt_{step:08d}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def tree_digest(tree: Any) -> str:
    """sha256 over the flattened tree (keys + raw leaf bytes): a cheap
    BITWISE fingerprint.  Two trees digest equal iff every leaf is
    byte-identical — the resume smoke diffs final params through this."""
    h = hashlib.sha256()
    flat = _flatten(tree)
    for key in sorted(flat):
        h.update(key.encode())
        h.update(np.ascontiguousarray(flat[key]).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class FederatedState:
    """Resumable FDAPT run state (the ``extra`` sidecar of a round
    checkpoint; see the module docstring).

    ``round`` is the NEXT round to run (r+1 after round r completed);
    ``ffdapt_start`` the rotation pointer at that round (0 without FFDAPT —
    the resume path re-derives the schedule and verifies the pointer
    matches); ``rng_state`` the client-sampling Generator's
    ``bit_generator.state`` dict captured AFTER round r's participation
    draw, so a resumed ``participation < 1`` run samples the exact clients
    the uninterrupted run would; ``history`` the serialized
    ``RoundResult.to_json()`` rounds so far; ``plan`` a fingerprint
    guarding against resuming under a different plan — the resume path
    raises on a mismatch of strategy (including its hyperparameters),
    engine, seed, participation, ffdapt config, or client sizes, while
    ``n_rounds`` is recorded for information only (resuming with a larger
    ``n_rounds`` legitimately extends the run).  JSON round-trips exactly
    (``from_json`` ignores unknown keys, so old two-field sidecars still
    load)."""

    round: int = 0
    ffdapt_start: int = 0
    rng_state: Optional[Dict[str, Any]] = None
    history: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    plan: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FederatedState":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})
