"""Low-overhead span tracer with Chrome trace-event export.

A ``Tracer`` records SPANS — named, nested intervals of host wall-clock —
into a fixed-capacity thread-safe ring buffer, and exports them as Chrome
trace-event JSON (the ``{"traceEvents": [...]}`` format Perfetto and
``chrome://tracing`` load directly).  Three kinds of event:

  * measured spans — ``with tracer.span("train.dispatch", round=t): ...``
    (or the ``@traced`` decorator).  Timestamps come from
    ``time.perf_counter_ns`` (monotonic; immune to wall-clock steps) and
    are exported relative to the tracer's epoch, one track per thread.
  * instants — ``tracer.instant("train.compile")`` marks a point in time
    (trace-time events like a shard-program compile).
  * synthetic spans — ``tracer.add_span(name, ts_s=..., dur_s=...)``
    places a span at EXPLICIT seconds on a separate process track.  The
    simulator replays its per-client ``ClientTiming`` phases through this
    (``repro.sim.events.emit_spans``), so a simulated round renders next
    to the measured one in a single Perfetto timeline.

Cost discipline: the module-level default tracer starts DISABLED, and a
disabled tracer's ``span()`` returns one shared no-op singleton — no
allocation, no clock read, one attribute check — so the round/decode hot
paths can stay instrumented unconditionally.  Enabled, each span costs two
monotonic clock reads and one locked ring-buffer append.

The ring keeps the newest ``capacity`` events and counts what it dropped
(``tracer.dropped``) — a long session degrades to "most recent window",
never to unbounded memory.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

# Chrome trace "pid" lanes: measured events vs synthetic (simulated) events
# render as two named processes in one timeline.
PID_MEASURED = 1
PID_SIM = 2


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One recorded event.  ``ts_us``/``dur_us`` are microseconds relative
    to the tracer's epoch; ``phase`` is the Chrome event phase ("X" =
    complete span, "i" = instant)."""

    name: str
    cat: str
    ts_us: float
    dur_us: float
    pid: int
    tid: int
    phase: str = "X"
    args: Optional[Dict[str, Any]] = None


class _NullSpan:
    """The disabled-tracer fast path: one process-wide singleton, so a
    disabled ``span()`` call allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """A live span handle (context manager).  Start/stop read
    ``perf_counter_ns``; the finished event is appended on ``__exit__``."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._t0 = 0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        self._tracer._append(SpanEvent(
            name=self._name, cat=self._cat,
            ts_us=(self._t0 - self._tracer._epoch_ns) / 1e3,
            dur_us=(t1 - self._t0) / 1e3,
            pid=PID_MEASURED, tid=threading.get_ident() & 0xFFFF,
            args=self._args))
        return False


class Tracer:
    """Thread-safe ring buffer of trace events.

    ``enabled=False`` (the default for the process-wide tracer) makes every
    recording call a no-op returning shared singletons; flipping
    ``enabled`` needs no re-instrumentation of call sites.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity {capacity} < 1")
        self.enabled = enabled
        self._capacity = capacity
        self._lock = threading.Lock()
        self._buf: List[Optional[SpanEvent]] = [None] * capacity
        self._n = 0                     # total events ever appended
        self._epoch_ns = time.perf_counter_ns()

    # -- recording ------------------------------------------------------

    def span(self, name: str, cat: str = "", **args):
        """Context manager timing a measured span.  Disabled: returns the
        shared no-op singleton (zero allocation)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Mark a point event at 'now' (e.g. a compile at trace time)."""
        if not self.enabled:
            return
        self._append(SpanEvent(
            name=name, cat=cat,
            ts_us=(time.perf_counter_ns() - self._epoch_ns) / 1e3,
            dur_us=0.0, pid=PID_MEASURED,
            tid=threading.get_ident() & 0xFFFF, phase="i",
            args=args or None))

    def add_span(self, name: str, *, ts_s: float, dur_s: float,
                 cat: str = "", pid: int = PID_SIM, tid: int = 0,
                 **args) -> None:
        """Record a SYNTHETIC span at explicit times (seconds).  Used by
        the simulator's replay; lands on the ``pid`` process track so
        synthetic and measured timelines stay visually separate."""
        if not self.enabled:
            return
        self._append(SpanEvent(
            name=name, cat=cat, ts_us=ts_s * 1e6, dur_us=dur_s * 1e6,
            pid=pid, tid=tid, args=args or None))

    def traced(self, name: Optional[str] = None, cat: str = ""):
        """Decorator form: ``@tracer.traced("phase")``."""

        def deco(fn: Callable) -> Callable:
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                if not self.enabled:
                    return fn(*a, **kw)
                with self.span(label, cat=cat):
                    return fn(*a, **kw)

            return wrapper

        return deco

    def _append(self, ev: SpanEvent) -> None:
        with self._lock:
            self._buf[self._n % self._capacity] = ev
            self._n += 1

    # -- inspection / export -------------------------------------------

    @property
    def dropped(self) -> int:
        """Events lost to ring overflow (oldest-first)."""
        return max(0, self._n - self._capacity)

    def __len__(self) -> int:
        return min(self._n, self._capacity)

    def events(self) -> List[SpanEvent]:
        """Surviving events, oldest first."""
        with self._lock:
            n, cap = self._n, self._capacity
            if n <= cap:
                return [e for e in self._buf[:n] if e is not None]
            head = n % cap
            return [e for e in self._buf[head:] + self._buf[:head]
                    if e is not None]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self._capacity
            self._n = 0
            self._epoch_ns = time.perf_counter_ns()

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (Perfetto-loadable):
        ``traceEvents`` carries one dict per event plus process-name
        metadata separating the measured and simulated tracks."""
        events: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": PID_MEASURED,
             "tid": 0, "args": {"name": "measured"}},
            {"ph": "M", "name": "process_name", "pid": PID_SIM,
             "tid": 0, "args": {"name": "simulated"}},
        ]
        for e in self.events():
            d: Dict[str, Any] = {"name": e.name, "cat": e.cat or "default",
                                 "ph": e.phase, "ts": e.ts_us,
                                 "pid": e.pid, "tid": e.tid}
            if e.phase == "X":
                d["dur"] = e.dur_us
            if e.phase == "i":
                d["s"] = "t"          # instant scope: thread
            if e.args:
                d["args"] = e.args
            events.append(d)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (dirs created)."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# Process-wide default tracer
# ---------------------------------------------------------------------------

# Disabled by default: the instrumented hot paths (rounds, serve, sim) pay
# one attribute check per call site until someone opts in via enable().
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-wide tracer every instrumented call site records into."""
    return _TRACER


def enable(capacity: int = 65536) -> Tracer:
    """Turn the process-wide tracer on (resetting its buffer) and return
    it.  The singleton object never changes identity, so references taken
    before ``enable()`` stay valid."""
    _TRACER._capacity = capacity
    _TRACER.clear()
    _TRACER.enabled = True
    return _TRACER


def disable() -> Tracer:
    """Turn the process-wide tracer off (events are kept for export)."""
    _TRACER.enabled = False
    return _TRACER


def span(name: str, cat: str = "", **args):
    """Module-level convenience: a span on the process-wide tracer."""
    if not _TRACER.enabled:
        return NULL_SPAN
    return _TRACER.span(name, cat=cat, **args)


def instant(name: str, cat: str = "", **args) -> None:
    """Module-level convenience: an instant on the process-wide tracer."""
    if _TRACER.enabled:
        _TRACER.instant(name, cat=cat, **args)


def traced(name: Optional[str] = None, cat: str = ""):
    """Decorator on the process-wide tracer (resolves ``enabled`` at CALL
    time, so decorating at import cost nothing until someone enables)."""

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _TRACER.enabled:
                return fn(*a, **kw)
            with _TRACER.span(label, cat=cat):
                return fn(*a, **kw)

        return wrapper

    return deco
