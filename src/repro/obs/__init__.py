"""repro.obs: observability — span tracing, metrics, drift monitoring.

The measurement counterpart of the repo's three predictors (analytic
telemetry, wall-clock simulator, calibrated presets):

  * :mod:`repro.obs.trace`   — low-overhead span tracer (context-manager +
    decorator API, monotonic clocks, thread-safe ring buffer, no-op when
    disabled) with Chrome trace-event JSON export (Perfetto-loadable);
    synthetic spans let the simulator replay onto the same timeline.
  * :mod:`repro.obs.metrics` — process-wide registry of counters / gauges
    / histograms with exact, version-pinned quantiles and JSONL export.
  * :mod:`repro.obs.drift`   — per-round measured-vs-predicted ratio
    ledger with configurable warn thresholds (the regression oracle every
    perf PR checks against).
  * :mod:`repro.obs.profile` — opt-in ``jax.profiler`` traces and
    compile-event capture onto the tracer.

The process-wide tracer starts DISABLED: instrumented hot paths
(``core/rounds.py``, ``serve/engine.py``, ``sim/events.py``) pay one
attribute check until a driver opts in (``--trace-out`` or
``repro.obs.enable()``).
"""

from repro.obs.drift import (DriftMonitor, DriftRecord, from_history,
                             measured_round_s, predicted_round_s)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               load_jsonl, quantile, registry, summary_stats)
from repro.obs.profile import capture_compiles, jax_profile, record_compile
from repro.obs.trace import (NULL_SPAN, PID_MEASURED, PID_SIM, SpanEvent,
                             Tracer, disable, enable, get_tracer, instant,
                             span, traced)

__all__ = [
    "Counter", "DriftMonitor", "DriftRecord", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_SPAN", "PID_MEASURED", "PID_SIM", "SpanEvent",
    "Tracer", "capture_compiles", "disable", "enable", "from_history",
    "get_tracer", "instant", "jax_profile", "load_jsonl",
    "measured_round_s", "predicted_round_s", "quantile", "record_compile",
    "registry", "span", "summary_stats", "traced",
]
