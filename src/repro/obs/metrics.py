"""Process-wide metrics: counters, gauges, histograms, exact quantiles.

``MetricsRegistry`` is a named, typed bag of metrics with a thread-safe
get-or-create API (``registry().counter("train.rounds").inc()``); a
process-wide default registry backs the ``--metrics-out`` flags, and
subsystems that need isolated accounting (e.g. one ``ServeMetrics`` per
engine in a parity test) construct their own.

Quantiles are EXACT and version-pinned: ``quantile`` implements linear
interpolation between closest ranks (``h = (n-1)q``) in pure Python —
the method numpy calls ``"linear"`` — so p50/p99 summaries cannot drift
when numpy changes its default interpolation across versions (it did:
the ``interpolation=`` -> ``method=`` migration).  ``summary_stats`` is
the single mean/p50/p99 rule; ``repro.serve.metrics.percentiles``
delegates here, which is what makes ``BENCH_serve.json`` percentile
fields reproducible bit-for-bit on any numpy.

Export is JSONL — one metric per line, sorted by name, deterministic —
so two identical runs produce byte-identical files and downstream tools
can stream-parse.

>>> quantile([1.0, 2.0, 3.0, 4.0], 0.5)
2.5
>>> quantile([1.0, 2.0, 3.0, 4.0, 5.0], 0.25)
2.0
>>> summary_stats([3.0, 1.0, 2.0])["p50"]
2.0
"""

from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Dict, Iterable, List, Optional, Union

Number = Union[int, float]


def quantile(xs: Iterable[Number], q: float) -> float:
    """Exact q-quantile (0 <= q <= 1) by linear interpolation between
    closest ranks: ``h = (n-1) q``, result = ``s[floor(h)] + frac(h) *
    (s[ceil(h)] - s[floor(h)])`` over the sorted values.  Pure Python on
    purpose — pinned against numpy method changes.  Empty input -> 0.0.

    >>> quantile([], 0.5)
    0.0
    >>> quantile([7.0], 0.99)
    7.0
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q {q} not in [0, 1]")
    s = sorted(float(x) for x in xs)
    if not s:
        return 0.0
    h = (len(s) - 1) * q
    lo = math.floor(h)
    hi = math.ceil(h)
    if lo == hi:
        return s[lo]
    return s[lo] + (s[hi] - s[lo]) * (h - lo)


def summary_stats(xs: Iterable[Number]) -> Dict[str, float]:
    """The repo's one mean/p50/p99 rule (BENCH files, serve metrics,
    histogram summaries all come through here)."""
    vals = [float(x) for x in xs]
    if not vals:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0}
    return {"mean": math.fsum(vals) / len(vals),
            "p50": quantile(vals, 0.50),
            "p99": quantile(vals, 0.99)}


class Counter:
    """Monotonically increasing count (events, tokens, bytes)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": self.kind, "value": self._value}


class Gauge:
    """Last-written value (occupancy, queue depth, drift ratio)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: Number) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": self.kind, "value": self._value}


class Histogram:
    """Value distribution with exact-quantile summaries.  Keeps every
    observation (host floats — thousands of samples, not millions); the
    summary computes min/max/mean and pinned p50/p90/p99."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: List[float] = []
        self._lock = threading.Lock()

    def observe(self, v: Number) -> None:
        with self._lock:
            self._values.append(float(v))

    @property
    def count(self) -> int:
        return len(self._values)

    @property
    def sum(self) -> float:
        return math.fsum(self._values)

    def values(self) -> List[float]:
        with self._lock:
            return list(self._values)

    def summary(self) -> Dict[str, float]:
        vals = self.values()
        if not vals:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"count": len(vals), "sum": math.fsum(vals),
                "min": min(vals), "max": max(vals),
                "mean": math.fsum(vals) / len(vals),
                "p50": quantile(vals, 0.50), "p90": quantile(vals, 0.90),
                "p99": quantile(vals, 0.99)}

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": self.kind, **self.summary()}


class MetricsRegistry:
    """Named, typed metric store.  Get-or-create semantics; re-requesting
    a name under a different type raises instead of silently shadowing."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """{name: metric JSON} for every registered metric (sorted)."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.to_json() for name, m in items}

    def export_jsonl(self, path: str) -> str:
        """One JSON object per line, sorted by metric name, trailing
        newline — byte-identical across identical runs."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for _, payload in sorted(self.snapshot().items()):
                f.write(json.dumps(payload, sort_keys=True) + "\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._metrics = {}


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry (``--metrics-out`` exports it)."""
    return _REGISTRY


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a metrics JSONL file back into its per-metric dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
