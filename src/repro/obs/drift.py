"""Measured-vs-predicted drift monitor: does reality match the model?

The repo predicts a round three independent ways — the analytic roofline
ledger (``repro.telemetry`` FLOPs/HBM/comm per compiled step), the
simulated wall-clock (``repro.sim.clock``/``repro.sim.events``), and the
calibrated presets — but a prediction nobody checks rots silently.  The
``DriftMonitor`` closes the loop per round: it joins a MEASURED duration
(a tracer span, or the ``RoundResult.round_time_s`` the engines record)
against a PREDICTED duration and banks the ratio in a ledger.

    monitor = DriftMonitor(warn_ratio=4.0)
    for rr in history:
        monitor.observe_round(rr, fleet=plan.simulate)
    monitor.export("drift.json"); monitor.warnings()

Prediction sources, in precedence order (``predicted_round_s``):

  1. ``fleet`` — ``repro.sim.clock.sync_round_s`` on that fleet (the
     slowest sampled client under the roofline clock);
  2. the round's recorded ``sim_round_s`` (a live ``RoundPlan.simulate``
     hook already priced it);
  3. ``device`` — a single ``DeviceProfile`` (or preset name): roofline
     seconds of the round's ledger totals on that device.

Ratios are ``measured / predicted``: 1.0 means the model nails reality,
a drifting ratio means either the machine changed (regression!) or the
model is mis-calibrated — both worth a warning.  The WARN rule is
symmetric in log-space: a row warns when ``ratio > warn_ratio`` or
``ratio < 1 / warn_ratio``.  A non-positive prediction yields
``ratio=None`` and warns (the model failed to price the round at all).

Every observed ratio also lands in the metrics registry (histogram
``drift.<phase>.ratio``), so ``--metrics-out`` carries the drift summary
even without the full ledger file.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.metrics import MetricsRegistry, registry as _default_registry


@dataclasses.dataclass(frozen=True)
class DriftRecord:
    """One measured-vs-predicted join.  Seconds on both sides;
    ``ratio = measured_s / predicted_s`` (None when the prediction is
    non-positive); ``warn`` applies the monitor's symmetric threshold."""

    round: int
    phase: str
    measured_s: float
    predicted_s: float
    ratio: Optional[float]
    warn: bool
    source: str = ""               # which predictor priced this row

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _resolve_device(device: Any):
    """A DeviceProfile, or a preset name from ``repro.sim.fleet``."""
    if isinstance(device, str):
        from repro.sim.fleet import PRESETS
        if device not in PRESETS:
            raise ValueError(
                f"unknown device preset {device!r} (one of {sorted(PRESETS)})")
        return PRESETS[device]
    return device


def predicted_round_s(rr: Any, *, fleet: Any = None, device: Any = None,
                      overlap: bool = False) -> tuple:
    """Price one round record -> ``(seconds, source)`` using the best
    available predictor (fleet clock > recorded sim_round_s > single-device
    roofline).  ``rr`` is duck-typed like the sim replays (a ``RoundResult``
    or its serialized dict)."""
    from repro.sim.clock import device_roofline_s, record_field, sync_round_s
    if fleet is not None:
        return float(sync_round_s(rr, fleet, overlap=overlap)), "fleet"
    sim_s = float(record_field(rr, "sim_round_s", 0.0) or 0.0)
    if sim_s > 0.0:
        return sim_s, "sim_round_s"
    if device is not None:
        dev = _resolve_device(device)
        terms = device_roofline_s(
            float(record_field(rr, "flops_estimate", 0.0) or 0.0),
            float(record_field(rr, "hbm_bytes_estimate", 0.0) or 0.0),
            float(record_field(rr, "comm_bytes", 0) or 0), dev)
        return (max(terms["compute"], terms["memory"])
                + terms["collective"]), f"device:{dev.name}"
    return 0.0, "none"


def measured_round_s(rr: Any, tracer: Any = None) -> float:
    """The round's measured seconds: the tracer's ``train.round`` span for
    this round when one exists (span args carry ``round``), else the
    engine's own ``round_time_s``.  The span and the perf_counter delta
    bound the same interval — the tracer join exists so drift can be
    computed for any phase the tracer names, not just whole rounds."""
    from repro.sim.clock import record_field
    t = int(record_field(rr, "round", 0))
    if tracer is not None:
        for e in tracer.events():
            if (e.name == "train.round" and e.phase == "X"
                    and (e.args or {}).get("round") == t):
                return e.dur_us / 1e6
    return float(record_field(rr, "round_time_s", 0.0) or 0.0)


class DriftMonitor:
    """Accumulates measured-vs-predicted rows and applies the warn rule."""

    def __init__(self, warn_ratio: float = 4.0,
                 metrics: Optional[MetricsRegistry] = None):
        if warn_ratio < 1.0:
            raise ValueError(f"warn_ratio {warn_ratio} < 1 (the rule is "
                             f"symmetric: ratio outside [1/w, w] warns)")
        self.warn_ratio = float(warn_ratio)
        self.records: List[DriftRecord] = []
        self._metrics = metrics if metrics is not None else _default_registry()

    def observe(self, round: int, phase: str, measured_s: float,
                predicted_s: float, source: str = "") -> DriftRecord:
        """Join one (measured, predicted) pair; returns the banked row."""
        if predicted_s > 0.0:
            ratio: Optional[float] = measured_s / predicted_s
            warn = not (1.0 / self.warn_ratio <= ratio <= self.warn_ratio)
        else:
            ratio, warn = None, True
        rec = DriftRecord(round=int(round), phase=phase,
                          measured_s=float(measured_s),
                          predicted_s=float(predicted_s),
                          ratio=ratio, warn=warn, source=source)
        self.records.append(rec)
        if ratio is not None:
            self._metrics.histogram(f"drift.{phase}.ratio").observe(ratio)
        self._metrics.counter("drift.rows").inc()
        if warn:
            self._metrics.counter("drift.warnings").inc()
        return rec

    def observe_round(self, rr: Any, *, fleet: Any = None, device: Any = None,
                      overlap: bool = False, tracer: Any = None
                      ) -> DriftRecord:
        """Join one round record against the best available predictor."""
        pred, source = predicted_round_s(rr, fleet=fleet, device=device,
                                         overlap=overlap)
        from repro.sim.clock import record_field
        return self.observe(int(record_field(rr, "round", 0)), "round",
                            measured_round_s(rr, tracer), pred, source)

    # -- reporting ------------------------------------------------------

    def warnings(self) -> List[DriftRecord]:
        return [r for r in self.records if r.warn]

    def rows(self) -> List[Dict[str, Any]]:
        return [r.to_json() for r in self.records]

    def lines(self) -> List[str]:
        """Human-readable ledger (the train driver prints it)."""
        out = [f"drift ledger: {len(self.records)} rows, "
               f"{len(self.warnings())} warnings (warn outside "
               f"[1/{self.warn_ratio:g}, {self.warn_ratio:g}]x)"]
        for r in self.records:
            ratio = f"{r.ratio:8.3f}x" if r.ratio is not None else "     n/a"
            flag = "  WARN" if r.warn else ""
            out.append(f"  round {r.round:3d} {r.phase:<10s} "
                       f"measured {r.measured_s:9.3f}s  predicted "
                       f"{r.predicted_s:9.3f}s  ratio {ratio} "
                       f"[{r.source}]{flag}")
        return out

    def export(self, path: str) -> str:
        """Write the ratio ledger as JSON (sorted keys, trailing newline;
        ``ratio`` is null where the prediction was non-positive, so the
        file is strict JSON)."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        payload = {"warn_ratio": self.warn_ratio,
                   "n_rows": len(self.records),
                   "n_warnings": len(self.warnings()),
                   "rows": self.rows()}
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        return path


def from_history(history: Sequence[Any], *, fleet: Any = None,
                 device: Any = None, overlap: bool = False,
                 warn_ratio: float = 4.0, tracer: Any = None,
                 metrics: Optional[MetricsRegistry] = None) -> DriftMonitor:
    """Build a monitor over a full session history (live ``RoundResult``
    objects or the serialized dicts a checkpoint sidecar carries)."""
    mon = DriftMonitor(warn_ratio=warn_ratio, metrics=metrics)
    for rr in history:
        mon.observe_round(rr, fleet=fleet, device=device, overlap=overlap,
                          tracer=tracer)
    return mon
