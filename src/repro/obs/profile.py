"""Opt-in profiler hooks: ``jax.profiler`` traces + compile-event capture.

Two independent capture layers on top of the span tracer:

  * ``jax_profile(outdir)`` — context manager around ``jax.profiler.trace``
    (TensorBoard/XProf format, device-level detail).  ``outdir=None`` is a
    no-op, so drivers can wire it unconditionally; a missing/broken
    profiler degrades to the no-op with a warning instead of killing the
    run (the container may lack libtpu/profiler support).

  * ``capture_compiles()`` — registers a ``jax.monitoring`` listener that
    turns every ``/jax/core/compile/*`` duration event (jaxpr trace, MLIR
    lowering, backend compile) into a span on the process-wide tracer
    (category ``compile``) and bumps ``compile.events`` /
    ``compile.total_s`` in the metrics registry.  Compile time is the #1
    confound in round-time drift — a retrace shows up as a fat span right
    where the round got slow instead of as an unexplained 30s ratio spike.

``record_compile`` is the explicit variant for compiles jax's monitoring
cannot attribute: the round engines call it at trace time of their shard
programs (wrapping the ``FedSession.shard_compiles`` counter), so the
Perfetto timeline shows WHICH round and shard width paid each trace.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Iterator, Optional

from repro.obs.metrics import registry as _registry
from repro.obs.trace import PID_MEASURED, get_tracer

_COMPILE_LISTENER_INSTALLED = False


def record_compile(what: str, **args: Any) -> None:
    """Mark an explicit compile/trace event 'now' on the process-wide
    tracer (instant, category ``compile``) and count it in the registry.
    Cheap no-op while the tracer is disabled (the counter still counts —
    compile counts are an invariant tests pin even without tracing)."""
    _registry().counter("compile.events").inc()
    tracer = get_tracer()
    if tracer.enabled:
        tracer.instant(f"compile/{what}", cat="compile", **args)


def _on_duration_event(event: str, duration_secs: float, **kw: Any) -> None:
    """jax.monitoring listener: compile-phase durations -> tracer spans.
    The event fires at phase END, so the span is backdated by its own
    duration; non-compile events are ignored."""
    if "compile" not in event:
        return
    name = event.rsplit("/", 1)[-1]
    if name.endswith("_duration"):
        name = name[: -len("_duration")]
    _registry().counter("compile.events").inc()
    _registry().counter("compile.total_s").inc(max(duration_secs, 0.0))
    tracer = get_tracer()
    if not tracer.enabled:
        return
    now_s = (time.perf_counter_ns() - tracer._epoch_ns) / 1e9
    tracer.add_span(f"compile/{name}", ts_s=now_s - duration_secs,
                    dur_s=duration_secs, cat="compile", pid=PID_MEASURED,
                    tid=0)


def capture_compiles() -> bool:
    """Install the compile-event listener (idempotent).  Returns True when
    the listener is active; False when this jax build has no
    ``jax.monitoring`` duration events to subscribe to."""
    global _COMPILE_LISTENER_INSTALLED
    if _COMPILE_LISTENER_INSTALLED:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_duration_event)
    except Exception:
        return False
    _COMPILE_LISTENER_INSTALLED = True
    return True


@contextlib.contextmanager
def jax_profile(outdir: Optional[str]) -> Iterator[None]:
    """``with jax_profile(dir):`` wraps the body in a ``jax.profiler``
    trace written to ``dir`` (viewable in TensorBoard / xprof / Perfetto).
    ``outdir`` of None/"" is a no-op; a profiler that fails to start
    degrades to the no-op with a warning (some hosts lack the backend)."""
    if not outdir:
        yield
        return
    try:
        import jax.profiler as jp
        ctx = jp.trace(outdir)
    except Exception as e:                        # pragma: no cover
        print(f"obs.profile: jax profiler unavailable ({e}); "
              f"continuing without device trace")
        yield
        return
    with ctx:
        yield
