"""Byte-accounting helpers shared across layers.

Dependency-free on purpose: both the round engines (``repro.core.rounds``)
and the lightweight sim replay path (``repro.sim.clock``) use these without
pulling the training stack in.
"""

from __future__ import annotations

from typing import List


def split_bytes(total: int, k: int) -> List[int]:
    """Per-client share of ``total`` upload bytes: even split with the
    remainder spread one byte over the first ``total % k`` clients, so the
    ledger sums EXACTLY to the round total (a plain ``total // k`` split
    drops the remainder and the sim replay under-counts wire traffic).

    >>> split_bytes(7, 2)
    [4, 3]
    >>> sum(split_bytes(10_000_001, 3))
    10000001
    """
    base, rem = divmod(int(total), k)
    return [base + (1 if i < rem else 0) for i in range(k)]
