"""FedAvg aggregation (McMahan et al., 2017): W = sum_k (n_k / n) W_k.

Three layouts:
  * ``fedavg``          — list of K param trees (the sequential engine).
  * ``fedavg_stacked``  — ONE tree with a leading client dim, reduced with
    ``jnp.sum`` over axis 0 (the production mesh program).  With the client
    dim sharded over the ``pod`` axis the weighted mean lowers to exactly
    one cross-pod all-reduce — FedAvg's communication pattern on DCN.
  * ``fedavg_fold``     — the STREAMING reduction: a client-index left fold
    ``acc <- acc + w_k * W_k`` carried in fp32.  This is the cohort-scan
    engine's canonical reduction order: a left fold is invariant to where
    shard boundaries fall (folding shards [0:S), [S:2S), ... through a
    carried accumulator performs literally the same add sequence as one
    fold over all K), which is what makes cohort-scan results bitwise
    identical to the full-width vmapped round at any shard size.  Note
    ``jnp.sum`` does NOT reduce in this order (XLA vectorizes it), so the
    fold and the sum differ in the last ulp — the parallel round engine
    uses the fold everywhere; the mesh program keeps the sum (one
    all-reduce beats a serialized fold on a sharded client axis).
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp


def _weights(sizes: Sequence[float]) -> jax.Array:
    w = jnp.asarray(sizes, jnp.float32)
    return w / jnp.sum(w)


def fedavg(trees: Sequence[Any], sizes: Sequence[float]) -> Any:
    w = _weights(sizes)

    def combine(*leaves):
        acc = sum(wk * l.astype(jnp.float32) for wk, l in zip(list(w), leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(combine, *trees)


def fedavg_stacked(stacked: Any, sizes: Sequence[float]) -> Any:
    """stacked: every leaf (K, ...) -> weighted mean over axis 0."""
    w = _weights(sizes)

    def combine(l):
        shape = (-1,) + (1,) * (l.ndim - 1)
        return jnp.sum(l.astype(jnp.float32) * w.reshape(shape), axis=0
                       ).astype(l.dtype)

    return jax.tree.map(combine, stacked)


def fold_init(tree: Any) -> Any:
    """Zero fp32 accumulator shaped like one (unstacked) param tree — the
    carry a streaming aggregation threads across cohort shards."""
    return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), tree)


def fedavg_fold(partial: Any, stacked: Any, norm_weights: jax.Array) -> Any:
    """Continue the canonical left fold: ``partial[i+1] = partial[i] +
    w_k * W_k`` over this shard's client axis.  ``norm_weights`` must
    already be normalized over the FULL cohort (w_k = n_k / n) — the fold
    itself never sees the cohort size, so any shard partition of the same
    client sequence produces the same bits."""
    def body(acc, xw):
        x, wk = xw
        return (jax.tree.map(lambda a, l: a + wk * l.astype(jnp.float32),
                             acc, x), None)

    acc, _ = jax.lax.scan(body, partial, (stacked, norm_weights))
    return acc


def fold_finalize(partial: Any, like: Any) -> Any:
    """Cast a finished fp32 fold accumulator back to the param dtypes
    (the same final cast ``fedavg_stacked`` performs)."""
    return jax.tree.map(lambda a, l: a.astype(l.dtype), partial, like)


def scalar_fold(acc: jax.Array, vals: jax.Array) -> jax.Array:
    """Left fold of a 1-D vector into a scalar carry (loss/token totals of
    the streaming round engine — same shard-invariance argument as
    ``fedavg_fold``)."""
    out, _ = jax.lax.scan(lambda a, v: (a + v, None), acc, vals)
    return out


def broadcast_clients(tree: Any, k: int) -> Any:
    """Replicate a global tree to the stacked (K, ...) client layout."""
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (k,) + l.shape), tree)
