"""FedAvg aggregation (McMahan et al., 2017): W = sum_k (n_k / n) W_k.

Two layouts:
  * ``fedavg``          — list of K param trees (the sequential engine).
  * ``fedavg_stacked``  — ONE tree with a leading client dim (the mesh
    engine / production program).  On the production mesh the client dim is
    sharded over the ``pod`` axis, so the weighted mean lowers to exactly one
    cross-pod all-reduce — FedAvg's communication pattern on DCN.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp


def _weights(sizes: Sequence[float]) -> jax.Array:
    w = jnp.asarray(sizes, jnp.float32)
    return w / jnp.sum(w)


def fedavg(trees: Sequence[Any], sizes: Sequence[float]) -> Any:
    w = _weights(sizes)

    def combine(*leaves):
        acc = sum(wk * l.astype(jnp.float32) for wk, l in zip(list(w), leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(combine, *trees)


def fedavg_stacked(stacked: Any, sizes: Sequence[float]) -> Any:
    """stacked: every leaf (K, ...) -> weighted mean over axis 0."""
    w = _weights(sizes)

    def combine(l):
        shape = (-1,) + (1,) * (l.ndim - 1)
        return jnp.sum(l.astype(jnp.float32) * w.reshape(shape), axis=0
                       ).astype(l.dtype)

    return jax.tree.map(combine, stacked)


def broadcast_clients(tree: Any, k: int) -> Any:
    """Replicate a global tree to the stacked (K, ...) client layout."""
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (k,) + l.shape), tree)
