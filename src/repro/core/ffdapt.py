"""FFDAPT — Frozen Federated Domain-Adaptive Pre-Training (Algorithm 1).

Per round t, per client k:
    N_k = min(epsilon, ceil(n_k / n * N) * gamma)
consecutive layers starting at a rotating pointer are frozen; the pointer
advances by N_k after each client and wraps modulo N (the algorithm's
``else`` branch freezes the two wrap segments).  ``epsilon`` caps the window
(< N — "freezing all layers is meaningless"); ``gamma`` scales it.

The schedule is pure data: ``rounds[t][k] = (start, n_frozen)``.  Execution
happens in ``repro.models.steps`` — either *static* windows (paper-faithful,
backward dW never compiled for frozen layers; at most N distinct programs
are ever compiled thanks to rotation) or *masked* (one program; update
suppression only).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

from repro.nn.stack import freeze_window_mask

Window = Tuple[int, int]          # (start layer, n_frozen), 0-based


@dataclasses.dataclass(frozen=True)
class FFDAPTConfig:
    epsilon: int = 0              # 0 -> default N-1
    gamma: float = 1.0


def client_window_size(n_k: int, n_total: int, n_layers: int,
                       epsilon: int, gamma: float) -> int:
    """Algorithm 1 line: N_k = min(eps, ceil(n_k/n * N) * gamma).

    The gamma-scaled size is rounded HALF-UP, not truncated: ``int()``
    floored the smallest clients' windows to 0 whenever ``gamma < 1``
    (n_k=5, n=100, N=12, gamma=0.5 gave int(0.5) = 0 — no freezing at
    all), silently disabling FFDAPT exactly where its saving matters."""
    raw = math.ceil(n_k / max(n_total, 1) * n_layers) * gamma
    return max(0, min(int(epsilon), math.floor(raw + 0.5)))


def schedule(n_layers: int, client_sizes: Sequence[int], n_rounds: int,
             *, epsilon: int = 0, gamma: float = 1.0) -> List[List[Window]]:
    """Full rotating schedule: ``out[t][k] = (start, N_k)``.

    The pointer is shared across clients and rounds: client k+1's window
    begins where client k's ended, so successive clients/rounds cover
    different layers (Algorithm 1's ``start = end + 1`` rotation).
    """
    n = sum(client_sizes)
    N = n_layers
    eps = epsilon if epsilon > 0 else max(N - 1, 0)
    eps = min(eps, N - 1) if N > 1 else 0
    start = 0
    out: List[List[Window]] = []
    for _ in range(n_rounds):
        rnd = []
        for nk in client_sizes:
            Nk = client_window_size(nk, n, N, eps, gamma)
            rnd.append((start, Nk))
            start = (start + Nk) % max(N, 1)
        out.append(rnd)
    return out


def window_mask(n_layers: int, window: Window) -> Tuple[bool, ...]:
    """(start, n_frozen) -> per-layer bool mask (wrap-aware)."""
    return freeze_window_mask(n_layers, window)


def backward_flop_saving(n_layers: int, windows: Sequence[Window],
                         *, layer_share: float = 1.0) -> float:
    """Analytic fraction of *backward dW* FLOPs removed, averaged over the
    given per-client windows.  With backward ~ 2x forward and dW ~ half of
    backward, total-step saving ~= saving_frac * layer_share * (2/3) * (1/2).

    ``layer_share``: fraction of total model FLOPs inside the freezable stack
    (embeddings/head excluded)."""
    if not windows:
        return 0.0
    frac = sum(min(nf, n_layers) for _, nf in windows) / (len(windows) * n_layers)
    return frac * layer_share * (2.0 / 3.0) * 0.5
