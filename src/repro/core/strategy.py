"""Unified ``FederatedStrategy`` API — the paper's §5 extension axes as one
pluggable abstraction over both round engines.

A strategy owns the three places federated algorithms differ:

  * the **client objective** — ``make_client_step`` builds the local train
    step (FedProx plugs its proximal term in here);
  * the **server aggregation** — ``aggregate`` (list-of-trees layout, the
    sequential engine) and the streaming contract ``aggregate_init`` /
    ``aggregate_partial`` / ``aggregate_combine`` (the cohort-scan engine
    folds one client shard at a time through a carried fp32 accumulator;
    ``aggregate_stacked`` is the same contract over a single full-cohort
    shard).  Strategies customize via ``effective_weights`` (AsyncFedAvg
    staleness discounts), ``map_clients`` (Compressed delta round-trip),
    and ``server_update`` (FedAvgM momentum) — the reduction order itself
    is fixed (a client-index left fold), which is what keeps results
    bitwise independent of the shard size;
  * the **upload accounting** — ``aggregate`` returns exact client->server
    bytes; ``upload_bytes`` is the static (shape-derived) figure the jitted
    path reports.

Instances are frozen dataclasses: hashable (they key the compiled-step
cache) and comparable (two ``FedAvg()`` are the same strategy).

Strategies:
  ``FedAvg``      — weighted mean (McMahan et al., 2017); the paper's server.
  ``FedAvgM``     — server momentum over the pseudo-gradient (Hsu et al., 2019).
  ``FedProx``     — proximal client objective (Li et al., 2020).
  ``Compressed``  — decorator: top-k sparsified or int8-quantized client
                    DELTAS around any inner strategy's aggregation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fedavg import (fedavg, fedavg_fold, fedavg_stacked,
                               fold_finalize, fold_init)
from repro.models.steps import make_masked_train_step, make_train_step


def tree_bytes(tree: Any) -> int:
    """Dense wire size of one upload: sum of leaf nbytes (dtype-aware)."""
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def tree_delta(new: Any, base: Any) -> Any:
    """Client delta in fp32 (deltas compress far better than weights)."""
    return jax.tree.map(lambda n, b: n.astype(jnp.float32)
                        - b.astype(jnp.float32), new, base)


def tree_add(base: Any, delta: Any) -> Any:
    """Apply an fp32 delta, casting back to the base leaf dtype."""
    return jax.tree.map(lambda b, d: (b.astype(jnp.float32) + d
                                      ).astype(b.dtype), base, delta)


# ---------------------------------------------------------------------------
# Compressors (jax-pure tree -> tree; trace-safe, vmap-able over a client dim)
# ---------------------------------------------------------------------------

def topk_count(n: int, frac: float) -> int:
    """The k every top-k site uses: ``ceil(frac * n)``, clamped to
    [1, n].  One shared helper so the eager compressor
    (``strategies.topk_sparsify``), the trace-safe compressor
    (``topk_compress``), and the static byte accounting (``topk_bytes``)
    cannot disagree about how many entries "top-frac" means
    (tests/test_strategies.py pins the exact-count law)."""
    return min(n, max(1, math.ceil(n * frac)))


def topk_compress(delta: Any, frac: float) -> Any:
    """Keep the top-``frac`` fraction of entries per leaf by magnitude.
    Ties at the threshold are kept (>=), matching the eager reference."""
    def one(d):
        k = topk_count(d.size, frac)
        flat = d.reshape(-1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(d.shape)

    return jax.tree.map(one, delta)


def int8_compress(delta: Any) -> Any:
    """Symmetric per-leaf int8 quantize->dequantize round trip."""
    def one(d):
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale

    return jax.tree.map(one, delta)


def topk_bytes(tree: Any, frac: float) -> int:
    """Static top-k upload size: k values (leaf dtype) + k int32 indices."""
    total = 0
    for l in jax.tree.leaves(tree):
        k = topk_count(l.size, frac)
        total += k * (jnp.dtype(l.dtype).itemsize + 4)
    return total


def int8_bytes(tree: Any) -> int:
    """Static int8 upload size: 1 B/entry + one fp32 scale per leaf."""
    return int(sum(l.size + 4 for l in jax.tree.leaves(tree)))


def exact_kept_bytes(compressed_delta: Any) -> int:
    """Exact top-k accounting on concrete (eager) arrays: the ``>= thresh``
    tie rule can keep MORE than k entries — count what actually survived."""
    total = 0
    for l in jax.tree.leaves(compressed_delta):
        kept = int(jnp.sum(l != 0.0))
        total += max(kept, 1) * (jnp.dtype(l.dtype).itemsize + 4)
    return total


# ---------------------------------------------------------------------------
# Strategy base
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FederatedStrategy:
    """Base strategy: plain FedAvg behavior for every hook.

    ``needs_anchor`` tells the engines whether client steps take the round's
    global params as an explicit argument (FedProx does; keeping the
    argument out of FedAvg-family programs preserves bitwise parity with the
    legacy engines)."""

    name = "strategy"
    needs_anchor = False

    # -- state ---------------------------------------------------------
    def init_state(self, global_params: Any) -> Any:
        """Server-side state threaded through every round (a pytree of
        arrays, so the jitted mesh program can carry it)."""
        return ()

    def state_to_tree(self, state: Any) -> Any:
        """Server state as a pytree of ARRAYS for the checkpoint layer
        (``repro.checkpoint`` flattens it next to the global params).  The
        default is the identity — it covers every strategy whose state
        already is such a pytree (FedAvg family / FedProx / AsyncFedAvg:
        ``()``; FedAvgM: the fp32 momentum tree).  A strategy carrying
        non-array state must encode it here and decode in
        ``state_from_tree`` so resumed runs stay bitwise identical."""
        return state

    def state_from_tree(self, tree: Any) -> Any:
        """Inverse of ``state_to_tree``.  ``tree`` holds the exact saved
        leaf values, restored against ``state_to_tree(init_state(params))``
        as the shape/dtype template."""
        return tree

    # -- client objective ---------------------------------------------
    def make_client_step(self, cfg, optimizer, *, frozen=None,
                         masked: bool = False, impl: str = "xla",
                         space=None):
        """Local train step.  ``masked=False`` (sequential engine): static
        FFDAPT ``frozen`` window, signature ``step(params, opt, batch)`` —
        or ``step(params, opt, anchor, batch)`` when ``needs_anchor``.
        ``masked=True`` (mesh engine): traced freeze mask appended.
        A low-rank ``space`` (repro.peft) swaps in the PEFT step: ``params``
        becomes the factor bank and the frozen base model splices in as
        ``step(bank, opt, base, [anchor,] batch)``."""
        if space is not None and space.low_rank:
            from repro.peft.step import make_peft_train_step
            return make_peft_train_step(cfg, optimizer, space, impl=impl)
        if masked:
            return make_masked_train_step(cfg, optimizer, impl=impl)
        return make_train_step(cfg, optimizer, frozen=frozen, impl=impl)

    def client_step_key(self) -> Tuple:
        """Cache identity of ``make_client_step``'s program: every strategy
        with the plain objective (FedAvg, FedAvgM, any ``Compressed`` over
        them) shares ONE compiled client step."""
        return ("plain",)

    # -- server aggregation -------------------------------------------
    def aggregate(self, global_params: Any, client_params: Sequence[Any],
                  sizes: Sequence[float], state: Any
                  ) -> Tuple[Any, Any, int]:
        """List layout (sequential engine).  Returns
        ``(new_global, new_state, upload_bytes)`` with exact accounting."""
        new = fedavg(client_params, sizes)
        return new, state, len(client_params) * tree_bytes(global_params)

    def aggregate_stacked(self, global_params: Any, stacked: Any,
                          weights: jax.Array, state: Any) -> Tuple[Any, Any]:
        """Stacked layout: every leaf of ``stacked`` is (K, ...).  Pure jax —
        traced inside the jitted round program (byte accounting is static;
        see ``upload_bytes``).

        Derived from the STREAMING contract below, so the full-width vmapped
        round and the cohort-scan engine share one reduction order: it is
        exactly ``aggregate_partial`` over a single shard holding the whole
        cohort, followed by ``aggregate_combine``."""
        k = int(weights.shape[0])
        wn = self.effective_weights(weights)
        wn = wn / jnp.sum(wn)
        partial = self.aggregate_partial(global_params, stacked, wn,
                                         self.aggregate_init(global_params))
        return self.aggregate_combine(global_params, partial, state, k=k)

    # -- streaming aggregation (the cohort-scan contract) --------------
    #
    # The cohort-scan engine never holds the whole cohort: it folds one
    # fixed-size shard at a time through a carried fp32 ``partial`` and
    # combines once at the end of the round.  Peak live client state is
    # O(shard), not O(cohort).  The reduction is the canonical client-index
    # left fold (``repro.core.fedavg.fedavg_fold``) — shard boundaries
    # cannot change the add sequence, so any shard size produces bitwise
    # the same round as the full-width vmapped program.
    #
    # Strategies customize three orthogonal hooks instead of rewriting the
    # reduction: ``effective_weights`` (AsyncFedAvg's staleness discounts),
    # ``map_clients`` (Compressed's per-client delta round-trip), and
    # ``server_update`` (FedAvgM's momentum, AsyncFedAvg's server step).

    def effective_weights(self, weights: jax.Array) -> jax.Array:
        """Cohort weight vector -> aggregation weights, BEFORE the global
        normalization.  Called once per round on the full cohort's (K,)
        weights — never per shard, so the normalizer sees every client."""
        return weights

    def map_clients(self, global_params: Any, stacked: Any) -> Any:
        """Per-client transform applied to a shard's stacked params before
        they enter the fold (vmapped-style, O(shard) live).  ``Compressed``
        round-trips each client's delta here."""
        return stacked

    def server_update(self, global_params: Any, mean: Any, state: Any,
                      *, k: int) -> Tuple[Any, Any]:
        """Turn the finished weighted mean into the new global params.
        ``k`` is the cohort size (static).  FedAvg: the mean IS the new
        model."""
        return mean, state

    def aggregate_init(self, global_params: Any) -> Any:
        """Fresh fold carry for one round (fp32 zeros, unstacked shapes)."""
        return fold_init(global_params)

    def aggregate_partial(self, global_params: Any, stacked: Any,
                          norm_weights: jax.Array, partial: Any) -> Any:
        """Fold ONE shard into the carry.  ``stacked`` leaves are
        (shard, ...); ``norm_weights`` is this shard's slice of the
        cohort-normalized weights."""
        return fedavg_fold(partial, self.map_clients(global_params, stacked),
                           norm_weights)

    def aggregate_combine(self, global_params: Any, partial: Any, state: Any,
                          *, k: int) -> Tuple[Any, Any]:
        """Finish the round: cast the fp32 carry back to param dtypes and
        apply the strategy's server update."""
        mean = fold_finalize(partial, global_params)
        return self.server_update(global_params, mean, state, k=k)

    # -- accounting ----------------------------------------------------
    def upload_bytes(self, global_params: Any, k: int) -> int:
        """Static per-round client->server bytes for ``k`` participants."""
        return k * tree_bytes(global_params)

    def download_bytes(self, global_params: Any, k: int) -> int:
        """Static per-round server->client bytes: the round-start broadcast
        of the global model to ``k`` participants.  Dense for every strategy
        here — ``Compressed`` only compresses the upload direction (client
        deltas; the server's broadcast is the full aggregated model)."""
        return k * tree_bytes(global_params)


@dataclasses.dataclass(frozen=True)
class FedAvg(FederatedStrategy):
    """W = sum_k (n_k/n) W_k — the paper's aggregation, as a strategy."""

    name = "fedavg"


@dataclasses.dataclass(frozen=True)
class FedAvgM(FederatedStrategy):
    """Server momentum over the weighted client delta (pseudo-gradient)."""

    beta: float = 0.9
    lr: float = 1.0
    name = "fedavgm"

    def init_state(self, global_params):
        # zero momentum: round 1 reduces to m = delta, the standard start
        return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32),
                            global_params)

    def _apply(self, global_params, avg, state):
        delta = tree_delta(avg, global_params)
        m = jax.tree.map(lambda mo, d: self.beta * mo + d, state, delta)
        new = jax.tree.map(lambda g, mo: (g.astype(jnp.float32) + self.lr * mo
                                          ).astype(g.dtype), global_params, m)
        return new, m

    def aggregate(self, global_params, client_params, sizes, state):
        new, m = self._apply(global_params, fedavg(client_params, sizes), state)
        return new, m, len(client_params) * tree_bytes(global_params)

    def server_update(self, global_params, mean, state, *, k):
        return self._apply(global_params, mean, state)


@dataclasses.dataclass(frozen=True)
class FedProx(FederatedStrategy):
    """FedAvg aggregation + proximal client objective
    mu/2 ||w - w_global||^2 (bounds non-IID client drift)."""

    mu: float = 0.01
    name = "fedprox"

    @property
    def needs_anchor(self):                            # type: ignore[override]
        # mu=0 collapses to the plain (anchor-less) FedAvg client program
        return self.mu != 0.0

    def client_step_key(self):
        return ("prox", self.mu) if self.mu else ("plain",)

    def make_client_step(self, cfg, optimizer, *, frozen=None,
                         masked: bool = False, impl: str = "xla",
                         space=None):
        if space is not None and space.low_rank:
            # proximal pull toward the round-global BANK: base coordinates
            # never move, so ||bank - anchor||^2 is the whole prox term
            from repro.peft.step import make_peft_train_step
            return make_peft_train_step(cfg, optimizer, space, impl=impl,
                                        prox_mu=self.mu)
        if masked:
            return make_masked_train_step(cfg, optimizer, impl=impl,
                                          prox_mu=self.mu)
        return make_train_step(cfg, optimizer, frozen=frozen, impl=impl,
                               prox_mu=self.mu)


@dataclasses.dataclass(frozen=True)
class Compressed(FederatedStrategy):
    """Communication-efficient decorator: clients upload compressed DELTAS
    (deltas compress far better than weights); the inner strategy aggregates
    the reconstructed client trees.  ``kind``: ``topk`` | ``int8``."""

    inner: FederatedStrategy = FedAvg()
    kind: str = "topk"
    frac: float = 0.1

    @property
    def name(self):                                    # type: ignore[override]
        return f"{self.inner.name}+{self.kind}"

    @property
    def needs_anchor(self):                            # type: ignore[override]
        return self.inner.needs_anchor

    def _compress(self, delta):
        if self.kind == "topk":
            return topk_compress(delta, self.frac)
        if self.kind == "int8":
            return int8_compress(delta)
        raise ValueError(self.kind)

    def init_state(self, global_params):
        return self.inner.init_state(global_params)

    def state_to_tree(self, state):
        return self.inner.state_to_tree(state)

    def state_from_tree(self, tree):
        return self.inner.state_from_tree(tree)

    def make_client_step(self, cfg, optimizer, **kw):
        return self.inner.make_client_step(cfg, optimizer, **kw)

    def client_step_key(self):
        return self.inner.client_step_key()

    def aggregate(self, global_params, client_params, sizes, state):
        rebuilt, nbytes = [], 0
        for cp in client_params:
            d = self._compress(tree_delta(cp, global_params))
            if self.kind == "topk":
                nbytes += exact_kept_bytes(d)
            else:
                nbytes += int8_bytes(d)
            rebuilt.append(tree_add(global_params, d))
        new, state, _ = self.inner.aggregate(global_params, rebuilt, sizes,
                                             state)
        return new, state, nbytes

    def map_clients(self, global_params, stacked):
        """Per-client delta -> compress -> rebuild round-trip, vmapped over
        the shard's client axis (O(shard) live — each cohort shard is
        round-tripped as it streams through the fold)."""
        deltas = jax.tree.map(
            lambda s, g: s.astype(jnp.float32) - g.astype(jnp.float32)[None],
            stacked, global_params)
        comp = jax.vmap(self._compress)(deltas)
        rebuilt = jax.tree.map(
            lambda g, d: (g.astype(jnp.float32)[None] + d).astype(g.dtype),
            global_params, comp)
        return self.inner.map_clients(global_params, rebuilt)

    def effective_weights(self, weights):
        return self.inner.effective_weights(weights)

    def server_update(self, global_params, mean, state, *, k):
        return self.inner.server_update(global_params, mean, state, k=k)

    def upload_bytes(self, global_params, k):
        if self.kind == "topk":
            return k * topk_bytes(global_params, self.frac)
        return k * int8_bytes(global_params)


# ---------------------------------------------------------------------------
# Registry (the ``--strategy`` / ``--compress`` driver surface)
# ---------------------------------------------------------------------------

STRATEGIES = ("fedavg", "fedavgm", "fedprox", "asyncfedavg")
COMPRESSORS = ("none", "topk", "int8")


def make_strategy(name: str = "fedavg", *, compress: str = "none",
                  mu: float = 0.01, beta: float = 0.9, server_lr: float = 1.0,
                  frac: float = 0.1, alpha: float = 0.5,
                  staleness: Sequence[int] = ()) -> FederatedStrategy:
    """Build a strategy from flag-shaped arguments (see ``launch/train.py``)."""
    base: FederatedStrategy
    if name == "fedavg":
        base = FedAvg()
    elif name == "fedavgm":
        base = FedAvgM(beta=beta, lr=server_lr)
    elif name == "fedprox":
        base = FedProx(mu=mu)
    elif name == "asyncfedavg":
        # defined with the other server-side algorithms; imported lazily
        # (strategies.py imports this module's helpers)
        from repro.core.strategies import AsyncFedAvg
        base = AsyncFedAvg(alpha=alpha, server_lr=server_lr,
                           staleness=tuple(staleness))
    else:
        raise ValueError(f"unknown strategy {name!r} (want one of {STRATEGIES})")
    if compress == "none":
        return base
    if compress in ("topk", "int8"):
        return Compressed(inner=base, kind=compress, frac=frac)
    raise ValueError(f"unknown compressor {compress!r} (want one of {COMPRESSORS})")
