"""Federated aggregation strategies beyond FedAvg.

The paper (§5) names two extension axes: *other federated strategies* and
*communication-efficient algorithms*.  These are the standard instances of
each, implemented server-side over the same round engines:

  * ``fedavgm``   — FedAvg + server momentum (Hsu et al., 2019): the server
    treats the weighted client delta as a pseudo-gradient.
  * ``fedprox``   — FedProx (Li et al., 2020): a proximal term
    mu/2 ||w - w_global||^2 added to each client's local objective keeps
    non-IID clients from drifting (client-side; see ``make_fedprox_step``).
  * ``topk_sparsify / dequantize8`` — communication compression for the
    client->server upload: top-k magnitude sparsification and symmetric
    int8 quantization of client DELTAS (deltas compress far better than
    weights).  Both report exact upload-bytes so the efficiency/quality
    trade is measurable (benchmarks/comm_efficiency.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.fedavg import fedavg


# ---------------------------------------------------------------------------
# Server-side optimizers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServerState:
    momentum: Any = None


def fedavgm_update(global_params: Any, client_params: Sequence[Any],
                   sizes: Sequence[float], state: ServerState,
                   *, beta: float = 0.9, lr: float = 1.0):
    """Server momentum over the weighted client delta."""
    avg = fedavg(client_params, sizes)
    delta = jax.tree.map(lambda a, g: a.astype(jnp.float32)
                         - g.astype(jnp.float32), avg, global_params)
    if state.momentum is None:
        m = delta
    else:
        m = jax.tree.map(lambda mo, d: beta * mo + d, state.momentum, delta)
    new = jax.tree.map(lambda g, mo: (g.astype(jnp.float32) + lr * mo
                                      ).astype(g.dtype), global_params, m)
    return new, ServerState(momentum=m)


# ---------------------------------------------------------------------------
# FedProx client objective
# ---------------------------------------------------------------------------

def proximal_penalty(params: Any, anchor: Any) -> jax.Array:
    """mu-less proximal term: 1/2 ||w - w_anchor||^2 (caller scales by mu)."""
    leaves = jax.tree.map(
        lambda p, a: jnp.sum(jnp.square(p.astype(jnp.float32)
                                        - a.astype(jnp.float32))),
        params, anchor)
    return 0.5 * sum(jax.tree.leaves(leaves))


def make_fedprox_step(cfg, optimizer, *, mu: float = 0.01, impl: str = "xla",
                      clip_norm: float = 1.0):
    """Train step whose objective adds mu/2 ||w - w_global||^2.  The global
    anchor is passed per call (it changes every round)."""
    from repro.models.steps import _objective
    from repro.optim import apply_updates, clip_by_global_norm

    def objective(params, anchor, batch):
        total, metrics = _objective(params, cfg, batch, None, impl)
        prox = mu * proximal_penalty(params, anchor)
        return total + prox, dict(metrics, prox=prox)

    grad_fn = jax.value_and_grad(objective, has_aux=True)

    def step(params, opt_state, anchor, batch):
        (_, metrics), grads = grad_fn(params, anchor, batch)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, dict(metrics, grad_norm=gnorm)

    return step


# ---------------------------------------------------------------------------
# Upload compression (client deltas)
# ---------------------------------------------------------------------------

def tree_delta(new: Any, base: Any) -> Any:
    return jax.tree.map(lambda n, b: n.astype(jnp.float32)
                        - b.astype(jnp.float32), new, base)


def tree_add(base: Any, delta: Any) -> Any:
    return jax.tree.map(lambda b, d: (b.astype(jnp.float32) + d
                                      ).astype(b.dtype), base, delta)


def topk_sparsify(delta: Any, frac: float = 0.1):
    """Keep the top-``frac`` fraction of entries per leaf (by magnitude).
    Returns (sparse_delta, upload_bytes) — bytes = kept values (4B) + indices
    (4B) per entry, the standard sparse-upload accounting."""
    total_bytes = 0

    def one(d):
        nonlocal total_bytes
        n = d.size
        k = max(1, int(n * frac))
        flat = d.reshape(-1)
        thresh = jnp.sort(jnp.abs(flat))[n - k]
        kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        total_bytes += k * 8
        return kept.reshape(d.shape)

    out = jax.tree.map(one, delta)
    return out, total_bytes


def quantize8(delta: Any):
    """Symmetric per-leaf int8 quantization.  Returns (dequantized_delta,
    upload_bytes) — bytes = 1B/entry + one fp32 scale per leaf."""
    total_bytes = 0

    def one(d):
        nonlocal total_bytes
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
        total_bytes += d.size + 4
        return q.astype(jnp.float32) * scale

    out = jax.tree.map(one, delta)
    return out, total_bytes


def dense_bytes(tree: Any) -> int:
    return int(sum(l.size * 4 for l in jax.tree.leaves(tree)))


def compressed_fedavg(global_params: Any, client_params: Sequence[Any],
                      sizes: Sequence[float],
                      compressor: Optional[Callable] = None):
    """FedAvg over (optionally compressed) client DELTAS.  Returns
    (new_global, total_upload_bytes)."""
    deltas, bytes_total = [], 0
    for cp in client_params:
        d = tree_delta(cp, global_params)
        if compressor is not None:
            d, b = compressor(d)
        else:
            b = dense_bytes(d)
        deltas.append(d)
        bytes_total += b
    avg_delta = fedavg(deltas, sizes)
    return tree_add(global_params, avg_delta), bytes_total
