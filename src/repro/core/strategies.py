"""Federated aggregation strategies beyond FedAvg.

The paper (§5) names two extension axes: *other federated strategies* and
*communication-efficient algorithms*.  These are the standard instances of
each, implemented server-side over the same round engines:

  * ``fedavgm``   — FedAvg + server momentum (Hsu et al., 2019): the server
    treats the weighted client delta as a pseudo-gradient.
  * ``fedprox``   — FedProx (Li et al., 2020): a proximal term
    mu/2 ||w - w_global||^2 added to each client's local objective keeps
    non-IID clients from drifting (client-side; see ``make_fedprox_step``).
  * ``topk_sparsify / dequantize8`` — communication compression for the
    client->server upload: top-k magnitude sparsification and symmetric
    int8 quantization of client DELTAS (deltas compress far better than
    weights).  Both report exact upload-bytes so the efficiency/quality
    trade is measurable (benchmarks/comm_efficiency.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fedavg import fedavg
from repro.core.strategy import FederatedStrategy, tree_bytes


# ---------------------------------------------------------------------------
# Server-side optimizers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServerState:
    momentum: Any = None


def fedavgm_update(global_params: Any, client_params: Sequence[Any],
                   sizes: Sequence[float], state: ServerState,
                   *, beta: float = 0.9, lr: float = 1.0):
    """Server momentum over the weighted client delta.  ``sizes`` are the
    aggregation weights n_k (any positive unit — only ratios matter);
    ``beta``/``lr`` are dimensionless.  Returns (new_params, new_state)."""
    avg = fedavg(client_params, sizes)
    delta = jax.tree.map(lambda a, g: a.astype(jnp.float32)
                         - g.astype(jnp.float32), avg, global_params)
    if state.momentum is None:
        m = delta
    else:
        m = jax.tree.map(lambda mo, d: beta * mo + d, state.momentum, delta)
    new = jax.tree.map(lambda g, mo: (g.astype(jnp.float32) + lr * mo
                                      ).astype(g.dtype), global_params, m)
    return new, ServerState(momentum=m)


# ---------------------------------------------------------------------------
# Buffered-async aggregation (FedBuff-style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AsyncFedAvg(FederatedStrategy):
    """FedBuff-style staleness-discounted aggregation (Nguyen et al., 2022)
    as a ``FederatedStrategy``.

    In a real async deployment each buffered update k arrives with staleness
    tau_k = (server version now) - (version client k downloaded).  The
    server aggregates the buffer with discounted weights

        w'_k = n_k x s(tau_k),   s(tau) = (1 + tau)^-alpha

    then moves ``server_lr`` of the way to the discounted weighted mean.
    The round engines execute synchronously, so they cannot *produce*
    staleness — ``staleness[i]`` assigns client position i its tau (cycled;
    empty = all fresh).  ``repro.sim.events.simulate_async`` produces the
    taus a fleet's timing actually implies; feeding its observed schedule
    back in here runs the learning math of that schedule — the simulator
    and the strategy share this one discount rule.

    Parity contract (pinned in tests/test_sim.py): with no staleness and
    ``server_lr=1`` both layouts take the exact ``fedavg`` code path, so
    AsyncFedAvg degenerates BITWISE to FedAvg on both engines.

    The buffer size itself is a *schedule* parameter, not a learning-math
    one — pass it to ``repro.sim.events.simulate_async(buffer_size=...)``;
    the numeric engines aggregate every round as usual.

    Server state is empty (the staleness assignment is config, not state),
    so the base ``state_to_tree``/``state_from_tree`` checkpoint hooks
    round-trip it trivially and a resumed AsyncFedAvg run stays bitwise
    identical (pinned in tests/test_resume.py).
    """

    alpha: float = 0.5
    server_lr: float = 1.0
    staleness: Tuple[int, ...] = ()        # tau per client position (cycled)
    name = "asyncfedavg"

    def discount(self, tau: float) -> float:
        """s(tau) = (1 + tau)^-alpha, the polynomial FedBuff discount."""
        return float((1.0 + float(tau)) ** (-self.alpha))

    def _taus(self, k: int):
        if not self.staleness:
            return [0] * k
        return [self.staleness[i % len(self.staleness)] for i in range(k)]

    def _fresh(self, k: int) -> bool:
        return (self.server_lr == 1.0
                and all(t == 0 for t in self._taus(k)))

    def _server_step(self, global_params, mean):
        return jax.tree.map(
            lambda g, m: (g.astype(jnp.float32)
                          + self.server_lr * (m.astype(jnp.float32)
                                              - g.astype(jnp.float32))
                          ).astype(g.dtype), global_params, mean)

    def aggregate(self, global_params, client_params, sizes, state):
        """List-layout aggregation.  ``sizes`` are the n_k weights; returns
        (new_params, state, upload_bytes) — upload_bytes counts k dense
        models in BYTES (dtype-aware)."""
        k = len(client_params)
        nbytes = k * tree_bytes(global_params)
        if self._fresh(k):                 # bitwise-FedAvg fast path
            return fedavg(client_params, sizes), state, nbytes
        w = [s * self.discount(t) for s, t in zip(sizes, self._taus(k))]
        return (self._server_step(global_params, fedavg(client_params, w)),
                state, nbytes)

    def effective_weights(self, weights):
        """n_k -> n_k * s(tau_k) over the full cohort weight vector.  With
        no staleness configured the weights pass through UNTOUCHED (not
        multiplied by 1.0), keeping the fresh path the exact FedAvg
        program — the bitwise-parity contract above."""
        k = int(weights.shape[0])
        taus = self._taus(k)
        if all(t == 0 for t in taus):
            return weights
        d = jnp.asarray([self.discount(t) for t in taus], jnp.float32)
        return weights * d

    def server_update(self, global_params, mean, state, *, k):
        """Move ``server_lr`` of the way from the global model to the
        discounted mean (identity on the fresh path — bitwise FedAvg)."""
        if self._fresh(k):
            return mean, state
        return self._server_step(global_params, mean), state


# ---------------------------------------------------------------------------
# FedProx client objective
# ---------------------------------------------------------------------------

# canonical implementation lives with the other step factories
from repro.models.steps import proximal_penalty  # noqa: E402  (re-export)


def make_fedprox_step(cfg, optimizer, *, mu: float = 0.01, impl: str = "xla",
                      clip_norm: float = 1.0):
    """Train step whose objective adds mu/2 ||w - w_global||^2.  The global
    anchor is passed per call (it changes every round).
    ``step(params, opt_state, anchor, batch)`` — a thin wrapper over
    ``make_train_step(..., prox_mu=mu)``; prefer ``strategy.FedProx``."""
    from repro.models.steps import make_train_step
    return make_train_step(cfg, optimizer, impl=impl, clip_norm=clip_norm,
                           prox_mu=mu)


# ---------------------------------------------------------------------------
# Upload compression (client deltas)
# ---------------------------------------------------------------------------

# canonical delta/byte helpers live in repro.core.strategy
from repro.core.strategy import (tree_add, tree_delta,  # noqa: E402
                                 topk_count)            # (re-export)


def topk_sparsify(delta: Any, frac: float = 0.1):
    """Keep the top-``frac`` fraction of entries per leaf (by magnitude),
    ``k = topk_count(n, frac) = ceil(frac * n)`` — the same k the
    trace-safe ``strategy.topk_compress`` and the static
    ``strategy.topk_bytes`` use.  Returns (sparse_delta, upload_bytes) —
    bytes = kept values (leaf dtype) + int32 indices per entry, the
    standard sparse-upload accounting.  The ``>= thresh`` tie rule can
    keep MORE than k entries, so the byte count is taken from what
    actually survived, not from k."""
    total_bytes = 0

    def one(d):
        nonlocal total_bytes
        n = d.size
        k = topk_count(n, frac)
        flat = d.reshape(-1)
        thresh = jnp.sort(jnp.abs(flat))[n - k]
        kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        # count nonzero survivors: a zero threshold (all-zero leaf, e.g. a
        # frozen layer's delta) would otherwise "keep" the whole leaf
        total_bytes += (max(int(jnp.sum(kept != 0.0)), 1)
                        * (jnp.dtype(d.dtype).itemsize + 4))
        return kept.reshape(d.shape)

    out = jax.tree.map(one, delta)
    return out, total_bytes


def quantize8(delta: Any):
    """Symmetric per-leaf int8 quantization.  Returns (dequantized_delta,
    upload_bytes) — bytes = 1B/entry + one fp32 scale per leaf."""
    total_bytes = 0

    def one(d):
        nonlocal total_bytes
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
        total_bytes += d.size + 4
        return q.astype(jnp.float32) * scale

    out = jax.tree.map(one, delta)
    return out, total_bytes


def dense_bytes(tree: Any) -> int:
    """Dense upload size, dtype-aware (bf16 leaves count 2 B, not 4)."""
    from repro.core.strategy import tree_bytes
    return tree_bytes(tree)


def compressed_fedavg(global_params: Any, client_params: Sequence[Any],
                      sizes: Sequence[float],
                      compressor: Optional[Callable] = None):
    """FedAvg over (optionally compressed) client DELTAS.  Returns
    (new_global, total_upload_bytes)."""
    deltas, bytes_total = [], 0
    for cp in client_params:
        d = tree_delta(cp, global_params)
        if compressor is not None:
            d, b = compressor(d)
        else:
            b = dense_bytes(d)
        deltas.append(d)
        bytes_total += b
    avg_delta = fedavg(deltas, sizes)
    return tree_add(global_params, avg_delta), bytes_total
