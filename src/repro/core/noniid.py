"""Formal non-IIDness in federated pre-training (paper §3.2 + Appendix C).

The three skews over raw-text clients:
    D_Q (Eq. 8)  — quantity:         Q_i = i / sum_j(j) * Q
    D_L (Eq. 9)  — sentence length:  maximize sigma(L_1..L_k), pin others
    D_V (Eq. 10) — vocabulary:       maximize sigma(V_1..V_k), pin others

This module binds partitioner outputs to federated client datasets and
computes the Table-3 statistics; the partitioners themselves live in
``repro.data.partition``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from repro.data.batching import shard_batches
from repro.data.corpus import Document
from repro.data.partition import (SKEWS, ClientPool, client_stats_table,
                                  partition)


def make_client_datasets(docs: Sequence[Document], cfg, *, k: int,
                         skew: str = "iid", batch: int = 8, seq: int = 128,
                         seed: int = 0) -> Dict:
    """-> {"batches": [client_batches...], "sizes": n_k, "steps": local
    steps per epoch, "stats": Table-3}.

    ``sizes`` are the aggregation weights n_k (raw-document counts, Eq. 8);
    ``steps`` are the per-client LOCAL STEP counts one epoch takes
    (``len(batches[k])``) — the per-epoch schedule the wall-clock
    simulator's async replay consumes (``repro.sim.events.simulate_async(
    client_steps=...)``), so quantity skew reaches the staleness process
    even when an engine's recorded ledger is rectangular."""
    if skew not in SKEWS:
        raise ValueError(f"skew must be one of {SKEWS}")
    shards = partition(docs, k, skew, seed=seed)
    batches = [shard_batches(s, cfg, batch, seq, seed=seed + i)
               for i, s in enumerate(shards)]
    sizes = [len(s) for s in shards]            # n_k = raw-text count (Eq. 8)
    return {"batches": batches, "sizes": sizes,
            "steps": [len(b) for b in batches],
            "stats": client_stats_table(shards)}


def make_client_pool(docs: Sequence[Document], cfg, *, n_clients: int,
                     pool: int, skew: str = "iid", batch: int = 8,
                     seq: int = 128, seed: int = 0,
                     limit: int = 0) -> ClientPool:
    """Mega-cohort population: ``n_clients`` VIRTUAL clients served by a
    ``pool``-way partition of the corpus (virtual client k trains pool
    shard k % pool — same skew statistics, cycled).  Pool shards tokenize
    lazily on first access, so a sampled round builds at most ``pool``
    datasets no matter how large ``n_clients`` is; feed the result straight
    to ``FedSession.run`` in place of the materialized batch lists.
    ``limit`` > 0 caps each client's local steps per epoch (the
    ``--max-steps-per-round`` knob)."""
    if skew not in SKEWS:
        raise ValueError(f"skew must be one of {SKEWS}")
    shards = partition(docs, pool, skew, seed=seed)
    builders = [(lambda s=s, i=i: shard_batches(s, cfg, batch, seq,
                                                seed=seed + i))
                for i, s in enumerate(shards)]
    return ClientPool(n_clients, builders, [len(s) for s in shards],
                      limit=limit)
