"""Federated round engines, driven by a pluggable ``FederatedStrategy``.

``FedSession`` runs the full FDAPT/FFDAPT process from Appendix A: init every
client from the global model, run one local epoch per round, aggregate with
the session's strategy, repeat.  Two execution engines with identical math:

  * ``engine="sequential"`` — paper-faithful loop over clients (Flower runs
    clients as processes; we run them as successive jit calls).  Supports
    FFDAPT *static* windows: each (window pattern) compiles once, frozen
    layers truly skip backward dW.
  * ``engine="parallel"``  — the cohort-scan engine.  Participants are
    processed in fixed-size SHARDS of the stacked client axis: one jitted
    per-shard program (clients vmapped inside; the client axis mesh-shards
    via ``sharding/rules.py COHORT_RULES`` at production scale) runs each
    shard's local epochs and folds the shard into the strategy's streaming
    aggregation carry (``aggregate_partial``); a second tiny program
    combines the carry into the new global model (``aggregate_combine``).
    Peak live client state is O(shard), not O(cohort), and the compile
    count is independent of cohort size (one shard program, reused —
    plus one remainder-width program when shard does not divide the
    cohort).  ``RoundPlan.cohort_shard=None`` runs a single full-cohort
    shard — the classic all-clients-one-program vmapped round.  Because
    the aggregation is the canonical client-index left fold
    (``repro.core.fedavg.fedavg_fold``), every ``cohort_shard`` setting
    produces BITWISE the same round (pinned in tests/test_cohort.py).
    FFDAPT runs in *masked* mode here (traced per-client masks — a single
    program for all rounds).

``run`` accepts client data either as the materialized
``client_batches[k]`` lists or as a lazy provider (``data.partition.
ClientPool`` — anything with ``batches_for(k)`` / ``sizes`` /
``max_steps`` / ``__len__``): with a provider, only the sampled cohort's
shards are ever materialized, so million-client populations never build
1M datasets.

The round "what" lives in ``RoundPlan`` (strategy, FFDAPT schedule, client
participation, engine); the engines only supply the "how".  Every round
reports upload bytes and tokens/s in ``RoundResult``, plus a static
compute/comm ledger (``flops_estimate`` / ``hbm_bytes_estimate`` /
``comm_bytes``) derived from a scan-aware HLO analysis of the compiled
client step (``repro.telemetry``) — computed once per distinct program and
cached process-wide, so the per-round cost is a dictionary lookup.
``RoundPlan.simulate`` names a device fleet (``repro.sim``); the engines
then also record each round's per-client replay ledger and its ideal
synchronous wall-clock time on that fleet.

``RoundPlan.checkpoint_dir`` makes the run crash-safe: every
``checkpoint_every`` completed rounds both engines write the full run state
through ``repro.checkpoint`` — global params, the strategy's server-state
pytree (``state_to_tree``), the client-sampling RNG bit-state, the FFDAPT
pointer, and the serialized round history.  ``run(..., resume=True)``
restores all of it and skips the completed rounds; a run killed after any
round and resumed is BITWISE identical to the uninterrupted run (params and
history), on both engines, for every strategy (pinned in
tests/test_resume.py).  Checkpointing happens at round boundaries, where
the paper's schedule holds no optimizer state (optimizers re-init each
round), so params + server state + RNG + pointer IS the whole run state.

Per the paper (Appendix E.1): optimizers are re-initialized at the start of
each round's local training; 1 local epoch per round; 15 rounds.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ffdapt as ffd
from repro.core.accounting import split_bytes
from repro.core.fedavg import broadcast_clients, fedavg_stacked, scalar_fold
from repro.core.strategy import FedAvg, FederatedStrategy
from repro.models.steps import make_masked_train_step
from repro.nn import param as P
from repro.peft.space import ParamSpace, frozen_shippable_template
from repro.obs.metrics import registry as _obs_registry
from repro.obs.profile import record_compile
from repro.obs.trace import span as _obs_span
from repro.telemetry import batch_struct, client_step_cost


@dataclasses.dataclass
class RoundResult:
    round: int
    loss: float
    round_time_s: float
    windows: Optional[List[ffd.Window]] = None
    upload_bytes: int = 0                 # client->server bytes this round
    tokens: float = 0.0                   # tokens trained on this round
    tokens_per_s: float = 0.0
    clients: Optional[List[int]] = None   # participating client ids
    # static ledger from the compiled client step (repro.telemetry).  With
    # telemetry=False the compute terms are zero and comm_bytes keeps only
    # its shape-derived wire components (down broadcast + upload) — the
    # in-step collective term needs the compiled-program analysis.
    flops_estimate: float = 0.0           # dot/conv FLOPs across all clients
    hbm_bytes_estimate: float = 0.0       # HBM traffic across all clients
    comm_bytes: int = 0                   # down broadcast + upload [+ in-step
                                          # collective bytes, telemetry only]
    download_bytes: int = 0               # server->client bytes this round
    # per-client replay ledger (aligned with ``clients``) — what the
    # wall-clock simulator (repro.sim) needs to place each client's local
    # work on a heterogeneous device: local step count, per-STEP compute
    # terms (FFDAPT windows differ per client), and wire bytes.
    client_steps: Optional[List[int]] = None
    client_step_flops: Optional[List[float]] = None
    client_step_hbm: Optional[List[float]] = None
    client_upload_bytes: Optional[List[int]] = None
    # filled when RoundPlan.simulate is set: ideal (dropout-free) sync
    # round seconds on the plan's fleet (repro.sim.clock.sync_round_s)
    sim_round_s: float = 0.0
    # plan.eval_fn(params) after this round's aggregation; ``loss`` always
    # keeps the round's TRAIN loss (eval used to overwrite it)
    eval_loss: Optional[float] = None

    def to_json(self) -> Dict[str, Any]:
        """JSON-able dict (tuples become lists); ``from_json`` round-trips
        exactly — floats survive via repr, so a serialized history replays
        and compares bitwise."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "RoundResult":
        names = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in names}
        if d.get("windows") is not None:
            d["windows"] = [(int(s), int(n)) for s, n in d["windows"]]
        return cls(**d)


@dataclasses.dataclass
class RoundPlan:
    """Everything that defines a federated run except model/opt/data."""

    n_rounds: int = 15
    engine: str = "sequential"            # sequential | parallel
    impl: str = "xla"
    # cohort-scan shard size for the parallel engine: at most this many
    # clients are live at once (params/opt-state/batches stacked per shard;
    # the streaming aggregation carry is O(params)).  None = one full-cohort
    # shard (the classic vmapped round).  Any value produces bitwise the
    # same result — the fold reduction is shard-invariant and the schedule
    # never emits a width-1 shard (``_shard_widths``: clamps to >= 2,
    # absorbs a lone remainder) — so this is a pure memory/compile knob,
    # deliberately NOT part of the checkpoint fingerprint (a run may be
    # resumed under a different shard size).
    cohort_shard: Optional[int] = None
    strategy: FederatedStrategy = dataclasses.field(default_factory=FedAvg)
    ffdapt: Optional[ffd.FFDAPTConfig] = None
    # trainable/shippable subspace (repro.peft.ParamSpace).  None resolves to
    # ``frozen_window`` when an FFDAPT schedule is set, else ``full`` — both
    # run literally the pre-ParamSpace engine paths (bitwise; pinned in
    # tests/test_peft.py).  A low-rank space (lora/adapter) turns the
    # strategy's params tree into the factor BANK: aggregation, compression,
    # upload/download accounting, the cohort-scan carry and the checkpoint
    # server state all live in subspace coordinates, and the frozen base
    # rides into the client step as a separate traced argument.  Low-rank
    # does not compose with ``ffdapt`` (two ownership claims on the same
    # update masking — ``run`` raises).
    param_space: Optional[ParamSpace] = None
    participation: float = 1.0            # fraction of clients per round
    seed: int = 0                         # client-sampling seed
    client_sizes: Optional[Sequence[int]] = None   # n_k; default batch counts
    eval_fn: Optional[Callable[[Any], float]] = None
    telemetry: bool = True                # per-round compute/comm ledger
    # wall-clock simulation hook: a repro.sim Fleet, a named-fleet string
    # ("edge-mixed", ...), or a {preset: weight} mixture.  When set, every
    # RoundResult carries sim_round_s — the ideal synchronous round time on
    # that fleet (slowest sampled client; requires telemetry=True for the
    # compute terms).  Deadline/async schedules are post-hoc replays:
    # repro.sim.events.simulate(history, fleet, mode=...) — the async one
    # consumes the ledger's PER-CLIENT step schedule, so quantity skew
    # shows up as staleness.
    simulate: Optional[Any] = None
    # clock mode for sim_round_s: False = sequential down/compute/up sum,
    # True = pipelined overlap clock (repro.sim.clock).
    overlap: bool = False
    # crash-safe checkpointing (repro.checkpoint): when set, both engines
    # write the full run state (params + server state + RNG + FFDAPT
    # pointer + history) every ``checkpoint_every`` completed rounds, plus
    # at the final round and before a ``stop_after_round`` halt; ``_rotate``
    # keeps the newest ``checkpoint_keep``.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    checkpoint_keep: int = 3
    # preemption hook (tests / the resume smoke): return after completing
    # this many rounds, as if the process were killed right after the
    # checkpoint — resume picks up the remaining rounds.
    stop_after_round: Optional[int] = None
    # extra JSON-able identity merged into the checkpoint plan fingerprint
    # and verified on resume.  The session can fingerprint its own plan but
    # not the optimizer (closures) or the data pipeline — the caller pins
    # those here (train.py records lr/arch/batch/seq/docs/skew).
    fingerprint_extra: Optional[Dict[str, Any]] = None


def _epoch(step, params, opt_state, batches: Sequence[Dict[str, Any]],
           *extra):
    """One local epoch.  ``extra`` args splice between opt_state and the
    batch: ``(anchor,)`` for FedProx, ``(base,)`` for PEFT steps (the frozen
    base model), ``(base, anchor)`` for both."""
    losses, toks = [], []
    for b in batches:
        params, opt_state, m = step(params, opt_state, *extra, b)
        losses.append(m["loss"])
        toks.append(m["tokens"])
    return (params, opt_state, float(jnp.mean(jnp.stack(losses))),
            float(jnp.sum(jnp.stack(toks))))


def _participants(rng, k: int, participation: float) -> List[int]:
    """Sample the round's cohort: m of k clients, without replacement, in
    O(m) memory via Floyd's algorithm — ``rng.choice(k, replace=False)``
    materializes a k-length permutation, which at million-client
    populations dominates the round's host memory.  The draw consumes the
    generator deterministically (one vectorized ``integers`` call), so the
    PR 5 resume contract holds: restoring the checkpointed RNG bit-state
    reproduces the exact cohort sequence."""
    if participation >= 1.0:
        return list(range(k))
    m = max(1, int(round(participation * k)))
    if m >= k:
        return list(range(k))
    # Floyd: for j = k-m .. k-1, draw t in [0, j]; take t unless already
    # chosen, else take j.  Each j is chosen with probability m/k, uniform
    # over all m-subsets.  The m draws vectorize into one generator call.
    ts = rng.integers(0, np.arange(k - m + 1, k + 1))
    chosen: set = set()
    for j, t in zip(range(k - m, k), ts.tolist()):
        chosen.add(t if t not in chosen else j)
    return sorted(chosen)


class _ListClientData:
    """Adapter giving materialized ``client_batches`` lists the lazy
    provider interface the engines consume (``ClientPool`` is the
    million-client implementation; see ``repro.data.partition``)."""

    def __init__(self, client_batches: List[List[Dict[str, Any]]]):
        self._batches = client_batches

    def __len__(self) -> int:
        return len(self._batches)

    @property
    def sizes(self) -> List[int]:
        return [len(bs) for bs in self._batches]

    @property
    def max_steps(self) -> int:
        return max(len(bs) for bs in self._batches)

    def batches_for(self, k: int) -> List[Dict[str, Any]]:
        return self._batches[k]


def _as_client_data(client_batches) -> Any:
    if hasattr(client_batches, "batches_for"):
        return client_batches
    return _ListClientData(client_batches)


def _shard_widths(m: int, shard: Optional[int]) -> List[int]:
    """Cohort-scan shard schedule: widths summing to ``m``, each ``shard``
    except the tail.  Two rules keep every schedule BITWISE equal to the
    full-width program: no shard is ever width 1 (XLA lowers a degenerate
    single-client vmap differently — its lanes come out a ulp off the
    width>=2 programs, which are all per-lane identical), so the requested
    width clamps to >= 2 and a remainder of 1 is absorbed into the last
    shard (width ``shard + 1``) instead of trailing alone.  At most two
    distinct widths -> at most two shard-program compiles per session."""
    if shard is None or shard >= m:
        return [m]
    shard = max(2, shard)
    if shard >= m:
        return [m]
    widths = [shard] * (m // shard)
    r = m % shard
    if r == 1:
        widths[-1] += 1
    elif r:
        widths.append(r)
    return widths


def _stack_shard(data, ids: Sequence[int], max_steps: int):
    """Materialize ONE shard's rectangular batch block: (shard, steps,
    B, ...) per leaf.  Short clients pad by CYCLING their local batches
    (same rule the full-width engine always used), and only this shard's
    clients are ever resident."""
    per_client = []
    for k in ids:
        bs = data.batches_for(k)
        padded = [bs[i % len(bs)] for i in range(max_steps)]
        per_client.append(jax.tree.map(lambda *xs: jnp.stack(xs), *padded))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)


def _record_round_metrics(rr: "RoundResult") -> None:
    """Bank one round into the process-wide metrics registry (counters +
    the round-seconds histogram ``--metrics-out`` exports).  Host floats
    only — negligible next to a round."""
    reg = _obs_registry()
    reg.counter("train.rounds").inc()
    reg.counter("train.tokens").inc(rr.tokens)
    reg.counter("train.upload_bytes").inc(rr.upload_bytes)
    reg.counter("train.comm_bytes").inc(rr.comm_bytes)
    reg.histogram("train.round_s").observe(rr.round_time_s)
    reg.gauge("train.last_loss").set(rr.loss)


class FedSession:
    """A federated training session: ``FedSession(cfg, opt, plan).run(...)``.

    Construct with a ``RoundPlan`` or with plan fields as kwargs:
    ``FedSession(cfg, opt, n_rounds=3, strategy=FedProx(mu=0.01))``.
    """

    def __init__(self, cfg, optimizer, plan: Optional[RoundPlan] = None,
                 **plan_overrides):
        if plan is None:
            plan = RoundPlan(**plan_overrides)
        elif plan_overrides:
            plan = dataclasses.replace(plan, **plan_overrides)
        self.cfg = cfg
        self.optimizer = optimizer
        self.plan = plan

    def run(self, params, client_batches, *, resume: bool = False):
        """Returns (final_params, [RoundResult...]).

        ``client_batches`` is either the materialized lists —
        ``client_batches[k]`` = that client's local batches for one epoch
        (re-used each round — the paper re-iterates the local dataset every
        round) — or a lazy provider (``repro.data.partition.ClientPool``)
        exposing ``batches_for(k)`` / ``sizes`` / ``max_steps`` /
        ``__len__``, under which only sampled cohorts materialize.
        ``plan.client_sizes`` defaults to per-client batch counts (n_k of
        Algorithm 1).

        ``resume=True`` restores the latest checkpoint in
        ``plan.checkpoint_dir`` (params, server state, RNG position, FFDAPT
        pointer, history) and runs only the remaining rounds; without a
        checkpoint on disk it starts fresh.  The resumed run is bitwise
        identical to the uninterrupted one.
        """
        plan = self.plan
        space = plan.param_space
        if space is None:
            # implicit spaces: FFDAPT plans ARE frozen_window, all others
            # full — resolution changes nothing about the executed program
            space = ParamSpace("frozen_window" if plan.ffdapt else "full")
        peft = space.low_rank
        if peft and plan.ffdapt is not None:
            raise ValueError(
                f"param space {space.kind!r} does not compose with "
                f"plan.ffdapt frozen windows — both claim the update mask; "
                f"pick one")
        self._space, self._peft = space, peft
        data = _as_client_data(client_batches)
        sizes = (list(plan.client_sizes) if plan.client_sizes is not None
                 else list(data.sizes))
        # the client population is part of the checkpoint fingerprint:
        # resuming over different clients/weights must raise, not diverge
        self._run_sizes = sizes
        if peft and not (isinstance(params, dict)
                         and set(params) == {"base", "peft"}):
            # seed the bank deterministically from the plan seed: a resumed
            # run rebuilds the same template, and two runs with one seed get
            # one bank init (B factors are zero, so round 0 starts from the
            # base model exactly)
            params = {"base": params,
                      "peft": space.inject(params,
                                           jax.random.PRNGKey(plan.seed))}
        from repro.models.model import n_freeze_units
        n_units = n_freeze_units(self.cfg)
        windows = (ffd.schedule(n_units, sizes, plan.n_rounds,
                                epsilon=plan.ffdapt.epsilon,
                                gamma=plan.ffdapt.gamma)
                   if plan.ffdapt else None)
        start, state, rng, history = 0, None, None, None
        if resume:
            got = self._restore(params, windows, n_units)
            if got is not None:
                start, params, state, rng, history = got
        elif plan.checkpoint_dir:
            # a fresh run must not write into a directory that already
            # holds checkpoints: the new rounds would sort OLDEST and be
            # rotated away, and a later resume would silently pick up the
            # stale run's state instead of this one's
            from repro.checkpoint import latest_step
            have = latest_step(plan.checkpoint_dir)
            if have is not None:
                raise ValueError(
                    f"checkpoint_dir {plan.checkpoint_dir!r} already holds "
                    f"round checkpoints (latest {have}) — pass resume=True "
                    f"to continue that run, or use a fresh directory")
        if start >= plan.n_rounds:
            if peft:
                return space.merge(params["base"], params["peft"]), history or []
            return params, history or []
        if plan.engine == "sequential":
            return self._run_sequential(params, data, sizes,
                                        windows, n_units, start=start,
                                        state=state, rng=rng, history=history)
        if plan.engine == "parallel":
            return self._run_parallel(params, data, sizes,
                                      windows, n_units, start=start,
                                      state=state, rng=rng, history=history)
        raise ValueError(plan.engine)

    # -----------------------------------------------------------------
    # Checkpoint / resume (shared by both engines)
    # -----------------------------------------------------------------

    def _ckpt_plan_fingerprint(self) -> Dict[str, Any]:
        # n_rounds is recorded for information only (resuming with a larger
        # n_rounds legitimately extends the run); everything else must
        # match or the resumed math would silently diverge.  The strategy
        # fingerprint carries its full hyperparameters (strategies are
        # frozen dataclasses; Compressed recurses into its inner) — name
        # alone would let e.g. FedAvgM(beta=0.5) resume a beta=0.9 run.
        # JSON-normalized so the fresh fingerprint compares equal to one
        # read back from the sidecar (tuples -> lists, float repr).
        plan = self.plan
        strat = {"name": plan.strategy.name,
                 **dataclasses.asdict(plan.strategy)}
        sizes = [int(s) for s in getattr(self, "_run_sizes", [])]
        if len(sizes) > 4096:
            # mega-cohort populations: fingerprint the size vector by
            # digest, not value — a million-entry list would dominate every
            # checkpoint sidecar.  Deterministic, so fresh and restored
            # fingerprints still compare equal.
            import hashlib
            sizes = {"n": len(sizes),
                     "sha256": hashlib.sha256(
                         np.asarray(sizes, np.int64).tobytes()).hexdigest()}
        fp = {"strategy": strat, "engine": plan.engine, "impl": plan.impl,
              "seed": plan.seed, "participation": plan.participation,
              "ffdapt": (dataclasses.asdict(plan.ffdapt)
                         if plan.ffdapt else None),
              "client_sizes": sizes,
              # recorded for information, like n_rounds — NOT resume-
              # enforced: the fold aggregation is shard-invariant, so a
              # run may legitimately resume under a different cohort_shard
              # (pinned bitwise in tests/test_cohort.py)
              "cohort_shard": plan.cohort_shard,
              # the trainable subspace decides both the archive layout
              # (low-rank runs store base + bank) and the executed math —
              # resuming a rank-4 LoRA run as rank-8 (or as full) must raise
              "param_space": (plan.param_space.to_json()
                              if plan.param_space is not None else None),
              # telemetry/simulate/overlap don't move the params, but they
              # decide the history's ledger columns — a resumed run must
              # fill them the same way or the prefix and suffix disagree
              "telemetry": plan.telemetry, "overlap": plan.overlap,
              "simulate": self._simulate_fingerprint(),
              "extra": plan.fingerprint_extra,
              "n_rounds": plan.n_rounds}
        return json.loads(json.dumps(fp))

    def _simulate_fingerprint(self):
        """plan.simulate's identity for the fingerprint.  A Fleet is
        fingerprinted by its full device composition, not just its name —
        two same-named fleets (e.g. "edge-mixed" datasheet vs calibrated,
        or any two sample_fleet mixtures, both named "custom") would
        otherwise resume into each other and desync sim_round_s between
        the restored prefix and the resumed suffix."""
        sim = self.plan.simulate
        if sim is not None and hasattr(sim, "devices"):
            return {"name": getattr(sim, "name", None),
                    "devices": [dataclasses.asdict(d) for d in sim.devices]}
        return sim

    def _restore(self, params, windows, n_units):
        """Load the newest checkpoint in ``plan.checkpoint_dir``; None when
        the directory holds none (fresh start).  Raises on a checkpoint
        written under an incompatible plan — resuming with a different
        strategy/seed/participation would silently change the math."""
        plan, strategy = self.plan, self.plan.strategy
        if not plan.checkpoint_dir:
            raise ValueError("resume=True needs plan.checkpoint_dir")
        from repro.checkpoint import (latest_step, restore_checkpoint,
                                      restore_extra)
        from repro.checkpoint.npz import FederatedState
        step = latest_step(plan.checkpoint_dir)
        if step is None:
            return None
        meta = restore_extra(plan.checkpoint_dir, step)
        if meta is None or "round" not in meta or "history" not in meta:
            raise ValueError(
                f"checkpoint {step} in {plan.checkpoint_dir!r} is not a "
                f"resumable round checkpoint (no FederatedState sidecar — "
                f"written by an older final-snapshot save?)")
        fed = FederatedState.from_json(meta)
        if fed.plan:
            mine = self._ckpt_plan_fingerprint()
            for key in ("strategy", "engine", "impl", "seed",
                        "participation", "ffdapt", "client_sizes",
                        "param_space", "telemetry", "overlap", "simulate",
                        "extra"):
                if key in fed.plan and fed.plan[key] != mine[key]:
                    raise ValueError(
                        f"checkpoint was written under a different plan: "
                        f"{key}={fed.plan[key]!r} != {mine[key]!r}")
        if windows is not None and fed.round < len(windows):
            want = windows[fed.round][0][0]
            if fed.ffdapt_start != want:
                raise ValueError(
                    f"checkpoint FFDAPT pointer {fed.ffdapt_start} does not "
                    f"match the plan's schedule ({want} at round "
                    f"{fed.round}) — client sizes or gamma/epsilon changed")
        # low-rank runs aggregate in bank coordinates: the server-state
        # template must be built over the bank, while the params template
        # keeps the combined {base, peft} layout the archive stores
        agg_tmpl = params["peft"] if self._peft else params
        template = {
            "params": jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params),
            "server": strategy.state_to_tree(strategy.init_state(agg_tmpl))}
        tree = restore_checkpoint(plan.checkpoint_dir, step, template)
        state = strategy.state_from_tree(tree["server"])
        rng = np.random.default_rng(plan.seed)
        if fed.rng_state is not None:
            rng.bit_generator.state = fed.rng_state
        history = [RoundResult.from_json(h) for h in fed.history]
        return fed.round, tree["params"], state, rng, history

    def _checkpoint(self, t, params, state, rng, history, windows, n_units):
        """Write the full run state after round ``t`` when due: every
        ``checkpoint_every`` rounds, at the final round, and right before a
        ``stop_after_round`` halt (so the simulated preemption always
        leaves a resumable checkpoint behind)."""
        plan, strategy = self.plan, self.plan.strategy
        if not plan.checkpoint_dir:
            return
        done = t + 1
        due = (done % max(plan.checkpoint_every, 1) == 0
               or done == plan.n_rounds or done == plan.stop_after_round)
        if not due:
            return
        from repro.checkpoint import save_checkpoint
        from repro.checkpoint.npz import FederatedState
        if windows is None:
            ptr = 0
        elif done < len(windows):
            ptr = windows[done][0][0]
        else:
            s, nf = windows[-1][-1]
            ptr = (s + nf) % max(n_units, 1)
        fed = FederatedState(
            round=done, ffdapt_start=ptr,
            rng_state=rng.bit_generator.state,
            history=[h.to_json() for h in history],
            plan=self._ckpt_plan_fingerprint())
        with _obs_span("train.checkpoint", cat="train", round=t):
            save_checkpoint(
                plan.checkpoint_dir, done,
                {"params": params, "server": strategy.state_to_tree(state)},
                extra=fed.to_json(), keep=plan.checkpoint_keep)
        _obs_registry().counter("train.checkpoints").inc()

    # -----------------------------------------------------------------
    # Sequential (paper-faithful; static FFDAPT windows)
    # -----------------------------------------------------------------

    def _step_for(self, frozen):
        # Keyed on the strategy's CLIENT-STEP identity, not the strategy
        # itself: FedAvg/FedAvgM/Compressed share one compiled program,
        # FedProx compiles per distinct mu.  Keys hold strong refs to
        # cfg/optimizer, so a GC'd optimizer can never alias a live cache
        # entry (the old ``id(optimizer.update)`` key could, after id reuse).
        # The subspace keys the cache through ``space.step_key``: full and
        # frozen_window return ``frozen`` verbatim, so their entries (and
        # compiled programs) are IDENTICAL to pre-ParamSpace sessions;
        # low-rank spaces key on (kind, rank, alpha, targets).
        space = getattr(self, "_space", None)
        skey = space.step_key(frozen) if space is not None else frozen
        key = (self.cfg, self.optimizer, self.plan.strategy.client_step_key(),
               skey, self.plan.impl)
        if key not in _STEP_CACHE:
            # a cache miss means the next call traces+compiles a new client
            # program — mark it so the trace shows which round paid it
            record_compile("client_step",
                           strategy=self.plan.strategy.name,
                           impl=self.plan.impl)
            kw = {}
            if space is not None and space.low_rank:
                kw["space"] = space
            _STEP_CACHE[key] = jax.jit(self.plan.strategy.make_client_step(
                self.cfg, self.optimizer, frozen=frozen, impl=self.plan.impl,
                **kw))
        return _STEP_CACHE[key]

    def _client_upload_bytes(self, params, part, windows, n_units, t):
        """Per-client upload ledger + round total.  FFDAPT rounds price each
        client at its SHIPPABLE subspace (unfrozen layer rows — the ROADMAP
        'frozen-window masking costs full-tree traffic' fix); the strategy's
        tree-generic byte formulas make this compose with top-k/int8.  For
        every other space ``params`` is already the shipped tree (the bank,
        under low-rank), so the strategy's round total splits evenly."""
        strategy = self.plan.strategy
        if windows is not None:
            per = [strategy.upload_bytes(
                frozen_shippable_template(
                    self.cfg, params, ffd.window_mask(n_units, windows[t][k])),
                1) for k in part]
            return per, sum(per)
        nbytes = strategy.upload_bytes(params, len(part))
        return split_bytes(nbytes, len(part)), nbytes

    def _step_cost(self, batch, *, frozen=None, masked=False):
        """Cached telemetry for ONE client step of this session's program
        family (same cache cardinality as the compiled-step cache)."""
        space = getattr(self, "_space", None)
        return client_step_cost(self.cfg, self.optimizer, self.plan.strategy,
                                batch_struct(batch), frozen=frozen,
                                masked=masked, impl=self.plan.impl,
                                space=space if getattr(self, "_peft", False)
                                else None)

    def _fleet(self, n_clients: int):
        """Resolve plan.simulate into a repro.sim Fleet (None = no sim)."""
        if self.plan.simulate is None:
            return None
        from repro.sim.clock import resolve_fleet
        return resolve_fleet(self.plan.simulate, n_clients, self.plan.seed)

    def _run_sequential(self, params, data, sizes, windows,
                        n_units, *, start=0, state=None, rng=None,
                        history=None):
        plan, optimizer, strategy = self.plan, self.optimizer, self.plan.strategy
        space, peft = self._space, self._peft
        base = None
        if peft:
            # from here on ``params`` IS the bank: the strategy aggregates,
            # prices and checkpoints subspace coordinates; the frozen base
            # enters the client step as an extra traced argument
            base, params = params["base"], params["peft"]
        rng = np.random.default_rng(plan.seed) if rng is None else rng
        state = strategy.init_state(params) if state is None else state
        fleet = self._fleet(len(data))
        history = [] if history is None else history
        for t in range(start, plan.n_rounds):
            # loop-ENTRY guard: a resumed run whose restored rounds already
            # reach the threshold halts immediately (stop_after_round=r
            # means "at most r completed rounds", fresh or resumed)
            if (plan.stop_after_round is not None
                    and t >= plan.stop_after_round):
                break
            with _obs_span("train.round", cat="train", round=t,
                           engine="sequential"):
                t0 = time.perf_counter()
                part = _participants(rng, len(data), plan.participation)
                down = strategy.download_bytes(params, len(part))
                locals_, losses, tokens = [], [], 0.0
                flops_e = hbm_e = coll_e = 0.0
                c_steps, c_flops, c_hbm = [], [], []
                for k in part:
                    frozen = None
                    if windows is not None:
                        frozen = ffd.window_mask(n_units, windows[t][k])
                    bs_k = data.batches_for(k)
                    steps_k = len(bs_k)
                    c_steps.append(steps_k)
                    if plan.telemetry:
                        cost = self._step_cost(bs_k[0], frozen=frozen)
                        c_flops.append(cost.flops)
                        c_hbm.append(cost.hbm_bytes)
                        flops_e += cost.flops * steps_k
                        hbm_e += cost.hbm_bytes * steps_k
                        coll_e += cost.collective_bytes * steps_k
                    opt_state = P.unbox(optimizer.init(params))
                    extra = (base,) if peft else ()
                    if strategy.needs_anchor:
                        extra += (params,)   # round-global anchor (the bank,
                                             # under low-rank — FedProx pulls
                                             # toward the global subspace)
                    # dispatch span = one client's whole local epoch (the
                    # sequential engine's unit of dispatch); jit calls sync
                    # per batch, so this measures real compute
                    with _obs_span("train.dispatch", cat="train", round=t,
                                   client=k, steps=steps_k):
                        p_k, _, loss, tok = _epoch(self._step_for(frozen),
                                                   params, opt_state, bs_k,
                                                   *extra)
                    locals_.append(p_k)
                    losses.append(loss)
                    tokens += tok
                with _obs_span("train.aggregate", cat="train", round=t,
                               clients=len(part)):
                    params, state, nbytes = strategy.aggregate(
                        params, locals_, [sizes[k] for k in part], state)
                dt = time.perf_counter() - t0
            if windows is not None:
                # FFDAPT accounting fix: clients ship only their unfrozen
                # layer rows, so the round total is the sum of per-client
                # subspace prices — not the aggregate()'s full-tree figure
                c_up, nbytes = self._client_upload_bytes(
                    params, part, windows, n_units, t)
            else:
                # aggregate() reports the exact round total; per-client
                # shares are the static even split + remainder (Compressed
                # tie-keeps can skew individual clients by a few entries,
                # but the shares always sum to the exact round total)
                c_up = split_bytes(nbytes, len(part))
            rr = RoundResult(
                t, float(np.mean(losses)), dt,
                windows[t] if windows else None,
                upload_bytes=nbytes, tokens=tokens,
                tokens_per_s=tokens / max(dt, 1e-9), clients=part,
                flops_estimate=flops_e, hbm_bytes_estimate=hbm_e,
                comm_bytes=down + nbytes + int(coll_e),
                download_bytes=down, client_steps=c_steps,
                client_step_flops=c_flops or None,
                client_step_hbm=c_hbm or None,
                client_upload_bytes=c_up)
            if fleet is not None:
                from repro.sim.clock import sync_round_s
                rr.sim_round_s = sync_round_s(rr, fleet,
                                              overlap=plan.overlap)
            if plan.eval_fn is not None:
                rr.eval_loss = float(plan.eval_fn(
                    space.merge(base, params) if peft else params))
            history.append(rr)
            _record_round_metrics(rr)
            self._checkpoint(t, {"base": base, "peft": params} if peft
                             else params, state, rng, history, windows,
                             n_units)
        return (space.merge(base, params) if peft else params), history

    # -----------------------------------------------------------------
    # Parallel (cohort-scan engine; masked FFDAPT)
    # -----------------------------------------------------------------

    def _run_parallel(self, params, data, sizes, windows, n_units,
                      *, start=0, state=None, rng=None, history=None):
        plan, optimizer, strategy = self.plan, self.optimizer, self.plan.strategy
        space, peft = self._space, self._peft
        base = None
        if peft:
            # bank-as-params: the stacked client state, the streaming
            # aggregation carry and the combine program are all O(bank);
            # the base is one unstacked donated-in argument per shard call
            base, params = params["base"], params["peft"]
        K = len(data)
        # rectangular schedule: pad short clients by CYCLING their local
        # batches (quantity skew -> unequal local steps); the n_k
        # aggregation weights stay the true sizes.  NOTE: cycling means a
        # short client re-iterates its data within the round (>1 local
        # epoch), so sequential/parallel only match exactly when all
        # clients have equal step counts; RoundResult.tokens counts the
        # repeats (they were trained on).
        max_steps = data.max_steps

        use_mask = windows is not None
        step_kw = {"space": space} if peft else {}
        client_step = strategy.make_client_step(
            self.cfg, optimizer, masked=use_mask, impl=plan.impl, **step_kw)
        needs_anchor = strategy.needs_anchor

        # traced (= compiled) shard-program count this session: the
        # compile-count invariant tests/test_cohort.py pins — one program
        # per distinct shard WIDTH (so 1, or 2 when the shard size does
        # not divide the cohort), never one per shard or per round.
        self.shard_compiles = 0

        def _fed_shard(global_params, base_params, partial, loss_acc,
                       tok_acc, bsub, fmasks, w_agg, w_loss):
            """One cohort shard: vmapped local epochs + streaming fold.

            ``global_params`` is the aggregated tree (the BANK under a
            low-rank space, with ``base_params`` the frozen base — None,
            an empty pytree, otherwise); ``partial``/``loss_acc``/
            ``tok_acc`` are the round's carries; ``w_agg`` is this shard's
            slice of the cohort-normalized aggregation weights, ``w_loss``
            the raw-normalized loss weights.  Traced once per shard width
            (jit caches on shapes).
            """
            self.shard_compiles += 1          # trace-time, not per call
            ksub = fmasks.shape[0]
            # emit the compile as a trace event too: the Perfetto timeline
            # then shows WHICH round/shard width paid each trace (the
            # shard_compiles counter alone only says how many)
            record_compile("shard_program", width=int(ksub))
            stacked = broadcast_clients(global_params, ksub)
            opts = jax.vmap(lambda p: P.unbox(optimizer.init(p)))(stacked)

            def client_epoch(p, o, bs, fm):
                def one(carry, b):
                    p_, o_ = carry
                    args = (p_, o_)
                    if base_params is not None:
                        args += (base_params,)
                    if needs_anchor:
                        args += (global_params,)
                    args += (b,)
                    if use_mask:
                        args += (fm,)
                    p_, o_, m = client_step(*args)
                    return (p_, o_), (m["loss"], m["tokens"])

                (p, o), (ls, toks) = jax.lax.scan(one, (p, o), bs)
                return p, jnp.mean(ls), jnp.sum(toks)

            p_k, losses, toks = jax.vmap(client_epoch)(stacked, opts, bsub,
                                                       fmasks)
            partial = strategy.aggregate_partial(global_params, p_k, w_agg,
                                                 partial)
            return (partial, scalar_fold(loss_acc, losses * w_loss),
                    scalar_fold(tok_acc, toks))

        fed_shard = jax.jit(_fed_shard)

        @jax.jit
        def norm_weights(w):
            """Both weight normalizations, over the FULL cohort vector
            before any sharding — every shard folds with weights the whole
            cohort normalized, exactly like the full-width program."""
            we = strategy.effective_weights(w)
            return we / jnp.sum(we), w / jnp.sum(w)

        combine_cache: Dict[int, Callable] = {}

        def _combine_for(m: int):
            # aggregate_combine takes the cohort size statically (AsyncFedAvg
            # resolves its fresh path on it); participation keeps m constant
            # across rounds, so this compiles once per session
            if m not in combine_cache:
                combine_cache[m] = jax.jit(
                    lambda gp, pa, st: strategy.aggregate_combine(
                        gp, pa, st, k=m))
            return combine_cache[m]

        rng = np.random.default_rng(plan.seed) if rng is None else rng
        w_all = jnp.asarray(sizes, jnp.float32)
        state = strategy.init_state(params) if state is None else state
        # one program family for the whole session: a single cached analysis
        # covers every round (masked FFDAPT has no per-window programs)
        step_cost = (self._step_cost(data.batches_for(0)[0], masked=use_mask)
                     if plan.telemetry else None)
        fleet = self._fleet(K)
        history = [] if history is None else history
        for t in range(start, plan.n_rounds):
            # loop-ENTRY guard: a resumed run whose restored rounds already
            # reach the threshold halts immediately (stop_after_round=r
            # means "at most r completed rounds", fresh or resumed)
            if (plan.stop_after_round is not None
                    and t >= plan.stop_after_round):
                break
            with _obs_span("train.round", cat="train", round=t,
                           engine="parallel"):
                t0 = time.perf_counter()
                part = _participants(rng, K, plan.participation)
                m = len(part)
                w = w_all if m == K else w_all[jnp.asarray(part, jnp.int32)]
                w_agg, w_loss = norm_weights(w)
                partial = strategy.aggregate_init(params)
                loss_acc = jnp.zeros((), jnp.float32)
                tok_acc = jnp.zeros((), jnp.float32)
                off = 0
                for si, width in enumerate(_shard_widths(m,
                                                         plan.cohort_shard)):
                    ids = part[off:off + width]
                    # dispatch span = shard materialization + the async jit
                    # dispatch (device work may still be in flight when it
                    # closes; the round span is bounded by block_until_ready)
                    with _obs_span("train.dispatch", cat="train", round=t,
                                   shard=si, width=width):
                        bsub = _stack_shard(data, ids, max_steps)
                        if windows is not None:
                            fmasks = jnp.stack([
                                jnp.asarray(ffd.window_mask(n_units,
                                                            windows[t][k]),
                                            jnp.float32) for k in ids])
                        else:
                            fmasks = jnp.zeros((len(ids), n_units),
                                               jnp.float32)
                        partial, loss_acc, tok_acc = fed_shard(
                            params, base, partial, loss_acc, tok_acc, bsub,
                            fmasks, w_agg[off:off + width],
                            w_loss[off:off + width])
                    off += width
                with _obs_span("train.aggregate", cat="train", round=t,
                               clients=m):
                    params, state = _combine_for(m)(params, partial, state)
                    loss, toks = loss_acc, tok_acc
                    # async dispatch would under-time the round
                    jax.block_until_ready(loss)
                dt = time.perf_counter() - t0
            toks = float(toks)
            c_up, nbytes = self._client_upload_bytes(params, part, windows,
                                                     n_units, t)
            # rectangular schedule: every participant runs max_steps steps
            # (short clients cycle their data), so the ledger multiplies the
            # single analyzed program by steps x participants
            n_steps = max_steps * len(part)
            down = strategy.download_bytes(params, len(part))
            rr = RoundResult(
                t, float(loss), dt, windows[t] if windows else None,
                upload_bytes=nbytes,
                tokens=toks, tokens_per_s=toks / max(dt, 1e-9), clients=part,
                flops_estimate=(step_cost.flops * n_steps
                                if step_cost else 0.0),
                hbm_bytes_estimate=(step_cost.hbm_bytes * n_steps
                                    if step_cost else 0.0),
                comm_bytes=(down + nbytes
                            + int(step_cost.collective_bytes * n_steps
                                  if step_cost else 0)),
                download_bytes=down,
                client_steps=[max_steps] * len(part),
                client_step_flops=([step_cost.flops] * len(part)
                                   if step_cost else None),
                client_step_hbm=([step_cost.hbm_bytes] * len(part)
                                 if step_cost else None),
                client_upload_bytes=c_up)
            if fleet is not None:
                from repro.sim.clock import sync_round_s
                rr.sim_round_s = sync_round_s(rr, fleet,
                                              overlap=plan.overlap)
            if plan.eval_fn is not None:
                rr.eval_loss = float(plan.eval_fn(
                    space.merge(base, params) if peft else params))
            history.append(rr)
            _record_round_metrics(rr)
            self._checkpoint(t, {"base": base, "peft": params} if peft
                             else params, state, rng, history, windows,
                             n_units)
        return (space.merge(base, params) if peft else params), history


# process-wide program cache: one compiled step per distinct
# (config, optimizer, strategy, frozen pattern, impl) — rotation reuses at
# most N programs, and repeated sessions (benchmarks, resumed runs) pay zero
# recompiles.
_STEP_CACHE: Dict[Any, Callable] = {}


def make_fed_round_program(cfg, optimizer, *, impl: str = "xla"):
    """ONE federated round as a single jit-able program for the production
    mesh: every client runs its local epoch simultaneously (client dim
    sharded over the ``pod`` axis via FED_RULES), then FedAvg aggregates with
    one weighted all-reduce over clients — cross-pod DCN traffic, exactly the
    WAN aggregation the paper's Flower server performs.

    fed_round(stacked_params (K,...), stacked_opt, batches (K,steps,B,S...),
              fmasks (K, n_units), sizes (K,)) ->
        (new stacked params, per-client losses)
    FFDAPT runs in masked mode here (traced per-client windows)."""
    step = make_masked_train_step(cfg, optimizer, impl=impl)

    def fed_round(stacked_params, stacked_opt, batches, fmasks, sizes):
        K = jax.tree.leaves(stacked_params)[0].shape[0]

        def client_epoch(p, o, bs, fm):
            def one(carry, b):
                p_, o_ = carry
                p_, o_, m = step(p_, o_, b, fm)
                return (p_, o_), m["loss"]
            (p, o), losses = jax.lax.scan(one, (p, o), bs)
            return p, jnp.mean(losses)

        p_k, losses = jax.vmap(client_epoch)(stacked_params, stacked_opt,
                                             batches, fmasks)
        new_global = fedavg_stacked(p_k, sizes)
        return broadcast_clients(new_global, K), losses

    return fed_round
