"""Federated round engines.

``run_fdapt`` drives the full FDAPT/FFDAPT process from Appendix A: init
every client from the global model, run one local epoch per round, FedAvg,
repeat.  Two execution engines with identical math:

  * ``engine="sequential"`` — paper-faithful loop over clients (Flower runs
    clients as processes; we run them as successive jit calls).  Supports
    FFDAPT *static* windows: each (window pattern) compiles once, frozen
    layers truly skip backward dW.
  * ``engine="parallel"``  — all K clients execute as ONE program, client
    dim vmapped/mesh-sharded (clients <-> pod/data axes at production
    scale); FedAvg is a weighted mean over the client dim (one all-reduce).
    FFDAPT runs in *masked* mode here (traced per-client masks — a single
    program for all rounds).

Per the paper (Appendix E.1): optimizers are re-initialized at the start of
each round's local training; 1 local epoch per round; 15 rounds.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ffdapt as ffd
from repro.core.fedavg import broadcast_clients, fedavg, fedavg_stacked
from repro.models.steps import make_masked_train_step, make_train_step
from repro.nn import param as P


@dataclasses.dataclass
class RoundResult:
    round: int
    loss: float
    round_time_s: float
    windows: Optional[List[ffd.Window]] = None


def _epoch(train_step, params, opt_state, batches: Sequence[Dict[str, Any]]):
    losses = []
    for b in batches:
        params, opt_state, m = train_step(params, opt_state, b)
        losses.append(m["loss"])
    return params, opt_state, float(jnp.mean(jnp.stack(losses)))


def run_fdapt(cfg, optimizer, params, client_batches: List[List[Dict[str, Any]]],
              *, n_rounds: int = 15, client_sizes: Optional[Sequence[int]] = None,
              ffdapt: Optional[ffd.FFDAPTConfig] = None,
              engine: str = "sequential", impl: str = "xla",
              eval_fn: Optional[Callable[[Any], float]] = None):
    """Returns (final_params, [RoundResult...]).

    client_batches[k] = that client's local batches for one epoch (re-used
    each round — the paper re-iterates the local dataset every round).
    client_sizes defaults to per-client batch counts (n_k of Algorithm 1).
    """
    K = len(client_batches)
    sizes = list(client_sizes) if client_sizes is not None else [
        len(bs) for bs in client_batches]
    from repro.models.model import n_freeze_units
    N = n_freeze_units(cfg)
    windows = (ffd.schedule(N, sizes, n_rounds, epsilon=ffdapt.epsilon,
                            gamma=ffdapt.gamma) if ffdapt else None)

    if engine == "sequential":
        return _run_sequential(cfg, optimizer, params, client_batches, sizes,
                               n_rounds, windows, impl, eval_fn, N)
    if engine == "parallel":
        return _run_parallel(cfg, optimizer, params, client_batches, sizes,
                             n_rounds, windows, impl, eval_fn, N)
    raise ValueError(engine)


# ---------------------------------------------------------------------------
# Sequential (paper-faithful; static FFDAPT windows)
# ---------------------------------------------------------------------------

# process-wide program cache: one compiled step per distinct
# (config, optimizer, frozen pattern) — rotation reuses at most N programs,
# and repeated run_fdapt calls (benchmarks, resumed runs) pay zero recompiles.
_STEP_CACHE: Dict[Any, Callable] = {}


def _run_sequential(cfg, optimizer, params, client_batches, sizes, n_rounds,
                    windows, impl, eval_fn, n_units):
    def step_for(frozen):
        key = (cfg, id(optimizer.update), frozen, impl)
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = jax.jit(make_train_step(
                cfg, optimizer, frozen=frozen, impl=impl))
        return _STEP_CACHE[key]

    history = []
    for t in range(n_rounds):
        t0 = time.perf_counter()
        locals_, losses = [], []
        for k, batches in enumerate(client_batches):
            frozen = None
            if windows is not None:
                frozen = ffd.window_mask(n_units, windows[t][k])
            opt_state = P.unbox(optimizer.init(params))
            p_k, _, loss = _epoch(step_for(frozen), params, opt_state, batches)
            locals_.append(p_k)
            losses.append(loss)
        params = fedavg(locals_, sizes)
        dt = time.perf_counter() - t0
        history.append(RoundResult(t, float(jnp.mean(jnp.asarray(losses))), dt,
                                   windows[t] if windows else None))
        if eval_fn is not None:
            history[-1].loss = eval_fn(params)
    return params, history


def make_fed_round_program(cfg, optimizer, *, impl: str = "xla"):
    """ONE federated round as a single jit-able program for the production
    mesh: every client runs its local epoch simultaneously (client dim
    sharded over the ``pod`` axis via FED_RULES), then FedAvg aggregates with
    one weighted all-reduce over clients — cross-pod DCN traffic, exactly the
    WAN aggregation the paper's Flower server performs.

    fed_round(stacked_params (K,...), stacked_opt, batches (K,steps,B,S...),
              fmasks (K, n_units), sizes (K,)) ->
        (new stacked params, per-client losses)
    FFDAPT runs in masked mode here (traced per-client windows)."""
    step = make_masked_train_step(cfg, optimizer, impl=impl)

    def fed_round(stacked_params, stacked_opt, batches, fmasks, sizes):
        K = jax.tree.leaves(stacked_params)[0].shape[0]

        def client_epoch(p, o, bs, fm):
            def one(carry, b):
                p_, o_ = carry
                p_, o_, m = step(p_, o_, b, fm)
                return (p_, o_), m["loss"]
            (p, o), losses = jax.lax.scan(one, (p, o), bs)
            return p, jnp.mean(losses)

        p_k, losses = jax.vmap(client_epoch)(stacked_params, stacked_opt,
                                             batches, fmasks)
        new_global = fedavg_stacked(p_k, sizes)
        return broadcast_clients(new_global, K), losses

    return fed_round


# ---------------------------------------------------------------------------
# Parallel (mesh / vmap engine; masked FFDAPT)
# ---------------------------------------------------------------------------

def _run_parallel(cfg, optimizer, params, client_batches, sizes, n_rounds,
                  windows, impl, eval_fn, n_units):
    K = len(client_batches)
    steps_per_client = min(len(b) for b in client_batches)
    if any(len(b) != steps_per_client for b in client_batches):
        # pad by cycling (quantity skew -> unequal local steps; the stacked
        # engine needs a rectangular schedule, extras are dropped/cycled)
        client_batches = [bs[:steps_per_client] for bs in client_batches]

    def stack_batches():
        per_client = [jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
                      for bs in client_batches]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)

    batches = stack_batches()                 # leaves: (K, steps, B, ...)
    masked_step = make_masked_train_step(cfg, optimizer, impl=impl)
    plain_step = make_train_step(cfg, optimizer, impl=impl)

    def client_epoch(p, o, bs, fmask):
        def one(carry, b):
            p_, o_ = carry
            if windows is not None:
                p_, o_, m = masked_step(p_, o_, b, fmask)
            else:
                p_, o_, m = plain_step(p_, o_, b)
            return (p_, o_), m["loss"]
        (p, o), losses = jax.lax.scan(one, (p, o), bs)
        return p, jnp.mean(losses)

    w = jnp.asarray(sizes, jnp.float32)

    @jax.jit
    def fed_round(global_params, batches, fmasks):
        stacked = broadcast_clients(global_params, K)
        opts = jax.vmap(lambda p: P.unbox(optimizer.init(p)))(stacked)
        p_k, losses = jax.vmap(client_epoch)(stacked, opts, batches, fmasks)
        new_global = fedavg_stacked(p_k, w)
        return new_global, jnp.sum(losses * (w / jnp.sum(w)))

    history = []
    for t in range(n_rounds):
        t0 = time.perf_counter()
        if windows is not None:
            fmasks = jnp.stack([
                jnp.asarray(ffd.window_mask(n_units, windows[t][k]), jnp.float32)
                for k in range(K)])
        else:
            fmasks = jnp.zeros((K, n_units), jnp.float32)
        params, loss = fed_round(params, batches, fmasks)
        dt = time.perf_counter() - t0
        history.append(RoundResult(t, float(loss), dt,
                                   windows[t] if windows else None))
        if eval_fn is not None:
            history[-1].loss = eval_fn(params)
    return params, history
