"""Unified model assembly for the architecture zoo.

``init_model`` / ``apply_model`` cover all seven families (dense, moe, ssm,
hybrid, vlm, audio, mlm) behind one interface:

    logits, new_cache, aux = apply_model(params, cfg, batch, mode=...,
                                         cache=..., frozen=..., impl=...)

``frozen`` is a STATIC per-freeze-unit bool tuple (FFDAPT Algorithm 1's
consecutive window, possibly wrapped); frozen units run under
``stop_gradient`` so the compiled backward skips their dW entirely.

Freeze units (what Algorithm 1's N counts) per family:
  uniform stacks (dense/moe/mlm/ssm): one unit per layer.
  hybrid:  one unit per mamba block (the shared attention block is shared
           across positions and stays trainable — see DESIGN §Arch-applicability).
  vlm:     one unit per (cross_attn_every-1 self + 1 cross) group.
  audio:   encoder layers ++ decoder layers, concatenated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.attention import abstract_cache  # noqa: F401 (re-export)
from repro.nn.layers import (apply_embedding, apply_lm_head, apply_norm,
                             apply_positional, init_embedding, init_lm_head,
                             init_norm, init_positional)
from repro.nn.mamba import mamba_dims
from repro.nn.param import Box, ParamCtx
from repro.nn.rwkv import rwkv_heads
from repro.nn.stack import init_stack, scan_stack, mask_segments
from repro.sharding.ctx import constrain
from repro.models import blocks as B


# ---------------------------------------------------------------------------
# Freeze-unit accounting
# ---------------------------------------------------------------------------

def n_freeze_units(cfg) -> int:
    if cfg.arch_type == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.arch_type == "audio":
        return cfg.encoder_layers + cfg.n_layers
    return cfg.n_layers


def _split_frozen(frozen, n_first):
    """Split a combined frozen mask into two per-stack masks (audio)."""
    if frozen is None:
        return None, None
    return tuple(frozen[:n_first]), tuple(frozen[n_first:])


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_model(key: jax.Array, cfg) -> Any:
    """Boxed parameter tree.  Use ``P.abstract_init(init_model, key, cfg)``
    for allocation-free specs (the 340B dry-run path)."""
    cfg.validate()
    ctx = ParamCtx(key, cfg.pdtype)
    p: Dict[str, Any] = {"embed": init_embedding(ctx.sub("embed"),
                                                 cfg.vocab_size, cfg.d_model)}
    if not cfg.use_rope and cfg.arch_type != "ssm":
        p["pos"] = init_positional(ctx.sub("pos"), cfg.max_seq_len, cfg.d_model)

    at = cfg.arch_type
    if at in ("dense", "moe", "mlm"):
        p["layers"] = init_stack(ctx, "layers", cfg.n_layers,
                                 lambda c: B.init_transformer_block(c, cfg))
    elif at == "ssm":
        p["ln_in"] = init_norm(ctx.sub("ln_in"), cfg.d_model, "layernorm")
        p["layers"] = init_stack(ctx, "layers", cfg.n_layers,
                                 lambda c: B.init_rwkv_block(c, cfg))
    elif at == "hybrid":
        p["layers"] = init_stack(ctx, "layers", cfg.n_layers,
                                 lambda c: B.init_mamba_block(c, cfg))
        p["shared_attn"] = B.init_transformer_block(ctx.sub("shared_attn"), cfg)
    elif at == "vlm":
        per = cfg.cross_attn_every - 1
        G = cfg.n_layers // cfg.cross_attn_every

        def init_group(c):
            return {
                "self": init_stack(c, "self", per,
                                   lambda cc: B.init_transformer_block(cc, cfg)),
                "cross": B.init_transformer_block(c.sub("cross"), cfg, cross=True),
            }

        p["layers"] = init_stack(ctx, "groups", G, init_group)
    elif at == "audio":
        p["enc_pos"] = init_positional(ctx.sub("enc_pos"),
                                       cfg.n_audio_frames, cfg.d_model)
        p["enc_layers"] = init_stack(ctx, "enc_layers", cfg.encoder_layers,
                                     lambda c: B.init_transformer_block(c, cfg))
        p["enc_norm"] = init_norm(ctx.sub("enc_norm"), cfg.d_model, cfg.norm_type)
        p["layers"] = init_stack(ctx, "dec_layers", cfg.n_layers,
                                 lambda c: B.init_encdec_block(c, cfg))
    else:
        raise ValueError(f"unknown arch_type {at!r}")

    p["final_norm"] = init_norm(ctx.sub("final_norm"), cfg.d_model, cfg.norm_type)
    if not cfg.tie_embeddings:
        p["lm_head"] = init_lm_head(ctx.sub("lm_head"), cfg.d_model, cfg.vocab_size)
    if cfg.arch_type == "mlm":
        # BERT-style MLM transform head
        p["mlm_transform"] = {
            "w": ctx.param("mlm_w", (cfg.d_model, cfg.d_model), P.fan_in(),
                           (P.EMBED, P.EMBED)),
            "b": ctx.param("mlm_b", (cfg.d_model,), P.zeros(), (P.EMBED,)),
            "ln": init_norm(ctx.sub("mlm_ln"), cfg.d_model, cfg.norm_type),
        }
    return p


# ---------------------------------------------------------------------------
# Caches (boxed ShapeDtypeStruct trees -> shardable, allocation-free)
# ---------------------------------------------------------------------------

def _box(shape, dtype, axes):
    return Box(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))


def cache_struct(cfg, batch: int, cache_len: int, dtype=None) -> Any:
    """Boxed SDS cache tree for (arch, batch, cache_len)."""
    dt = dtype or cfg.cdtype
    at = cfg.arch_type
    L, Kv, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    kvax = (P.LAYERS, P.BATCH, P.SEQ, P.KV_HEADS, P.HEAD_DIM)
    c: Dict[str, Any] = {"index": _box((), jnp.int32, ())}
    C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len

    if at in ("dense", "moe"):
        c["layers"] = {"k": _box((L, batch, C, Kv, D), dt, kvax),
                       "v": _box((L, batch, C, Kv, D), dt, kvax)}
    elif at == "ssm":
        H = rwkv_heads(cfg.d_model, cfg.ssm_heads)
        hd = cfg.d_model // H
        c["layers"] = {
            "tm_x": _box((L, batch, cfg.d_model), dt, (P.LAYERS, P.BATCH, P.EMBED)),
            "cm_x": _box((L, batch, cfg.d_model), dt, (P.LAYERS, P.BATCH, P.EMBED)),
            "wkv": _box((L, batch, H, hd, hd), jnp.float32,
                        (P.LAYERS, P.BATCH, P.HEADS, None, None)),
        }
    elif at == "hybrid":
        _, H, CC = mamba_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                              cfg.conv_dim)
        A = len(cfg.shared_attn_positions)
        from repro.nn.mamba import HEAD_P
        c["layers"] = {
            "conv": _box((L, batch, cfg.conv_dim - 1, CC), dt,
                         (P.LAYERS, P.BATCH, None, P.FFN)),
            "ssm": _box((L, batch, H, HEAD_P, cfg.ssm_state), jnp.float32,
                        (P.LAYERS, P.BATCH, P.HEADS, None, P.DSTATE)),
        }
        c["shared"] = {"k": _box((A, batch, cache_len, Kv, D), dt, kvax),
                       "v": _box((A, batch, cache_len, Kv, D), dt, kvax)}
    elif at == "vlm":
        per = cfg.cross_attn_every - 1
        G = cfg.n_layers // cfg.cross_attn_every
        sax = (P.LAYERS, None, P.BATCH, P.SEQ, P.KV_HEADS, P.HEAD_DIM)
        xax = (P.LAYERS, P.BATCH, None, P.KV_HEADS, P.HEAD_DIM)
        c["layers"] = {
            "self": {"k": _box((G, per, batch, C, Kv, D), dt, sax),
                     "v": _box((G, per, batch, C, Kv, D), dt, sax)},
            "cross": {"xk": _box((G, batch, cfg.n_image_tokens, Kv, D), dt, xax),
                      "xv": _box((G, batch, cfg.n_image_tokens, Kv, D), dt, xax)},
        }
    elif at == "audio":
        xax = (P.LAYERS, P.BATCH, None, P.KV_HEADS, P.HEAD_DIM)
        c["layers"] = {
            "k": _box((L, batch, C, Kv, D), dt, kvax),
            "v": _box((L, batch, C, Kv, D), dt, kvax),
            "xk": _box((L, batch, cfg.n_audio_frames, Kv, D), dt, xax),
            "xv": _box((L, batch, cfg.n_audio_frames, Kv, D), dt, xax),
        }
    else:
        raise ValueError(f"no cache for arch_type {at!r}")
    return c


def init_cache(cfg, batch: int, cache_len: int, dtype=None) -> Any:
    struct = cache_struct(cfg, batch, cache_len, dtype)
    return jax.tree.map(lambda b: jnp.zeros(b.value.shape, b.value.dtype),
                        struct, is_leaf=P.is_box)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _positions(mode, Bn, S, index):
    if mode == "decode":
        return jnp.broadcast_to(index[None, None], (Bn, 1)).astype(jnp.int32)
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (Bn, S))


def _learned_pos(p, positions, max_len, dtype):
    pos = jnp.minimum(positions, max_len - 1)
    return apply_positional(p, pos, dtype)


def _head(params, cfg, x):
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.arch_type == "mlm":
        t = params["mlm_transform"]
        x = jnp.einsum("...d,de->...e", x, t["w"].astype(x.dtype)) + t["b"].astype(x.dtype)
        x = jax.nn.gelu(x)
        x = apply_norm(t["ln"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        return apply_lm_head(None, x, embedding_table=params["embed"]["table"])
    return apply_lm_head(params["lm_head"], x)


def apply_model(params, cfg, batch: Dict[str, Any], *, mode: str = "train",
                cache: Any = None, frozen: Optional[Tuple[bool, ...]] = None,
                impl: str = "xla", last_only: bool = False):
    """batch: {"tokens": (B,S) int32, ["image_embeds"], ["frames"]}.

    Returns (logits (B,S,V), new_cache (or None), aux_loss scalar).
    mode: "train" (no cache) | "prefill" (fills cache) | "decode" (S==1).
    last_only: apply the LM head to the final position only (prefill) —
    the (B,S,vocab) buffer is the single largest activation at scale.
    """
    tokens = batch["tokens"]
    Bn, S = tokens.shape
    dt = cfg.cdtype
    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = _positions(mode, Bn, S, index)

    x = apply_embedding(params["embed"], tokens, dt)
    x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
    if "pos" in params and cfg.arch_type != "audio":
        x = x + _learned_pos(params["pos"], positions, cfg.max_seq_len, dt)

    at = cfg.arch_type
    new_layers = None
    aux_total = jnp.zeros((), jnp.float32)

    if at in ("dense", "moe", "mlm"):
        causal = at != "mlm"

        def body(p, x, lc):
            x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
            x, nlc, aux = B.apply_transformer_block(
                p, x, cfg, lc, mode=mode, causal=causal, positions=positions,
                cache_index=index, impl=impl)
            return constrain(x, (P.BATCH, P.SEQ, P.EMBED)), (nlc, aux)

        lcs = cache["layers"] if cache is not None else None
        x, outs = scan_stack(P.unbox_if(params["layers"]), x, body, aux=lcs,
                             remat=cfg.remat, frozen=frozen,
                             unroll=cfg.scan_unroll)
        new_layers, auxs = outs
        aux_total = jnp.sum(auxs)

    elif at == "ssm":
        x = apply_norm(params["ln_in"], x, "layernorm", cfg.norm_eps)

        def body(p, x, lc):
            x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
            x, nlc, aux = B.apply_rwkv_block(p, x, cfg, lc, impl=impl)
            return constrain(x, (P.BATCH, P.SEQ, P.EMBED)), (nlc, aux)

        lcs = cache["layers"] if cache is not None else None
        x, outs = scan_stack(P.unbox_if(params["layers"]), x, body, aux=lcs,
                             remat=cfg.remat, frozen=frozen,
                             unroll=cfg.scan_unroll)
        new_layers, auxs = outs
        aux_total = jnp.sum(auxs)

    elif at == "hybrid":
        x, new_layers, new_shared, aux_total = _apply_hybrid(
            params, cfg, x, cache, mode=mode, positions=positions,
            index=index, frozen=frozen, impl=impl)

    elif at == "vlm":
        x, new_layers, aux_total = _apply_vlm(
            params, cfg, x, batch, cache, mode=mode, positions=positions,
            index=index, frozen=frozen, impl=impl)

    elif at == "audio":
        x, new_layers, aux_total = _apply_audio(
            params, cfg, x, batch, cache, mode=mode, positions=positions,
            index=index, frozen=frozen, impl=impl)
    else:
        raise ValueError(at)

    if last_only:
        x = x[:, -1:, :]
    logits = _head(params, cfg, x)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["index"] = index + (1 if mode == "decode" else S)
        if at == "hybrid":
            new_cache["shared"] = new_shared
    return logits, new_cache, aux_total


# ---------------------------------------------------------------------------
# Hybrid (zamba2): mamba stack with a shared attention block spliced in
# ---------------------------------------------------------------------------

def _apply_hybrid(params, cfg, x, cache, *, mode, positions, index, frozen, impl):
    n = cfg.n_layers
    attn_after = sorted(cfg.shared_attn_positions)   # apply shared attn after these
    frozen = tuple(frozen) if frozen is not None else (False,) * n

    # segment boundaries: frozen-run edges ∪ attention positions
    cuts = {0, n}
    for lo, hi, _ in mask_segments(frozen):
        cuts.update((lo, hi))
    for a in attn_after:
        cuts.add(a + 1)
    cuts = sorted(cuts)

    lcs = cache["layers"] if cache is not None else None
    shared = cache["shared"] if cache is not None else None
    shared_p = P.unbox_if(params["shared_attn"])
    stacked = P.unbox_if(params["layers"])

    def body(p, x, lc):
        x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
        x, nlc, aux = B.apply_mamba_block(p, x, cfg, lc, impl=impl)
        return constrain(x, (P.BATCH, P.SEQ, P.EMBED)), (nlc, aux)

    new_lcs, new_shared_k, new_shared_v, auxs = [], [], [], []
    app_i = 0
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        pseg = jax.tree.map(lambda t: t[lo:hi], stacked)
        if frozen[lo]:
            pseg = jax.tree.map(jax.lax.stop_gradient, pseg)
        aseg = jax.tree.map(lambda t: t[lo:hi], lcs) if lcs is not None else None
        x, (nlc, aux) = jax.lax.scan(
            jax.checkpoint(lambda c, xs: body(xs[0], c, xs[1])) if cfg.remat
            else (lambda c, xs: body(xs[0], c, xs[1])),
            x, (pseg, aseg), unroll=(hi - lo) if cfg.scan_unroll else 1)
        new_lcs.append(nlc)
        auxs.append(jnp.sum(aux))
        if (hi - 1) in attn_after:
            slc = None
            if shared is not None:
                slc = {"k": shared["k"][app_i], "v": shared["v"][app_i]}
            x, nslc, aux2 = B.apply_transformer_block(
                shared_p, x, cfg, slc, mode=mode, causal=True,
                positions=positions, cache_index=index, impl=impl)
            auxs.append(aux2)
            if nslc is not None:
                new_shared_k.append(nslc["k"])
                new_shared_v.append(nslc["v"])
            app_i += 1

    new_layers = None
    if lcs is not None:
        new_layers = jax.tree.map(lambda *ts: jnp.concatenate(ts, 0), *new_lcs)
    new_shared = None
    if shared is not None:
        new_shared = {"k": jnp.stack(new_shared_k), "v": jnp.stack(new_shared_v)}
    return x, new_layers, new_shared, sum(auxs)


# ---------------------------------------------------------------------------
# VLM (llama-3.2-vision): grouped scan, gated cross-attention every Nth layer
# ---------------------------------------------------------------------------

def _apply_vlm(params, cfg, x, batch, cache, *, mode, positions, index,
               frozen, impl):
    per = cfg.cross_attn_every - 1
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(x.dtype)

    def group_body(gp, x, glc):
        x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
        auxs = []
        nself = None
        if glc is not None:
            nks, nvs = [], []
        for i in range(per):
            pi = jax.tree.map(lambda t: t[i], gp["self"])
            lci = None
            if glc is not None:
                lci = {"k": glc["self"]["k"][i], "v": glc["self"]["v"][i]}
            x, nlc, aux = B.apply_transformer_block(
                pi, x, cfg, lci, mode=mode, causal=True, positions=positions,
                cache_index=index, impl=impl)
            auxs.append(aux)
            if glc is not None:
                nks.append(nlc["k"])
                nvs.append(nlc["v"])
        xlc = glc["cross"] if glc is not None else None
        x, nxlc, aux = B.apply_cross_block(gp["cross"], x, cfg, xlc, mode=mode,
                                           kv_embeds=img, impl=impl)
        auxs.append(aux)
        nglc = None
        if glc is not None:
            nglc = {"self": {"k": jnp.stack(nks), "v": jnp.stack(nvs)},
                    "cross": nxlc}
        return x, (nglc, sum(auxs))

    lcs = cache["layers"] if cache is not None else None
    x, outs = scan_stack(P.unbox_if(params["layers"]), x, group_body, aux=lcs,
                         remat=cfg.remat, frozen=frozen, unroll=cfg.scan_unroll)
    new_layers, auxs = outs
    return x, new_layers, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Audio (whisper): encoder over stub frame embeddings + causal decoder
# ---------------------------------------------------------------------------

def _apply_audio(params, cfg, x, batch, cache, *, mode, positions, index,
                 frozen, impl):
    frz_enc, frz_dec = _split_frozen(frozen, cfg.encoder_layers)
    enc_out = None
    if mode != "decode":
        frames = batch["frames"].astype(x.dtype)          # (B, F, d) stub embeds
        F = frames.shape[1]
        fpos = jnp.arange(F, dtype=jnp.int32)[None, :]
        h = frames + apply_positional(params["enc_pos"], fpos, x.dtype)

        def enc_body(p, h, _):
            h = constrain(h, (P.BATCH, P.SEQ, P.EMBED))
            h, _, aux = B.apply_transformer_block(p, h, cfg, None, mode="train",
                                                  causal=False, impl=impl)
            return h, aux

        h, _ = scan_stack(P.unbox_if(params["enc_layers"]), h, enc_body,
                          remat=cfg.remat, frozen=frz_enc,
                          unroll=cfg.scan_unroll)
        enc_out = apply_norm(params["enc_norm"], h, cfg.norm_type, cfg.norm_eps)

    if "pos" in params:
        x = x + _learned_pos(params["pos"], positions, cfg.max_seq_len, x.dtype)

    def dec_body(p, x, lc):
        x = constrain(x, (P.BATCH, P.SEQ, P.EMBED))
        x, nlc, aux = B.apply_encdec_block(p, x, cfg, lc, mode=mode,
                                           enc_out=enc_out, positions=positions,
                                           cache_index=index, impl=impl)
        return x, (nlc, aux)

    lcs = cache["layers"] if cache is not None else None
    x, outs = scan_stack(P.unbox_if(params["layers"]), x, dec_body, aux=lcs,
                         remat=cfg.remat, frozen=frz_dec,
                         unroll=cfg.scan_unroll)
    new_layers, auxs = outs
    return x, new_layers, jnp.sum(auxs)
