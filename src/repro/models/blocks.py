"""Per-family layer blocks assembled from repro.nn.

Each family exposes ``init_<family>_block(ctx, cfg)`` (one layer's boxed
params) and ``apply_<family>_block(params, x, cfg, layer_cache, **kw)``
returning ``(x, new_layer_cache)``.  Layer caches are dicts of per-layer
arrays — ``scan_stack`` scans over their stacked (leading-layers-dim) form.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.attention import apply_attention, init_attention
from repro.nn.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.nn.mamba import apply_mamba2, init_mamba2
from repro.nn.moe import apply_moe, init_moe
from repro.nn.param import ParamCtx
from repro.nn.rwkv import (apply_rwkv_channel_mix, apply_rwkv_time_mix,
                           init_rwkv_channel_mix, init_rwkv_time_mix,
                           rwkv_heads)


# ---------------------------------------------------------------------------
# Transformer block (dense / moe / mlm / whisper-enc / vlm-self)
# ---------------------------------------------------------------------------

def init_transformer_block(ctx: ParamCtx, cfg, *, cross: bool = False):
    p = {
        "ln1": init_norm(ctx.sub("ln1"), cfg.d_model, cfg.norm_type),
        "attn": init_attention(ctx.sub("attn"), cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim_,
                               qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": init_norm(ctx.sub("ln2"), cfg.d_model, cfg.norm_type),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(ctx.sub("moe"), cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = init_mlp(ctx.sub("mlp"), cfg.d_model, cfg.d_ff, cfg.mlp_type)
    if cross:
        # gated cross-attention (llama-3.2-vision style): tanh-gated residual
        p["xattn"] = init_attention(ctx.sub("xattn"), cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim_)
        p["lnx"] = init_norm(ctx.sub("lnx"), cfg.d_model, cfg.norm_type)
        p["gate_attn"] = ctx.param("gate_attn", (), P.zeros(), ())
        p["gate_mlp"] = ctx.param("gate_mlp", (), P.zeros(), ())
    return p


# Declarative PEFT target table (consumed by repro.peft.space).  Maps a
# target group to the projection leaves inside a block that are linear maps,
# with each leaf's dimension split ``(n_in_dims, n_out_dims)`` counted after
# stripping leading stack dims (layers / experts).  E.g. a stacked ``wq`` of
# shape (L, d, H, hd) factors as input (d,) -> output (H, hd).  Biases and
# norms are never adapted; MoE expert banks are deliberately excluded (their
# leading experts dim is a stack dim a rank-r factor would have to share).
PEFT_TARGETS = {
    "attn": {"wq": (1, 2), "wk": (1, 2), "wv": (1, 2), "wo": (2, 1)},
    "mlp": {"wi_gate": (1, 1), "wi_up": (1, 1), "wi": (1, 1), "wo": (1, 1)},
}

# Path components under which each target group's leaves live.  "attn" covers
# both self-attention and the gated cross-attention of VLM/enc-dec blocks.
PEFT_GROUPS = {
    "attn": ("attn", "xattn"),
    "mlp": ("mlp",),
}


def _ffn(p, x, cfg, impl):
    if cfg.n_experts:
        groups = 0
        if cfg.moe_local_dispatch:
            from repro.sharding.ctx import data_parallel_size
            groups = data_parallel_size()
        return apply_moe(p["moe"], x, cfg.top_k,
                         capacity_factor=cfg.capacity_factor, impl=impl,
                         groups=groups)
    return apply_mlp(p["mlp"], x, cfg.mlp_type), jnp.zeros((), jnp.float32)


def apply_transformer_block(p, x, cfg, lc, *, mode, causal=True,
                            positions=None, cache_index=None, impl="xla"):
    """Self-attention transformer layer.  lc (layer cache): dict with
    k/v (B,C,Kv,D) or None in train mode; cache_index is the global scalar."""
    ck = lc.get("k") if lc else None
    cv = lc.get("v") if lc else None
    ci = cache_index
    if cfg.norm_position == "pre":
        h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
        a, nk, nv = apply_attention(p["attn"], h, cfg, mode=mode, causal=causal,
                                    cache_k=ck, cache_v=cv, cache_index=ci,
                                    positions=positions, impl=impl)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        m, aux = _ffn(p, h, cfg, impl)
        x = x + m
    else:  # post-norm (distilbert)
        a, nk, nv = apply_attention(p["attn"], x, cfg, mode=mode, causal=causal,
                                    cache_k=ck, cache_v=cv, cache_index=ci,
                                    positions=positions, impl=impl)
        x = apply_norm(p["ln1"], x + a, cfg.norm_type, cfg.norm_eps)
        m, aux = _ffn(p, x, cfg, impl)
        x = apply_norm(p["ln2"], x + m, cfg.norm_type, cfg.norm_eps)
    nlc = {"k": nk, "v": nv} if lc else None
    return x, nlc, aux


def apply_cross_block(p, x, cfg, lc, *, mode, kv_embeds=None, positions=None,
                      impl="xla"):
    """Gated cross-attention layer (VLM).  kv_embeds: (B,Tkv,d) image/frame
    embeddings (prefill/train) — at decode the projected kv live in lc."""
    gate_a = jnp.tanh(p["gate_attn"]).astype(x.dtype)
    gate_m = jnp.tanh(p["gate_mlp"]).astype(x.dtype)
    h = apply_norm(p["lnx"], x, cfg.norm_type, cfg.norm_eps)
    if mode == "decode" and lc and "xk" in lc:
        # reuse projected image kv from the cache
        from repro.nn.attention import _gqa_scores_combine, _project_qkv
        dt = x.dtype
        q = jnp.einsum("...d,dhk->...hk", h, p["xattn"]["wq"].astype(dt))
        mask = jnp.zeros((1, 1, 1, lc["xk"].shape[1]), jnp.float32)
        out = _gqa_scores_combine(q, lc["xk"].astype(dt), lc["xv"].astype(dt), mask)
        a = jnp.einsum("...hk,hkd->...d", out, p["xattn"]["wo"].astype(dt))
        nxk, nxv = lc["xk"], lc["xv"]
    else:
        a, _, _ = apply_attention(p["xattn"], h, cfg, mode="train", causal=False,
                                  kv_x=kv_embeds, impl=impl)
        dt = x.dtype
        nxk = jnp.einsum("...d,dhk->...hk", kv_embeds, p["xattn"]["wk"].astype(dt))
        nxv = jnp.einsum("...d,dhk->...hk", kv_embeds, p["xattn"]["wv"].astype(dt))
    x = x + gate_a * a
    h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
    m, aux = _ffn(p, h, cfg, impl)
    x = x + gate_m * m
    nlc = {"xk": nxk, "xv": nxv} if lc is not None else None
    return x, nlc, aux


# ---------------------------------------------------------------------------
# Encoder-decoder block (whisper decoder: self + cross + mlp)
# ---------------------------------------------------------------------------

def init_encdec_block(ctx: ParamCtx, cfg):
    return {
        "ln1": init_norm(ctx.sub("ln1"), cfg.d_model, cfg.norm_type),
        "attn": init_attention(ctx.sub("attn"), cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim_,
                               qkv_bias=cfg.qkv_bias),
        "lnx": init_norm(ctx.sub("lnx"), cfg.d_model, cfg.norm_type),
        "xattn": init_attention(ctx.sub("xattn"), cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.head_dim_,
                                qkv_bias=cfg.qkv_bias),
        "ln2": init_norm(ctx.sub("ln2"), cfg.d_model, cfg.norm_type),
        "mlp": init_mlp(ctx.sub("mlp"), cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def apply_encdec_block(p, x, cfg, lc, *, mode, enc_out=None, positions=None,
                       cache_index=None, impl="xla"):
    """Whisper decoder layer.  lc: {k, v, xk, xv}; cache_index global scalar."""
    ck = lc.get("k") if lc else None
    cv = lc.get("v") if lc else None
    ci = cache_index
    h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    a, nk, nv = apply_attention(p["attn"], h, cfg, mode=mode, causal=True,
                                cache_k=ck, cache_v=cv, cache_index=ci,
                                positions=positions, impl=impl)
    x = x + a
    h = apply_norm(p["lnx"], x, cfg.norm_type, cfg.norm_eps)
    if mode == "decode" and lc and "xk" in lc:
        from repro.nn.attention import _gqa_scores_combine
        dt = x.dtype
        q = jnp.einsum("...d,dhk->...hk", h, p["xattn"]["wq"].astype(dt))
        if "bq" in p["xattn"]:
            q = q + p["xattn"]["bq"].astype(dt)
        mask = jnp.zeros((1, 1, 1, lc["xk"].shape[1]), jnp.float32)
        out = _gqa_scores_combine(q, lc["xk"].astype(dt), lc["xv"].astype(dt), mask)
        a = jnp.einsum("...hk,hkd->...d", out, p["xattn"]["wo"].astype(dt))
        nxk, nxv = lc["xk"], lc["xv"]
    else:
        a, _, _ = apply_attention(p["xattn"], h, cfg, mode="train", causal=False,
                                  kv_x=enc_out, impl=impl)
        dt = x.dtype
        nxk = jnp.einsum("...d,dhk->...hk", enc_out, p["xattn"]["wk"].astype(dt))
        nxv = jnp.einsum("...d,dhk->...hk", enc_out, p["xattn"]["wv"].astype(dt))
        if "bk" in p["xattn"]:
            nxk = nxk + p["xattn"]["bk"].astype(dt)
            nxv = nxv + p["xattn"]["bv"].astype(dt)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
    m = apply_mlp(p["mlp"], h, cfg.mlp_type)
    x = x + m
    nlc = None
    if lc is not None:
        nlc = {"k": nk, "v": nv, "xk": nxk, "xv": nxv}
    return x, nlc, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------

def init_rwkv_block(ctx: ParamCtx, cfg):
    H = rwkv_heads(cfg.d_model, cfg.ssm_heads)
    return {
        "ln1": init_norm(ctx.sub("ln1"), cfg.d_model, "layernorm"),
        "tm": init_rwkv_time_mix(ctx.sub("tm"), cfg.d_model, H),
        "ln2": init_norm(ctx.sub("ln2"), cfg.d_model, "layernorm"),
        "cm": init_rwkv_channel_mix(ctx.sub("cm"), cfg.d_model, cfg.d_ff),
    }


def apply_rwkv_block(p, x, cfg, lc, *, impl="xla"):
    """lc: {tm_x (B,d), cm_x (B,d), wkv (B,H,hd,hd)} or None (train: zeros)."""
    B, T, d = x.shape
    H = rwkv_heads(cfg.d_model, cfg.ssm_heads)
    hd = d // H
    if lc is None:
        tm_x = jnp.zeros((B, d), x.dtype)
        cm_x = jnp.zeros((B, d), x.dtype)
        wkv = jnp.zeros((B, H, hd, hd), jnp.float32)
    else:
        tm_x, cm_x, wkv = lc["tm_x"].astype(x.dtype), lc["cm_x"].astype(x.dtype), lc["wkv"]
    h = apply_norm(p["ln1"], x, "layernorm", cfg.norm_eps)
    a, new_tm_x, new_wkv = apply_rwkv_time_mix(p["tm"], h, H, last_x=tm_x,
                                               state=wkv, impl=impl)
    x = x + a
    h = apply_norm(p["ln2"], x, "layernorm", cfg.norm_eps)
    m, new_cm_x = apply_rwkv_channel_mix(p["cm"], h, last_x=cm_x)
    x = x + m
    nlc = None
    if lc is not None:
        nlc = {"tm_x": new_tm_x, "cm_x": new_cm_x, "wkv": new_wkv}
    return x, nlc, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 main stack)
# ---------------------------------------------------------------------------

def init_mamba_block(ctx: ParamCtx, cfg):
    return {
        "ln": init_norm(ctx.sub("ln"), cfg.d_model, cfg.norm_type),
        "mamba": init_mamba2(ctx.sub("mamba"), cfg.d_model, cfg.ssm_state,
                             expand=cfg.ssm_expand, conv_dim=cfg.conv_dim),
    }


def apply_mamba_block(p, x, cfg, lc, *, impl="xla"):
    """lc: {conv (B,W-1,CC), ssm (B,H,P,N)} or None."""
    conv = lc["conv"] if lc else None
    ssm = lc["ssm"] if lc else None
    h = apply_norm(p["ln"], x, cfg.norm_type, cfg.norm_eps)
    y, nconv, nssm = apply_mamba2(p["mamba"], h, cfg, conv_state=conv,
                                  ssm_state=ssm, impl=impl)
    x = x + y
    nlc = {"conv": nconv, "ssm": nssm} if lc is not None else None
    return x, nlc, jnp.zeros((), jnp.float32)
