"""Model configuration shared by every architecture in the zoo.

One dataclass covers the whole assigned pool (dense / MoE / SSM / hybrid /
VLM / audio / MLM); each ``repro.configs.<arch>`` module instantiates it with
the exact published numbers and cites the source.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio | mlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True              # False -> learned absolute positions (BERT/Whisper)
    sliding_window: int = 0            # 0 = full attention; >0 = windowed (ring cache)
    attn_logit_softcap: float = 0.0

    # mlp flavour
    mlp_type: str = "swiglu"           # swiglu | gelu | relu2
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm
    norm_position: str = "pre"         # pre | post (post = BERT-family)
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0                 # 0 = dense MLP
    top_k: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25      # expert capacity multiplier (drop-token)
    moe_local_dispatch: bool = False   # per-data-shard dispatch (see §Perf)

    # SSM (rwkv6 / mamba2)
    ssm_state: int = 0                 # mamba2 state dim
    ssm_heads: int = 0                 # rwkv6 / mamba2 heads (0 -> derive)
    ssm_expand: int = 2                # mamba2 inner expansion
    conv_dim: int = 4                  # mamba2 depthwise conv width
    ssm_chunk: int = 128               # chunked-SSD block length (see §Perf)

    # hybrid (zamba2): shared attention block applied at these (0-based) depths
    shared_attn_positions: Tuple[int, ...] = ()

    # VLM (llama-3.2-vision): a cross-attention layer every N layers
    cross_attn_every: int = 0
    n_image_tokens: int = 0            # stub vision-frontend patch count

    # audio (whisper): encoder stack over stub frame embeddings
    encoder_layers: int = 0
    n_audio_frames: int = 0

    # objective / head
    objective: str = "clm"             # clm | mlm | seq2seq
    tie_embeddings: bool = True
    mlm_mask_rate: float = 0.15

    max_seq_len: int = 131072
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True                 # checkpoint each scanned layer
    scan_unroll: bool = False          # unroll layer scans (dry-run: makes
                                       # cost_analysis count every layer)
    source: str = ""                   # citation

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_decoder_only(self) -> bool:
        return self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm")

    @property
    def subquadratic(self) -> bool:
        """True if a 500k-token decode is admissible (O(1)-state or windowed)."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_window(self, window: int = 8192) -> "ModelConfig":
        """Sliding-window variant used for long_500k on attention archs."""
        return self.replace(sliding_window=window)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep the GQA flavour: kv < q when the full config has it
        if self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // 2)
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads if n_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            max_seq_len=2048,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=2)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_heads=0)
        if self.ssm_heads and not self.ssm_state:   # rwkv6
            kw.update(ssm_heads=0)
        if self.shared_attn_positions:
            kw.update(shared_attn_positions=(1,))
        if self.cross_attn_every:
            kw.update(cross_attn_every=2, n_image_tokens=16)
        if self.encoder_layers:
            kw.update(encoder_layers=2, n_audio_frames=32)
        return self.replace(**kw)

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0 and self.vocab_size > 0
        if self.arch_type != "ssm":
            assert self.n_heads > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
                f"{self.name}: n_heads {self.n_heads} % n_kv_heads {self.n_kv_heads}"
        if self.n_experts:
            assert 0 < self.top_k <= self.n_experts
        if self.arch_type == "vlm":
            assert self.cross_attn_every > 0 and self.n_image_tokens > 0
        if self.arch_type == "audio":
            assert self.encoder_layers > 0 and self.n_audio_frames > 0
