"""Step factories: train / eval / prefill / serve.

``make_train_step(cfg, opt, frozen=...)`` bakes an FFDAPT freeze window into
the program *statically* — the paper-faithful mode, where frozen layers'
backward dW is never compiled.  ``make_masked_train_step`` is the
single-program alternative (traced per-layer mask, masked updates only; no
backward-FLOP saving) used when per-round recompiles are unacceptable.

All steps are functional pytree->pytree and jit/pjit-able; distribution is
applied by the caller (``repro.launch``) via in/out shardings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import apply_model, init_cache, init_model
from repro.optim import apply_updates, clip_by_global_norm


def abstract_train_state(cfg, optimizer, *, boxed: bool = False
                         ) -> Tuple[Any, Any]:
    """(params, opt_state) as ShapeDtypeStructs — no allocation.  The shared
    entry point for everything that lowers a train step on abstract inputs
    (dry-run, telemetry).  ``boxed=True`` keeps the sharding-axis boxes (the
    dry-run derives shardings from them)."""
    from repro.nn import param as P

    def full(key):
        p = init_model(key, cfg)
        return p, optimizer.init(p)

    pb, ob = jax.eval_shape(full, jax.random.PRNGKey(0))
    if boxed:
        return pb, ob
    return P.unbox(pb), P.unbox(ob)


def lm_loss(logits: jax.Array, targets: jax.Array, loss_mask: jax.Array):
    """Mean masked cross-entropy in fp32.  Returns (loss, n_tokens).

    The gold-logit pick uses an iota-compare reduction instead of
    ``take_along_axis``: gathering along a *model-sharded* vocab axis would
    make GSPMD all-gather the full (B,S,V) logits per device (hundreds of GB
    at train_4k scale); the masked reduction stays sharded and lowers to one
    small all-reduce."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * loss_mask
    count = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll) / count, count


def _objective(params, cfg, batch, frozen, impl):
    logits, _, aux = apply_model(params, cfg, batch, mode="train",
                                 frozen=frozen, impl=impl)
    loss, count = lm_loss(logits, batch["targets"],
                          batch["loss_mask"].astype(jnp.float32))
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux, "tokens": count}


def proximal_penalty(params: Any, anchor: Any) -> jax.Array:
    """mu-less proximal term: 1/2 ||w - w_anchor||^2 (caller scales by mu).
    The FedProx client objective (Li et al., 2020)."""
    leaves = jax.tree.map(
        lambda p, a: jnp.sum(jnp.square(p.astype(jnp.float32)
                                        - a.astype(jnp.float32))),
        params, anchor)
    return 0.5 * sum(jax.tree.leaves(leaves))


def _split_microbatches(batch: Dict[str, Any], m: int):
    def split(x):
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(split, batch)


def _stack_masks(cfg, frozen):
    """Map a per-freeze-unit mask onto the stacked top-level param entries.
    Returns [(top_key, frozen-mask over that entry's leading dim)]."""
    if frozen is None:
        return []
    if cfg.arch_type == "audio":
        e = cfg.encoder_layers
        return [("enc_layers", jnp.asarray(frozen[:e], jnp.float32)),
                ("layers", jnp.asarray(frozen[e:], jnp.float32))]
    return [("layers", jnp.asarray(frozen, jnp.float32))]


def _apply_freeze_to_updates(cfg, frozen, updates, new_opt, old_opt):
    """Frozen units are *fully untouched*: their updates are zeroed and their
    optimizer moments restored (torch requires_grad=False semantics — a zero
    grad would otherwise still move params through Adam momentum)."""
    for key, fmask in _stack_masks(cfg, frozen):
        def mask_u(u):
            keep = (1.0 - fmask).reshape((-1,) + (1,) * (u.ndim - 1))
            return u * keep.astype(u.dtype)

        def restore(new, old):
            sel = fmask.reshape((-1,) + (1,) * (new.ndim - 1)) > 0.5
            return jnp.where(sel, old, new)

        updates = dict(updates)
        updates[key] = jax.tree.map(mask_u, updates[key])
        for field in ("m", "v"):
            if field in new_opt:
                new_opt = dict(new_opt)
                new_opt[field] = dict(new_opt[field])
                new_opt[field][key] = jax.tree.map(
                    restore, new_opt[field][key], old_opt[field][key])
    return updates, new_opt


def make_train_step(cfg, optimizer, *, frozen: Optional[Tuple[bool, ...]] = None,
                    microbatches: int = 1, impl: str = "xla",
                    clip_norm: float = 1.0, prox_mu: float = 0.0):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``frozen``: static per-freeze-unit mask (FFDAPT); recompiled per distinct
    window — at most N distinct programs over a whole federated run.
    ``prox_mu`` > 0 adds FedProx's mu/2 ||w - w_global||^2 to the objective
    and changes the signature to ``step(params, opt_state, anchor, batch)``
    (the global anchor changes every round, so it is a per-call argument).
    """
    def objective(params, anchor, batch):
        total, metrics = _objective(params, cfg, batch, frozen, impl)
        if prox_mu:
            prox = prox_mu * proximal_penalty(params, anchor)
            total = total + prox
            metrics = dict(metrics, prox=prox)
        return total, metrics

    grad_fn = jax.value_and_grad(objective, has_aux=True)

    def one_micro(params, anchor, mb):
        (total, metrics), grads = grad_fn(params, anchor, mb)
        return grads, metrics

    def train_step(params, opt_state, anchor, batch):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc(carry, mb):
                g_acc, m_acc = carry
                g, m = one_micro(params, anchor, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32),
                  "tokens": jnp.zeros((), jnp.float32)}
            if prox_mu:
                m0["prox"] = jnp.zeros((), jnp.float32)
            (grads, metrics), _ = jax.lax.scan(acc, (g0, m0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {k: v / microbatches if k != "tokens" else v
                       for k, v in metrics.items()}
        else:
            grads, metrics = one_micro(params, anchor, batch)

        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, new_opt = optimizer.update(grads, opt_state, params)
        if frozen is not None and any(frozen):
            updates, new_opt = _apply_freeze_to_updates(
                cfg, frozen, updates, new_opt, opt_state)
        params = apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, new_opt, metrics

    if prox_mu:
        return train_step
    return lambda params, opt_state, batch: train_step(params, opt_state,
                                                       None, batch)


def make_masked_train_step(cfg, optimizer, *, impl: str = "xla",
                           clip_norm: float = 1.0, prox_mu: float = 0.0):
    """Single-program FFDAPT variant: ``freeze_mask`` is a TRACED (L,) float
    {0,1} array multiplying the main-stack gradients — one compiled program
    serves every round, but backward FLOPs are NOT saved (only updates are
    suppressed).  Supported for uniform-stack archs (``layers`` leading dim).
    ``prox_mu`` > 0 adds the FedProx term and the signature becomes
    ``step(params, opt_state, anchor, batch, freeze_mask)``."""
    def objective(params, anchor, batch):
        total, metrics = _objective(params, cfg, batch, None, impl)
        if prox_mu:
            prox = prox_mu * proximal_penalty(params, anchor)
            total = total + prox
            metrics = dict(metrics, prox=prox)
        return total, metrics

    grad_fn = jax.value_and_grad(objective, has_aux=True)

    def train_step(params, opt_state, anchor, batch, freeze_mask):
        (total, metrics), grads = grad_fn(params, anchor, batch)
        keep = 1.0 - freeze_mask                       # (L,) traced

        def mask_stacked(path_grads):
            def one(g):
                shape = (-1,) + (1,) * (g.ndim - 1)
                return g * keep.reshape(shape).astype(g.dtype)
            return jax.tree.map(one, path_grads)

        grads = dict(grads)
        grads["layers"] = mask_stacked(grads["layers"])
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, new_opt = optimizer.update(grads, opt_state, params)
        # frozen layers fully untouched: zero updates + restore moments
        updates = dict(updates)
        updates["layers"] = mask_stacked(updates["layers"])
        sel = freeze_mask > 0.5

        def restore(new, old):
            s = sel.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(s, old, new)

        for field in ("m", "v"):
            if field in new_opt:
                new_opt = dict(new_opt)
                new_opt[field] = dict(new_opt[field])
                new_opt[field]["layers"] = jax.tree.map(
                    restore, new_opt[field]["layers"], opt_state[field]["layers"])
        params = apply_updates(params, updates)
        return params, new_opt, dict(metrics, grad_norm=gnorm)

    if prox_mu:
        return train_step
    return lambda params, opt_state, batch, freeze_mask: train_step(
        params, opt_state, None, batch, freeze_mask)


def make_eval_step(cfg, *, impl: str = "xla"):
    def eval_step(params, batch):
        logits, _, aux = apply_model(params, cfg, batch, mode="train", impl=impl)
        loss, count = lm_loss(logits, batch["targets"],
                              batch["loss_mask"].astype(jnp.float32))
        return {"loss": loss, "aux": aux, "tokens": count}
    return eval_step


def make_prefill_step(cfg, cache_len: int, *, impl: str = "xla",
                      cache_dtype=None):
    """-> prefill_step(params, batch) -> (last_token_logits, filled_cache).

    Only the LAST position's logits are needed — ``last_only`` makes the LM
    head run on one position instead of materializing (B, S, vocab): at
    nemotron scale that buffer alone is 4.2 TB global (16 GB/device)."""
    def prefill_step(params, batch):
        Bn = batch["tokens"].shape[0]
        cache = init_cache(cfg, Bn, cache_len, cache_dtype)
        logits, cache, _ = apply_model(params, cfg, batch, mode="prefill",
                                       cache=cache, impl=impl, last_only=True)
        return logits[:, -1, :], cache
    return prefill_step


def make_serve_step(cfg, *, impl: str = "xla"):
    """-> serve_step(params, batch{tokens (B,1)}, cache) -> (logits, cache).
    One new token against the existing cache — the decode-shape program."""
    def serve_step(params, batch, cache):
        logits, cache, _ = apply_model(params, cfg, batch, mode="decode",
                                       cache=cache, impl=impl)
        return logits[:, -1, :], cache
    return serve_step


def make_slot_serve_step(cfg, *, impl: str = "xla"):
    """The continuous-batching decode program (``repro.serve``): the batch=1
    serve step vmapped over a leading SLOT axis of stacked per-request
    caches.

    -> slot_serve(params, batch{tokens (slots,1,1)}, pool) -> (logits
    (slots,1,V), pool), where every pool leaf is (slots, *batch1_leaf) and
    each slot carries its OWN cache index — per-slot positions, RoPE phases
    and ring-buffer writes fall out of the vmap instead of threading a
    position vector through the model.  The program's shape depends only on
    the pool, so one compile serves every admit/evict sequence (pinned via
    the jit cache-miss counter in tests/test_serve.py), and its per-slot
    math is the single-request math exactly (engine outputs are bitwise
    identical to static decode)."""
    return jax.vmap(make_serve_step(cfg, impl=impl), in_axes=(None, 0, 0))
