"""Parameter-efficient federated training (ParamSpace contract).

See ``docs/peft.md`` for how freezing, low-rank adapters and delta
compression compose.
"""

from repro.peft.space import (ParamSpace, adapter, frozen_shippable_template,
                              frozen_window, full, lora, make_param_space)
from repro.peft.step import make_peft_train_step

__all__ = [
    "ParamSpace", "adapter", "frozen_shippable_template", "frozen_window",
    "full", "lora", "make_param_space", "make_peft_train_step",
]
