"""PEFT client train step: differentiate the bank, freeze the base.

The step merges ``base + ΔW(bank)`` *inside* the objective and takes
gradients w.r.t. the bank only — the base rides along as a traced argument
(never closed over: the engines' compiled-step cache is process-wide, and a
captured base would alias the wrong model across sessions; never stacked:
the cohort-scan carry stays O(bank)).

Signatures mirror ``models.steps.make_train_step`` with ``base`` spliced in
before the FedProx anchor:

    step(bank, opt_state, base, batch)            -> (bank, opt_state, metrics)
    step(bank, opt_state, base, anchor, batch)    (prox_mu > 0; anchor = the
                                                   round-global *bank*)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.steps import _objective, proximal_penalty
from repro.optim import apply_updates, clip_by_global_norm

from repro.peft.space import ParamSpace


def make_peft_train_step(cfg, optimizer, space: ParamSpace, *,
                         impl: str = "xla", clip_norm: float = 1.0,
                         prox_mu: float = 0.0):
    if not space.low_rank:
        raise ValueError(f"make_peft_train_step needs a low-rank space, "
                         f"got {space.kind!r}")

    def objective(bank, base, anchor, batch):
        total, metrics = _objective(space.merge(base, bank), cfg, batch,
                                    None, impl)
        if prox_mu:
            prox = prox_mu * proximal_penalty(bank, anchor)
            total = total + prox
            metrics = dict(metrics, prox=prox)
        return total, metrics

    grad_fn = jax.value_and_grad(objective, has_aux=True)

    def train_step(bank, opt_state, base, anchor, batch):
        (_, metrics), grads = grad_fn(bank, base, anchor, batch)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, new_opt = optimizer.update(grads, opt_state, bank)
        bank = apply_updates(bank, updates)
        return bank, new_opt, dict(metrics, grad_norm=gnorm)

    if prox_mu:
        return train_step
    return lambda bank, opt_state, base, batch: train_step(
        bank, opt_state, base, None, batch)
