"""ParamSpace — declarative trainable/shippable parameter subspaces.

The repo grew three disjoint mechanisms for "train and ship less than the
full model": FFDAPT frozen windows (``frozen=`` kwargs threaded through the
round engines), ``Compressed`` delta codecs, and — new here — low-rank
adapters.  :class:`ParamSpace` unifies them behind one contract:

``full``
    Today's FedAvg rounds, untouched.  ``inject`` is a no-op, the shippable
    tree is the whole model.
``frozen_window``
    FFDAPT re-expressed: the trainable subspace is the unfrozen layer
    window.  The engines keep running the exact pre-refactor masked/static
    step programs (bitwise identity is pinned in tests); what the space adds
    is honest *accounting* — :func:`frozen_shippable_template` prices a
    client's upload at only its unfrozen rows.
``lora(rank, targets)``
    Low-rank deltas ΔW = (alpha/r)·A@B injected next to the attention/MLP
    projections named in :data:`repro.models.blocks.PEFT_TARGETS`.  The A/B
    factor tree (the *bank*) becomes the params tree the federated
    strategies see: aggregation, compression, upload/download accounting and
    the cohort-scan carry all run in subspace coordinates, so comm and
    peak-live shrink to O(bank) with no strategy changes.
``adapter(bottleneck, targets)``
    Linear residual output adapters: W' = W·(I + D@U), i.e. ΔW = W@(D@U).
    Deliberately linear (no nonlinearity between D and U) so the serve-time
    merge ``W + ΔW`` is exact, not an approximation.

Both low-rank kinds zero-init the second factor, so ``merge(base, inject
(base)) == base`` bitwise — a freshly injected run starts from the base
model exactly.

The bank is a plain nested-dict pytree mirroring the base tree's paths,
with each adapted leaf replaced by ``{"a": A, "b": B}`` — it checkpoints,
fingerprints, and aggregates like any params tree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.blocks import PEFT_GROUPS, PEFT_TARGETS

_FNV32 = (2166136261, 16777619)


def _name_hash(name: str) -> int:
    """FNV-1a 31-bit — same scheme as ParamCtx._key_for (python ``hash()``
    is salted per-process; checkpoint determinism needs a stable one)."""
    h, mul = _FNV32
    for ch in name.encode():
        h = ((h ^ ch) * mul) & 0xFFFFFFFF
    return h & 0x7FFFFFFF


def _path_parts(path) -> Tuple[str, ...]:
    out = []
    for q in path:
        out.append(str(getattr(q, "key", getattr(q, "idx", q))))
    return tuple(out)


def _bank_set(bank: dict, parts: Tuple[str, ...], value: Any) -> None:
    node = bank
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = value


def _bank_get(bank: dict, parts: Tuple[str, ...]) -> Any:
    node = bank
    for p in parts:
        node = node[p]
    return node


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Declarative description of the trainable/shippable subspace.

    Hashable (frozen dataclass, tuple targets) so it can key the engines'
    compiled-step caches and the telemetry cost cache directly.
    """

    kind: str = "full"
    rank: int = 0
    alpha: float = 0.0
    targets: Tuple[str, ...] = ("attn", "mlp")

    def __post_init__(self):
        if self.kind not in ("full", "frozen_window", "lora", "adapter"):
            raise ValueError(f"unknown param space kind {self.kind!r}")
        if self.low_rank and self.rank < 1:
            raise ValueError(f"{self.kind} needs rank >= 1, got {self.rank}")
        for t in self.targets:
            if t not in PEFT_TARGETS:
                raise ValueError(
                    f"unknown PEFT target {t!r}; known: {sorted(PEFT_TARGETS)}")

    # -- identity ----------------------------------------------------------

    @property
    def low_rank(self) -> bool:
        return self.kind in ("lora", "adapter")

    @property
    def scale(self) -> float:
        """LoRA merge scale alpha/r (1.0 when alpha unset, and for adapters)."""
        if self.kind != "lora":
            return 1.0
        return (self.alpha or float(self.rank)) / float(self.rank)

    def step_key(self, frozen) -> Any:
        """Compiled-step cache key component.  full/frozen_window return the
        freeze mask verbatim so they share cache entries (and programs) with
        pre-ParamSpace sessions; low-rank spaces key on their geometry."""
        if not self.low_rank:
            return frozen
        return (self.kind, self.rank, float(self.alpha), self.targets)

    def to_json(self) -> dict:
        if not self.low_rank:
            return {"kind": self.kind}
        return {"kind": self.kind, "rank": self.rank,
                "alpha": float(self.alpha), "targets": list(self.targets)}

    @classmethod
    def from_json(cls, d: Optional[dict]) -> Optional["ParamSpace"]:
        if d is None:
            return None
        return cls(kind=d["kind"], rank=int(d.get("rank", 0)),
                   alpha=float(d.get("alpha", 0.0)),
                   targets=tuple(d.get("targets", ("attn", "mlp"))))

    # -- targeting ---------------------------------------------------------

    def _target_split(self, parts: Tuple[str, ...]) -> Optional[Tuple[int, int]]:
        """(n_in_dims, n_out_dims) when this leaf is adapted, else None."""
        if not self.low_rank or len(parts) < 2:
            return None
        name = parts[-1]
        for group in self.targets:
            if name in PEFT_TARGETS[group] and any(
                    c in parts[:-1] for c in PEFT_GROUPS[group]):
                return PEFT_TARGETS[group][name]
        return None

    def _factor_shapes(self, shape: Tuple[int, ...], split: Tuple[int, int]):
        """Leaf shape -> (stack, d_in, d_out, a_shape, b_shape)."""
        ni, no = split
        stack = shape[:len(shape) - ni - no]
        din = int(np.prod(shape[len(stack):len(stack) + ni]))
        dout = int(np.prod(shape[len(shape) - no:]))
        if self.kind == "adapter":
            # W' = W (I + D U): D maps output -> bottleneck, U back out.
            a_shape = stack + (dout, self.rank)
        else:
            a_shape = stack + (din, self.rank)
        b_shape = stack + (self.rank, dout)
        return stack, din, dout, a_shape, b_shape

    # -- bank construction / algebra --------------------------------------

    def inject(self, params: Any, key: Optional[jax.Array] = None) -> Any:
        """Build the trainable bank for ``params`` (empty dict for non-low-rank
        spaces).  A factors are normal-init with deterministic per-leaf keys
        (FNV hash of the leaf path folded into ``key``); B factors are zeros,
        so the injected delta starts at exactly 0."""
        if not self.low_rank:
            return {}
        if key is None:
            key = jax.random.PRNGKey(0)
        leaves, _ = jax.tree_util.tree_flatten_with_path(params)
        bank: dict = {}
        n_hit = 0
        for path, leaf in leaves:
            parts = _path_parts(path)
            split = self._target_split(parts)
            if split is None:
                continue
            n_hit += 1
            _, din, dout, a_shape, b_shape = self._factor_shapes(leaf.shape, split)
            fan = dout if self.kind == "adapter" else din
            std = (1.0 / max(fan, 1)) ** 0.5
            k = jax.random.fold_in(key, _name_hash("/".join(parts)))
            a = std * jax.random.normal(k, a_shape, jnp.float32)
            b = jnp.zeros(b_shape, jnp.float32)
            _bank_set(bank, parts, {"a": a, "b": b})
        if not n_hit:
            raise ValueError(
                f"param space {self.kind}(targets={self.targets}) matched no "
                "leaves in this model — nothing would train")
        return bank

    def _delta(self, w: Any, ab: dict) -> Any:
        """Float32 ΔW for one adapted leaf, shaped like ``w``."""
        a = ab["a"].astype(jnp.float32)
        b = ab["b"].astype(jnp.float32)
        low = jnp.matmul(a, b)                       # stack + (din|dout, dout)
        if self.kind == "adapter":
            stack = w.shape[:low.ndim - 2]
            dout = low.shape[-1]
            din = int(np.prod(w.shape[len(stack):])) // dout
            w2 = w.reshape(stack + (din, dout)).astype(jnp.float32)
            low = jnp.matmul(w2, low)                # W @ (D U)
        else:
            low = low * self.scale
        return low.reshape(w.shape)

    def merge(self, base: Any, bank: Any) -> Any:
        """Fold the bank's deltas into the base tree (serve/eval view).

        Untargeted leaves pass through as the same array objects; targeted
        leaves accumulate in float32 and cast back to the leaf dtype, so a
        zero bank merges to the base bitwise."""
        if not self.low_rank:
            return base

        def one(path, leaf):
            parts = _path_parts(path)
            if self._target_split(parts) is None:
                return leaf
            ab = _bank_get(bank, parts)
            return (leaf.astype(jnp.float32) + self._delta(leaf, ab)
                    ).astype(leaf.dtype)

        return jax.tree_util.tree_map_with_path(one, base)

    def extract_delta(self, base: Any, bank: Any) -> Any:
        """Dense ΔW tree (zeros for untargeted leaves) — what ``merge`` adds.
        Diagnostic / comm-analysis view; the wire format stays the bank."""
        if not self.low_rank:
            return jax.tree.map(jnp.zeros_like, base)

        def one(path, leaf):
            parts = _path_parts(path)
            if self._target_split(parts) is None:
                return jnp.zeros_like(leaf)
            return self._delta(leaf, _bank_get(bank, parts)).astype(leaf.dtype)

        return jax.tree_util.tree_map_with_path(one, base)

    def train_mask(self, base: Any, frozen=None, cfg=None) -> Any:
        """0/1 float tree over ``base``: 1 where a base leaf (or row, for
        frozen windows) is trainable *in base coordinates*.  Low-rank spaces
        train no base leaf at all — their trainables live in the bank."""
        if self.low_rank:
            return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), base)
        if self.kind == "frozen_window" and frozen is not None and cfg is not None:
            from repro.models.steps import _stack_masks
            masks = dict(_stack_masks(cfg, frozen))

            def one(path, leaf):
                top = _path_parts(path)[0]
                if top in masks:
                    keep = (1.0 - masks[top]).reshape(
                        (-1,) + (1,) * (leaf.ndim - 1))
                    return jnp.broadcast_to(keep, leaf.shape).astype(jnp.float32)
                return jnp.ones(leaf.shape, jnp.float32)

            return jax.tree_util.tree_map_with_path(one, base)
        return jax.tree.map(lambda l: jnp.ones(l.shape, jnp.float32), base)

    # -- accounting --------------------------------------------------------

    def shippable_tree(self, params: Any, *, bank: Any = None, frozen=None,
                       cfg=None) -> Any:
        """The tree a client actually ships, for byte accounting.  Low-rank:
        the bank.  frozen_window with an active mask: the unfrozen-row
        template.  Otherwise: the full tree."""
        if self.low_rank:
            return bank if bank is not None else params
        if (self.kind == "frozen_window" and frozen is not None
                and any(frozen) and cfg is not None):
            return frozen_shippable_template(cfg, params, frozen)
        return params

    def trainable_fraction(self, base: Any, *, bank: Any = None,
                           frozen=None) -> float:
        """Trainable params / base params — the analytic dW-FLOP discount
        (backward dW work scales with this fraction)."""
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(base))
        if self.low_rank:
            live = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(bank))
            return live / max(total, 1)
        if self.kind == "frozen_window" and frozen is not None and frozen:
            return 1.0 - sum(frozen) / len(frozen)
        return 1.0


def frozen_shippable_template(cfg, params: Any, frozen: Sequence[bool]) -> Any:
    """ShapeDtypeStruct tree of what a frozen-window client ships: stacked
    top-level entries ("layers"; audio: "enc_layers"+"layers") sliced to
    their unfrozen rows, everything else full-shape.  Feeding this to
    ``strategy.upload_bytes`` prices dense, top-k and int8 uploads in the
    subspace — the strategies' byte formulas are tree-generic."""
    from repro.models.steps import _stack_masks
    kept = {k: int(len(m) - np.sum(np.asarray(m)))
            for k, m in _stack_masks(cfg, frozen)}

    def one(path, leaf):
        top = _path_parts(path)[0]
        shape = leaf.shape
        if top in kept and len(shape) >= 1:
            shape = (kept[top],) + tuple(shape[1:])
        return jax.ShapeDtypeStruct(shape, leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, params)


# -- constructors -----------------------------------------------------------

def full() -> ParamSpace:
    return ParamSpace("full")


def frozen_window() -> ParamSpace:
    return ParamSpace("frozen_window")


def lora(rank: int, *, alpha: float = 0.0,
         targets: Sequence[str] = ("attn", "mlp")) -> ParamSpace:
    return ParamSpace("lora", rank=int(rank), alpha=float(alpha),
                      targets=tuple(targets))


def adapter(bottleneck: int, *,
            targets: Sequence[str] = ("attn", "mlp")) -> ParamSpace:
    return ParamSpace("adapter", rank=int(bottleneck), targets=tuple(targets))


def make_param_space(name: str, *, rank: int = 4, alpha: float = 0.0,
                     adapter_dim: int = 8,
                     targets: Sequence[str] = ("attn", "mlp")) -> ParamSpace:
    """Flag-shaped builder used by ``launch/train.py``."""
    if name == "full":
        return full()
    if name == "frozen_window":
        return frozen_window()
    if name == "lora":
        return lora(rank, alpha=alpha, targets=targets)
    if name == "adapter":
        return adapter(adapter_dim, targets=targets)
    raise ValueError(f"unknown param space {name!r}")
