from repro.sharding.rules import (  # noqa: F401
    Rules,
    DEFAULT_RULES,
    FED_RULES,
    logical_to_spec,
    tree_shardings,
    tree_specs,
)
