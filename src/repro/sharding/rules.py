"""Logical-axis -> mesh PartitionSpec resolution.

MaxText-style rules: each logical axis name (``repro.nn.param``) maps to an
ordered list of *candidate* mesh axes.  Resolution walks a tensor's logical
axes and, per dimension, picks the first candidate mesh axis (or axis tuple)
that (a) exists in the mesh, (b) divides the dimension size, and (c) has not
already been consumed by another dimension of the same tensor.  Anything that
fails all candidates is replicated — a *fallback*, never an error, so every
architecture in the zoo lowers even when its head counts do not match the
mesh (qwen2's 28 heads on a 16-way model axis, whisper's 6, ...).

Main rule tables:
  * ``DEFAULT_RULES``  — 2D/3D tensor+data parallel training/serving layout.
  * ``FED_RULES``      — federated layout: the ``client`` logical axis maps to
    the ``pod`` mesh axis so each pod holds one client's diverging replica.
  * ``COHORT_RULES``   — mega-cohort layout for the cohort-scan engine: one
    client SHARD is live at a time and its client dim takes the whole mesh
    (within-client tensors replicate), so the streaming FedAvg fold lowers
    to a single model-sized all-reduce over clients.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.nn import param as P

# A candidate is a mesh axis name or a tuple of mesh axis names (sharded over
# their product).  ``None`` means "replicate" and always succeeds.
Candidate = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class Rules:
    table: Mapping[str, Tuple[Candidate, ...]]

    def candidates(self, logical: Optional[str]) -> Tuple[Candidate, ...]:
        if logical is None:
            return (None,)
        return self.table.get(logical, (None,))


# Batch shards over every data-like mesh axis present; model-ish dims over
# "model".  Order = priority.
DEFAULT_RULES = Rules({
    P.BATCH:    (("pod", "data"), "data", None),
    P.SEQ:      (None,),                       # seq replicated by default
    P.ATTN_SEQ: (None,),                       # baseline: attention replicates
                                               # over model when heads don't
                                               # divide (see OPT_RULES)
    P.EMBED:    ("data", None),                # FSDP/ZeRO param shard
    P.FFN:      ("model", None),
    P.VOCAB:    ("model", None),
    P.HEADS:    ("model", None),
    P.KV_HEADS: ("model", None),
    P.HEAD_DIM: (None,),
    P.LAYERS:   (None,),                       # scanned, never mesh-sharded
    P.EXPERTS:  ("model", None),
    P.DSTATE:   (None,),
    P.DCONV:    (None,),
    P.CLIENT:   (("pod", "data"), "data", "pod", None),
})

# Federated layout: clients pinned to pods; within a pod the usual layout.
FED_RULES = Rules({
    **DEFAULT_RULES.table,
    P.CLIENT: ("pod", None),
    P.BATCH:  ("data", None),
    P.EMBED:  ("data", None),
})

# Mega-cohort layout for the cohort-scan engine: ONE shard of the stacked
# client axis is live at a time, and that shard's client dim takes every
# mesh axis (the whole machine works on the shard); within a client the
# tensors replicate.  The per-shard weighted-sum fold then lowers to a
# single all-reduce over the client axis whose payload is exactly one
# model's bytes — the committed 512-device HLO fixture
# (tests/fixtures/cohort_agg_512dev.json) pins those collective bytes.
COHORT_RULES = Rules({
    P.CLIENT:   (("pod", "data", "model"), ("pod", "data"), ("data", "model"),
                 ("pod", "model"), "data", "pod", "model", None),
    P.BATCH:    (None,),
    P.SEQ:      (None,),
    P.ATTN_SEQ: (None,),
    P.EMBED:    (None,),
    P.FFN:      (None,),
    P.VOCAB:    (None,),
    P.HEADS:    (None,),
    P.KV_HEADS: (None,),
    P.HEAD_DIM: (None,),
    P.LAYERS:   (None,),
    P.EXPERTS:  (None,),
    P.DSTATE:   (None,),
    P.DCONV:    (None,),
})

# Beyond-paper optimized layout (§Perf): context-parallel attention — the
# query sequence dim shards over "model" whenever the head count doesn't
# divide it, replacing 16x-replicated attention compute.
OPT_RULES = Rules({
    **DEFAULT_RULES.table,
    P.ATTN_SEQ: ("model", None),
})

# Decode: the KV cache is the dominant tensor and kv-head counts rarely
# divide the model axis — shard the cache *sequence* dim over "model"
# (attention contracts over it; GSPMD inserts one small all-reduce).
DECODE_RULES = Rules({
    **DEFAULT_RULES.table,
    P.SEQ: ("model", None),
})

# long_500k has batch=1: everything hangs off the sequence axis, so it takes
# both mesh axes when divisible.
LONG_CONTEXT_RULES = Rules({
    **DEFAULT_RULES.table,
    P.SEQ:   (("data", "model"), "data", "model", None),
    P.BATCH: (None,),
})


def _mesh_axis_sizes(mesh: Mesh) -> Mapping[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _cand_size(cand: Candidate, sizes: Mapping[str, int]) -> Optional[int]:
    if cand is None:
        return 1
    names = (cand,) if isinstance(cand, str) else cand
    total = 1
    for n in names:
        if n not in sizes:
            return None
        total *= sizes[n]
    return total


def logical_to_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                    mesh: Mesh, rules: Rules = DEFAULT_RULES) -> PartitionSpec:
    """Resolve one tensor's logical axes to a PartitionSpec.

    Divisibility-aware: a candidate that does not divide the dim falls through
    to the next; a mesh axis already used by an earlier dim of this tensor is
    skipped (PartitionSpec forbids reuse).
    """
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for logical, dim in zip(axes, shape):
        picked: Candidate = None
        for cand in rules.candidates(logical):
            if cand is None:
                picked = None
                break
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            size = _cand_size(names, sizes)
            if size is None or size <= 1:
                continue
            if any(n in used for n in names):
                continue
            if dim % size != 0:
                continue
            picked = names if len(names) > 1 else names[0]
            used.update(names)
            break
        out.append(picked)
    # Trim trailing Nones (cosmetic; PartitionSpec treats them the same).
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_specs(boxed_tree: Any, mesh: Mesh, rules: Rules = DEFAULT_RULES) -> Any:
    """Boxed pytree (values may be ShapeDtypeStructs) -> PartitionSpec pytree."""
    def one(b):
        if not P.is_box(b):
            return PartitionSpec()
        return logical_to_spec(b.axes, b.value.shape, mesh, rules)
    return jax.tree.map(one, boxed_tree, is_leaf=P.is_box)


def tree_shardings(boxed_tree: Any, mesh: Mesh, rules: Rules = DEFAULT_RULES) -> Any:
    def one(b):
        if not P.is_box(b):
            return NamedSharding(mesh, PartitionSpec())
        return NamedSharding(mesh, logical_to_spec(b.axes, b.value.shape, mesh, rules))
    return jax.tree.map(one, boxed_tree, is_leaf=P.is_box)


def spec_bytes_per_device(shape: Sequence[int], dtype, spec: PartitionSpec,
                          mesh: Mesh) -> int:
    """Post-sharding per-device bytes for one tensor (roofline bookkeeping)."""
    sizes = _mesh_axis_sizes(mesh)
    denom = 1
    for entry in spec:
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        for n in names:
            denom *= sizes.get(n, 1)
    return int(np.prod(shape)) * np.dtype(dtype).itemsize // max(denom, 1)
