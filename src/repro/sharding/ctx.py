"""Ambient activation-sharding context (MaxText's logical-constraint idiom).

GSPMD's sharding propagation regularly loses activation shardings inside
scanned layer bodies (the carry defaults to replicated) — on the production
mesh that silently replicates attention over the model axis, a 16x compute
regression the dry-run caught.  The fix is explicit constraints on the
residual stream / projection activations, expressed in *logical* axes and
resolved against whatever mesh+rules the launcher installed:

    with activation_sharding(mesh, rules):
        lowered = jax.jit(step, ...).lower(...)

Inside model code:  ``x = constrain(x, (BATCH, SEQ, EMBED))`` — a no-op when
no context is installed (CPU unit tests, plain eager use).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import Rules, logical_to_spec

_state = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Rules):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def current() -> Optional[tuple]:
    return getattr(_state, "ctx", None)


def data_parallel_size() -> int:
    """Product of the batch-carrying mesh axes (pod x data) in the ambient
    context; 1 when no context (CPU tests)."""
    ctx = current()
    if ctx is None:
        return 1
    mesh, _ = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = current()
    if ctx is None or not hasattr(x, "ndim"):
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        return x
    spec = logical_to_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
