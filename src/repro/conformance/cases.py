"""Declarative conformance case grid over the Pallas kernel zoo.

Every Pallas kernel registers a :class:`KernelSpec` — how to build
deterministic inputs for a :class:`Case`, how to run the kernel and its
``repro.kernels.ref`` oracle, and (for the recurrent scans) how to express
the state-chaining algebraic invariant.  The module-level :data:`CASES`
grid is the single source the pytest suite, ``benchmarks/kernel_bench.py``,
and ``scripts/kernel_smoke.sh`` all sweep, so "which shapes/dtypes/regimes
are covered" is one reviewable list instead of scattered test bodies.

Case axes:

  * **shape lattice** — the block-aligned, padded (non-multiple), MQA/GQA,
    cross-length, chunk>T corners of each kernel's tiling;
  * **dtype** — float32 and bfloat16, judged under the tolerance ladder
    (``repro.conformance.tolerances``);
  * **adversarial numerics** (tagged ``adversarial``) — extreme decay
    (|la| at 40/60 where a factorized pairwise form loses the mantissa),
    softcap saturation, denormal-scale inputs, fully-masked kv blocks,
    zero step sizes;
  * **chain cases** (``chain=True``) — split-at-t scans with carried state
    must equal the full-length scan (a property of the kernel itself, no
    oracle needed).

Adding a kernel = registering a spec + appending cases here; the harness,
bench, smoke script, and CI pick it up with no further wiring (the
registration how-to lives in docs/kernels.md).
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

KERNEL_NAMES = ("flash_attention", "rwkv6_scan", "mamba2_scan", "moe_gmm")


@dataclasses.dataclass(frozen=True)
class Case:
    """One grid point.  ``dims``/``kwargs`` are stored as sorted item
    tuples so cases are hashable and JSON-friendly."""

    kernel: str
    name: str                               # unique: "<kernel>/<slug>"
    dims: Tuple[Tuple[str, int], ...]
    dtype: str = "float32"
    tags: Tuple[str, ...] = ()
    seed: int = 0
    vjp: bool = True                        # run the gradient comparison
    chain: bool = False                     # run the state-chaining property
    tol_scale: float = 1.0                  # explicit per-case ladder loosen
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    def dim(self, key: str) -> int:
        return dict(self.dims)[key]

    @property
    def kw(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def key(self) -> jax.Array:
        """Deterministic per-case PRNG key (stable across sessions)."""
        return jax.random.PRNGKey(zlib.crc32(self.name.encode()) + self.seed)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """How the harness drives one kernel.

    ``make_inputs(case)`` -> input tuple (deterministic in the case);
    ``kernel_fn(case)`` / ``ref_fn(case)`` -> positional callables over
    that tuple; ``chain_fn(case, inputs)`` -> ``(got, want)`` pytrees for
    the split-scan invariant (scan kernels only)."""

    name: str
    make_inputs: Callable[[Case], Tuple]
    kernel_fn: Callable[[Case], Callable]
    ref_fn: Callable[[Case], Callable]
    chain_fn: Optional[Callable[[Case, Tuple], Tuple[Any, Any]]] = None


KERNELS: Dict[str, KernelSpec] = {}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    if spec.name in KERNELS:
        raise ValueError(f"kernel {spec.name!r} already registered")
    KERNELS[spec.name] = spec
    return spec


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _flash_inputs(case: Case):
    B, S, T = case.dim("B"), case.dim("S"), case.dim("T")
    H, Kv, D = case.dim("H"), case.dim("Kv"), case.dim("D")
    scale = case.kw.get("qk_scale", 1.0)
    ks = jax.random.split(case.key(), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), case.jdtype) * scale
    k = jax.random.normal(ks[1], (B, T, Kv, D), case.jdtype) * scale
    v = jax.random.normal(ks[2], (B, T, Kv, D), case.jdtype)
    return (q, k, v)


def _flash_kernel(case: Case):
    kw = case.kw
    return functools.partial(
        ops.flash_attention, causal=kw.get("causal", True),
        window=kw.get("window", 0), softcap=kw.get("softcap", 0.0),
        block_q=kw.get("block_q", 16), block_k=kw.get("block_k", 16))


def _flash_ref(case: Case):
    kw = case.kw
    return functools.partial(
        ref.attention, causal=kw.get("causal", True),
        window=kw.get("window", 0), softcap=kw.get("softcap", 0.0))


register_kernel(KernelSpec("flash_attention", _flash_inputs, _flash_kernel,
                           _flash_ref))


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------

def _rwkv_inputs(case: Case):
    B, T, H, D = (case.dim(x) for x in ("B", "T", "H", "D"))
    scale = case.kw.get("x_scale", 1.0)
    ks = jax.random.split(case.key(), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D), case.jdtype) * scale
               for i in range(3))
    wmode = case.kw.get("w_mode", "sigmoid")
    wraw = jax.random.normal(ks[3], (B, T, H, D))
    if wmode == "sigmoid":
        w = jax.nn.sigmoid(wraw)
    elif wmode == "harsh":          # near-total per-step decay, w ~ e^-12
        w = jnp.exp(-jnp.exp(wraw + 0.5))
    elif wmode == "harsh-logit":    # same regime; input IS the decay logit
        w = wraw + 0.5
    elif wmode == "near-one":       # log(w) precision regime
        w = 1.0 - 1e-6 * jax.nn.sigmoid(wraw)
    else:
        raise ValueError(f"unknown w_mode {wmode!r}")
    w = w.astype(case.jdtype)
    u = jax.random.normal(ks[4], (H, D), case.jdtype)
    s0 = jax.random.normal(ks[5], (B, H, D, D), jnp.float32) \
        * case.kw.get("s0_scale", 1.0)
    return (r, k, v, w, u, s0)


def _rwkv_logit_wrap(fn, case: Case):
    """``harsh-logit`` cases differentiate wrt the decay LOGIT (RWKV's
    actual parameterization, ``w = exp(-exp(l))``): the chunked backward's
    ``1/w`` factors cancel against ``dw/dl = -exp(l) w``, so the gradient
    is well-conditioned even where channels decay to ~e^-50.  Gradients
    wrt RAW ``w`` in that regime are formulation-induced ill-conditioning
    (see docs/kernels.md) — those cases run forward/chain only."""
    if case.kw.get("w_mode") != "harsh-logit":
        return fn

    def wrapped(r, k, v, wlog, u, s0):
        return fn(r, k, v, jnp.exp(-jnp.exp(wlog)), u, s0)
    return wrapped


def _rwkv_kernel(case: Case):
    return _rwkv_logit_wrap(
        functools.partial(ops.rwkv6_scan, chunk=case.kw.get("chunk", 8)),
        case)


def _rwkv_ref(case: Case):
    return _rwkv_logit_wrap(ref.rwkv6_scan, case)


def _rwkv_chain(case: Case, inputs):
    r, k, v, w, u, s0 = inputs
    split = case.kw["split"]
    c1, c2 = case.kw.get("chunk1", 4), case.kw.get("chunk2", 8)
    y1, s1 = ops.rwkv6_scan(r[:, :split], k[:, :split], v[:, :split],
                            w[:, :split], u, s0, chunk=c1)
    y2, s2 = ops.rwkv6_scan(r[:, split:], k[:, split:], v[:, split:],
                            w[:, split:], u, s1, chunk=c2)
    full = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=case.kw.get("chunk", 8))
    return (jnp.concatenate([y1, y2], axis=1), s2), full


register_kernel(KernelSpec("rwkv6_scan", _rwkv_inputs, _rwkv_kernel,
                           _rwkv_ref, _rwkv_chain))


# ---------------------------------------------------------------------------
# mamba2_scan
# ---------------------------------------------------------------------------

def _mamba_inputs(case: Case):
    B, T, H, P, N = (case.dim(x) for x in ("B", "T", "H", "P", "N"))
    ks = jax.random.split(case.key(), 6)
    x = jax.random.normal(ks[0], (B, T, H, P), case.jdtype)
    dt_const = case.kw.get("dt_const")
    if dt_const is None:
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    else:                           # pinned per-step decay, |la| targeting
        dt = jnp.full((B, T, H), dt_const, jnp.float32)
    dt = dt.astype(case.jdtype)
    a_log = (jax.random.normal(ks[2], (H,)) * 0.1
             if case.kw.get("a_mode", "random") == "random"
             else jnp.zeros((H,)))                    # A = -1 exactly
    b = jax.random.normal(ks[3], (B, T, N), case.jdtype)
    c = jax.random.normal(ks[4], (B, T, N), case.jdtype)
    h0 = jax.random.normal(ks[5], (B, H, P, N), jnp.float32)
    return (x, dt, a_log, b, c, h0)


def _mamba_kernel(case: Case):
    return functools.partial(ops.mamba2_scan, chunk=case.kw.get("chunk", 8))


def _mamba_ref(case: Case):
    return ref.mamba2_scan


def _mamba_chain(case: Case, inputs):
    x, dt, a_log, b, c, h0 = inputs
    split = case.kw["split"]
    c1, c2 = case.kw.get("chunk1", 4), case.kw.get("chunk2", 8)
    _, h1 = ops.mamba2_scan(x[:, :split], dt[:, :split], a_log, b[:, :split],
                            c[:, :split], h0, chunk=c1)
    y2, h2 = ops.mamba2_scan(x[:, split:], dt[:, split:], a_log, b[:, split:],
                             c[:, split:], h1, chunk=c2)
    y_full, h_full = ops.mamba2_scan(x, dt, a_log, b, c, h0,
                                     chunk=case.kw.get("chunk", 8))
    return (y2, h2), (y_full[:, split:], h_full)


register_kernel(KernelSpec("mamba2_scan", _mamba_inputs, _mamba_kernel,
                           _mamba_ref, _mamba_chain))


# ---------------------------------------------------------------------------
# moe_gmm
# ---------------------------------------------------------------------------

def _moe_inputs(case: Case):
    E, C, d, f = (case.dim(x) for x in ("E", "C", "d", "f"))
    scale = case.kw.get("x_scale", 1.0)
    ks = jax.random.split(case.key(), 4)
    xe = jax.random.normal(ks[0], (E, C, d), case.jdtype) * scale
    wg = (jax.random.normal(ks[1], (E, d, f)) * 0.1).astype(case.jdtype)
    wu = (jax.random.normal(ks[2], (E, d, f)) * 0.1).astype(case.jdtype)
    wo = (jax.random.normal(ks[3], (E, f, d)) * 0.1).astype(case.jdtype)
    return (xe, wg, wu, wo)


def _moe_kernel(case: Case):
    return functools.partial(ops.moe_ffn, block_c=case.kw.get("block_c", 8),
                             block_f=case.kw.get("block_f", 8))


def _moe_ref(case: Case):
    return ref.moe_ffn


register_kernel(KernelSpec("moe_gmm", _moe_inputs, _moe_kernel, _moe_ref))


# ---------------------------------------------------------------------------
# The grid
# ---------------------------------------------------------------------------

def _c(kernel: str, slug: str, dims: Dict[str, int], **kw) -> Case:
    dtype = kw.pop("dtype", "float32")
    tags = tuple(kw.pop("tags", ()))
    vjp = kw.pop("vjp", True)
    chain = kw.pop("chain", False)
    tol_scale = kw.pop("tol_scale", 1.0)
    return Case(kernel=kernel, name=f"{kernel}/{slug}",
                dims=tuple(sorted(dims.items())), dtype=dtype, tags=tags,
                vjp=vjp, chain=chain, tol_scale=tol_scale,
                kwargs=tuple(sorted(kw.items())))


def _flash_cases():
    lattice = [
        ("mha-tiny", dict(B=1, S=8, T=8, H=2, Kv=2, D=8)),
        ("gqa-unaligned", dict(B=2, S=37, T=37, H=8, Kv=4, D=16)),
        ("mqa-64", dict(B=1, S=64, T=64, H=4, Kv=1, D=32)),
        ("cross-len", dict(B=2, S=16, T=48, H=4, Kv=4, D=8)),
    ]
    out = []
    for slug, dims in lattice:
        causal = dims["S"] == dims["T"]
        for dtype in ("float32", "bfloat16"):
            suffix = "" if dtype == "float32" else "-bf16"
            out.append(_c("flash_attention", slug + suffix, dims,
                          dtype=dtype, causal=causal, tags=("lattice",)))
    win = dict(B=2, S=33, T=33, H=4, Kv=2, D=8)
    out += [
        _c("flash_attention", "window-4", win, window=4, tags=("window",)),
        _c("flash_attention", "window-31", win, window=31, tags=("window",)),
        _c("flash_attention", "window-16-bf16", win, window=16,
           dtype="bfloat16", tags=("window",)),
        _c("flash_attention", "softcap", dict(B=1, S=24, T=24, H=2, Kv=2,
                                              D=8),
           softcap=20.0, qk_scale=3.0, block_q=8, block_k=8,
           tags=("softcap",)),
        # scores driven deep into the tanh rail: |qk| >> softcap
        _c("flash_attention", "softcap-saturated",
           dict(B=1, S=24, T=24, H=2, Kv=2, D=8), softcap=5.0, qk_scale=30.0,
           block_q=8, block_k=8, tags=("adversarial", "softcap")),
        # window << block: most kv blocks are FULLY masked for a q block
        _c("flash_attention", "all-masked-blocks",
           dict(B=1, S=64, T=64, H=4, Kv=2, D=8), window=4,
           tags=("adversarial", "masked-blocks")),
    ]
    return out


def _rwkv_cases():
    lattice = [
        ("tiny", dict(B=1, T=8, H=1, D=4), dict(chunk=4)),
        ("padded", dict(B=2, T=19, H=3, D=8), dict(chunk=8)),
        ("long", dict(B=1, T=64, H=2, D=16), dict(chunk=32)),
    ]
    out = []
    for slug, dims, kw in lattice:
        for dtype in ("float32", "bfloat16"):
            suffix = "" if dtype == "float32" else "-bf16"
            out.append(_c("rwkv6_scan", slug + suffix, dims, dtype=dtype,
                          tags=("lattice",), **kw))
    out += [
        # raw-w gradients are ill-conditioned at this decay (1/w factors
        # that only cancel analytically) -> forward-only here, with the
        # well-posed logit-space VJP covered by the case below
        _c("rwkv6_scan", "harsh-decay", dict(B=2, T=48, H=2, D=8),
           w_mode="harsh", chunk=16, vjp=False,
           tags=("adversarial", "decay")),
        _c("rwkv6_scan", "harsh-decay-logit", dict(B=2, T=48, H=2, D=8),
           w_mode="harsh-logit", chunk=16, tol_scale=4.0,
           tags=("adversarial", "decay")),
        _c("rwkv6_scan", "decay-near-1", dict(B=1, T=32, H=2, D=8),
           w_mode="near-one", chunk=8, tags=("adversarial", "decay")),
        _c("rwkv6_scan", "denormal", dict(B=1, T=16, H=2, D=8),
           x_scale=1e-20, s0_scale=1e-20, chunk=8,
           tags=("adversarial", "denormal")),
        _c("rwkv6_scan", "chunk-gt-T", dict(B=2, T=30, H=2, D=8), chunk=64,
           tags=("padding",)),
        _c("rwkv6_scan", "chain-split10", dict(B=1, T=24, H=2, D=8),
           split=10, chunk=8, chunk1=4, chunk2=8, chain=True, vjp=False,
           tags=("chain",)),
        _c("rwkv6_scan", "chain-harsh", dict(B=1, T=32, H=2, D=8),
           w_mode="harsh", split=16, chunk=8, chunk1=8, chunk2=4,
           chain=True, vjp=False, tags=("chain", "decay")),
    ]
    return out


def _mamba_cases():
    out = [
        _c("mamba2_scan", "tiny", dict(B=1, T=8, H=1, P=4, N=4), chunk=4,
           tags=("lattice",)),
        _c("mamba2_scan", "padded", dict(B=2, T=13, H=3, P=4, N=5), chunk=4,
           tags=("lattice",)),
        _c("mamba2_scan", "long", dict(B=1, T=32, H=4, P=8, N=16), chunk=16,
           tags=("lattice",)),
        _c("mamba2_scan", "bf16", dict(B=2, T=32, H=2, P=4, N=8), chunk=16,
           dtype="bfloat16", tags=("lattice",)),
        # |la| = cumulative dt*A inside one chunk; A = -1 pinned, dt const.
        # 40 is where a factorized exp(la_t)*exp(-la_s) form starts losing
        # the fp32 mantissa (the PR 2 fix); 60 is well past it.
        _c("mamba2_scan", "decay-la40", dict(B=1, T=64, H=2, P=4, N=8),
           chunk=32, dt_const=1.25, a_mode="unit",
           tags=("adversarial", "decay")),
        _c("mamba2_scan", "decay-la60", dict(B=1, T=64, H=2, P=4, N=8),
           chunk=32, dt_const=1.875, a_mode="unit",
           tags=("adversarial", "decay", "decay60")),
        _c("mamba2_scan", "denormal-dt", dict(B=1, T=16, H=2, P=4, N=8),
           chunk=8, dt_const=1e-30, tags=("adversarial", "denormal")),
        _c("mamba2_scan", "zero-dt", dict(B=1, T=16, H=2, P=4, N=8),
           chunk=8, dt_const=0.0, tags=("adversarial", "zero-dt")),
        _c("mamba2_scan", "chunk-gt-T", dict(B=2, T=30, H=2, P=4, N=8),
           chunk=64, tags=("padding",)),
        _c("mamba2_scan", "wide-state", dict(B=1, T=16, H=2, P=4, N=32),
           chunk=8, tags=("lattice",)),
        _c("mamba2_scan", "chain-split7", dict(B=1, T=20, H=2, P=4, N=8),
           split=7, chunk=8, chunk1=4, chunk2=8, chain=True, vjp=False,
           tags=("chain",)),
        _c("mamba2_scan", "chain-decay", dict(B=1, T=32, H=2, P=4, N=8),
           split=16, chunk=8, chunk1=8, chunk2=4, dt_const=1.875,
           a_mode="unit", chain=True, vjp=False, tags=("chain", "decay")),
    ]
    return out


def _moe_cases():
    lattice = [
        ("square", dict(E=2, C=8, d=16, f=16)),
        ("padded", dict(E=3, C=10, d=16, f=24)),
        ("wide", dict(E=8, C=32, d=32, f=8)),
    ]
    out = []
    for slug, dims in lattice:
        for dtype in ("float32", "bfloat16"):
            suffix = "" if dtype == "float32" else "-bf16"
            out.append(_c("moe_gmm", slug + suffix, dims, dtype=dtype,
                          tags=("lattice",)))
    out += [
        _c("moe_gmm", "denormal", dict(E=2, C=8, d=16, f=16), x_scale=1e-20,
           tags=("adversarial", "denormal")),
        _c("moe_gmm", "single-expert", dict(E=1, C=8, d=16, f=16),
           tags=("lattice",)),
        _c("moe_gmm", "f-padded", dict(E=2, C=8, d=16, f=40), block_f=16,
           tags=("padding",)),
        _c("moe_gmm", "c-padded-bf16", dict(E=2, C=9, d=16, f=16),
           dtype="bfloat16", tags=("padding",)),
    ]
    return out


CASES: Tuple[Case, ...] = tuple(_flash_cases() + _rwkv_cases()
                                + _mamba_cases() + _moe_cases())

_BY_NAME = {c.name: c for c in CASES}
if len(_BY_NAME) != len(CASES):
    raise AssertionError("duplicate conformance case names")


def get_case(name: str) -> Case:
    return _BY_NAME[name]


def iter_cases(*, kernel: Optional[str] = None,
               tags: Tuple[str, ...] = ()) -> Tuple[Case, ...]:
    """Filter the grid by kernel and/or tags (a case matches if it carries
    ANY of the requested tags)."""
    out = []
    for c in CASES:
        if kernel is not None and c.kernel != kernel:
            continue
        if tags and not set(tags) & set(c.tags):
            continue
        out.append(c)
    return tuple(out)
