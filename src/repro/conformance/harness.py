"""Runs the conformance grid: differential forward, differential VJP,
chain properties, and (for the bench) kernel-vs-ref timing.

``run_case`` is the single execution path — pytest, ``kernel_smoke.sh``,
and ``benchmarks/kernel_bench.py`` all call it, so "what does a case
check" cannot fork between CI and the pinned baselines:

  * **forward** — kernel output vs the sequential oracle, every output
    leaf, under ``tolerances.forward_tol(kernel, dtype)``;
  * **vjp** — ``jax.grad`` of an identical scalar loss (sum of squares
    over all output leaves, fp32) through the Pallas op's ``custom_vjp``
    vs through the oracle's autodiff, every input, under ``vjp_tol``;
  * **chain** — the kernel's own split-at-t invariant (no oracle), under
    the forward tolerance;
  * **timing** (opt-in) — jit'd kernel vs jit'd oracle, min-of-reps after
    a warmup call.  On a non-TPU backend the kernel runs in interpret
    mode, so the speed ratio is *recorded but never asserted*
    (``interpret`` is part of every result row; see docs/kernels.md).

Results are plain dataclasses with a ``to_row()`` JSON form — the bench
files are just ``[r.to_row() for r in run_grid(...)]`` plus metadata.
Each executed case is wrapped in an ``obs.span("conformance.case")`` so a
traced run shows per-case wall-clock in the same Perfetto timeline as the
round engine.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.conformance import cases as _cases
from repro.conformance import tolerances as _tol
from repro.conformance.cases import CASES, KERNELS, Case


def interpret_mode() -> bool:
    """True when Pallas kernels run interpreted (any non-TPU backend)."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class CaseResult:
    """Outcome of one case.  ``*_violation`` is the worst
    ``|got-want| / (atol + rtol*|want|)`` ratio (<= 1 passes); ``None``
    means that check did not run for this case."""

    name: str
    kernel: str
    dtype: str
    tags: Tuple[str, ...]
    fwd_violation: Optional[float]
    vjp_violation: Optional[float]
    chain_violation: Optional[float]
    kernel_ms: Optional[float] = None
    ref_ms: Optional[float] = None
    interpret: bool = True

    @property
    def ok(self) -> bool:
        return all(v is None or v <= 1.0 for v in
                   (self.fwd_violation, self.vjp_violation,
                    self.chain_violation))

    @property
    def speedup(self) -> Optional[float]:
        if self.kernel_ms and self.ref_ms:
            return self.ref_ms / self.kernel_ms
        return None

    def to_row(self) -> Dict[str, Any]:
        row = {"name": self.name, "kernel": self.kernel, "dtype": self.dtype,
               "tags": list(self.tags), "ok": self.ok,
               "fwd_violation": self.fwd_violation,
               "vjp_violation": self.vjp_violation,
               "chain_violation": self.chain_violation,
               "interpret": self.interpret}
        if self.kernel_ms is not None:
            row["kernel_ms"] = self.kernel_ms
            row["ref_ms"] = self.ref_ms
            row["speedup"] = self.speedup
        return row


def _loss(fn, inputs) -> jax.Array:
    """Scalar sum-of-squares over every output leaf, fp32."""
    out = fn(*inputs)
    return sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
               for leaf in jax.tree_util.tree_leaves(out))


def _leaf_violation(tol: _tol.Tol, got, want) -> float:
    leaves_g = jax.tree_util.tree_leaves(got)
    leaves_w = jax.tree_util.tree_leaves(want)
    assert len(leaves_g) == len(leaves_w)
    return max(tol.violation(g, w) for g, w in zip(leaves_g, leaves_w))


def _time_ms(fn, inputs, reps: int) -> float:
    jitted = jax.jit(fn)
    jax.block_until_ready(jitted(*inputs))        # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*inputs))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def run_case(case: Case, *, timed: bool = False, reps: int = 3) -> CaseResult:
    """Execute one grid point: forward diff always; VJP / chain / timing
    per the case flags."""
    spec = KERNELS[case.kernel]
    interp = interpret_mode()
    with obs.span("conformance.case", case=case.name, kernel=case.kernel,
                  dtype=case.dtype):
        inputs = spec.make_inputs(case)
        kfn, rfn = spec.kernel_fn(case), spec.ref_fn(case)

        def scaled(tol: _tol.Tol) -> _tol.Tol:
            if case.tol_scale == 1.0:
                return tol
            return _tol.Tol(tol.rtol * case.tol_scale,
                            tol.atol * case.tol_scale)

        fwd_tol = scaled(_tol.forward_tol(case.kernel, case.dtype))
        fwd_v = _leaf_violation(fwd_tol, kfn(*inputs), rfn(*inputs))

        vjp_v = None
        if case.vjp:
            argnums = tuple(range(len(inputs)))
            gk = jax.grad(lambda *a: _loss(kfn, a), argnums=argnums)(*inputs)
            gr = jax.grad(lambda *a: _loss(rfn, a), argnums=argnums)(*inputs)
            vjp_v = _leaf_violation(
                scaled(_tol.vjp_tol(case.kernel, case.dtype)), gk, gr)

        chain_v = None
        if case.chain:
            if spec.chain_fn is None:
                raise ValueError(f"{case.kernel} has no chain property")
            got, want = spec.chain_fn(case, inputs)
            chain_v = _leaf_violation(fwd_tol, got, want)

        kernel_ms = ref_ms = None
        if timed:
            kernel_ms = _time_ms(kfn, inputs, reps)
            ref_ms = _time_ms(rfn, inputs, reps)

    return CaseResult(name=case.name, kernel=case.kernel, dtype=case.dtype,
                      tags=case.tags, fwd_violation=fwd_v,
                      vjp_violation=vjp_v, chain_violation=chain_v,
                      kernel_ms=kernel_ms, ref_ms=ref_ms, interpret=interp)


def run_grid(cases: Sequence[Case] = CASES, *, timed: bool = False,
             reps: int = 3, progress=None) -> List[CaseResult]:
    """Run a sequence of cases (the full registry by default)."""
    out = []
    for case in cases:
        res = run_case(case, timed=timed, reps=reps)
        if progress is not None:
            progress(res)
        out.append(res)
    return out


def summarize(results: Sequence[CaseResult]) -> Dict[str, Any]:
    """Aggregate a grid run into the JSON block the bench file pins."""
    by_kernel: Dict[str, Dict[str, int]] = {}
    for r in results:
        k = by_kernel.setdefault(r.kernel, {"cases": 0, "ok": 0, "vjp": 0,
                                            "chain": 0})
        k["cases"] += 1
        k["ok"] += int(r.ok)
        k["vjp"] += int(r.vjp_violation is not None)
        k["chain"] += int(r.chain_violation is not None)
    worst = {
        "fwd": max((r.fwd_violation or 0.0) for r in results),
        "vjp": max((r.vjp_violation or 0.0) for r in results),
        "chain": max((r.chain_violation or 0.0) for r in results),
    }
    return {
        "n_cases": len(results),
        "n_ok": sum(r.ok for r in results),
        "n_failed": sum(not r.ok for r in results),
        "by_kernel": by_kernel,
        "worst_violation": worst,
        "interpret": bool(results[0].interpret) if results else None,
    }
