"""repro.conformance: differential + gradient testing for the Pallas
fast path.

``kernels/ref.py`` is the oracle; every Pallas kernel sweeps a
declarative case grid (:mod:`repro.conformance.cases`) under a shared
per-(kernel, dtype, direction) tolerance ladder
(:mod:`repro.conformance.tolerances`), executed by one harness
(:mod:`repro.conformance.harness`) that pytest, ``kernel_smoke.sh``, and
``benchmarks/kernel_bench.py`` all share.  See docs/kernels.md for the
ladder policy and the register-a-kernel how-to.
"""

from repro.conformance.cases import (CASES, KERNEL_NAMES, KERNELS, Case,
                                     KernelSpec, get_case, iter_cases,
                                     register_kernel)
from repro.conformance.harness import (CaseResult, interpret_mode, run_case,
                                       run_grid, summarize)
from repro.conformance.tolerances import (Tol, forward_tol, ladder, vjp_tol)

__all__ = [
    "CASES", "Case", "CaseResult", "KERNELS", "KERNEL_NAMES", "KernelSpec",
    "Tol", "forward_tol", "get_case", "interpret_mode", "iter_cases",
    "ladder", "register_kernel", "run_case", "run_grid", "summarize",
    "vjp_tol",
]
