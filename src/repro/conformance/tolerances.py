"""The kernel tolerance ladder: ONE table for tests, harness, and bench.

Every Pallas kernel is compared against its ``repro.kernels.ref`` oracle
under a per-(kernel, dtype, direction) tolerance.  These used to live as
scattered rtol/atol literals inside ``tests/test_kernels.py``; hoisting
them here means the pytest suite, the conformance harness, and
``benchmarks/kernel_bench.py`` cannot drift apart — a tolerance change is
one diff line reviewed once.

Ladder policy (see docs/kernels.md for the full rationale):

  * ``float32`` forward — 2e-5 for the matmul-shaped kernels
    (``flash_attention``, ``moe_gmm``: one fp32 accumulation chain), 1e-4
    for the recurrent scans (``mamba2_scan``, ``rwkv6_scan``: T-step decay
    products compound rounding, and the chunked formulations regroup the
    arithmetic).
  * ``bfloat16`` forward — 2e-2 everywhere: the inputs themselves carry
    ~3 decimal digits, so the bound is dominated by input rounding, not by
    kernel arithmetic.
  * VJP — one ladder rung looser than forward: a backward pass roughly
    doubles the accumulation depth (recompute + cotangent contraction),
    and the scan backwards differentiate the *chunked* formulation against
    the sequential oracle's autodiff.

Comparisons use the ``numpy.testing.assert_allclose`` predicate
``|got - want| <= atol + rtol * |want|`` elementwise; ``Tol.violation``
returns the worst ratio of the left side to the right side, so ``<= 1``
passes and the margin is measurable (the bench files record it).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Tol:
    """One rung of the ladder (``assert_allclose`` semantics)."""

    rtol: float
    atol: float

    def kw(self) -> Dict[str, float]:
        """Keyword form for ``np.testing.assert_allclose(**tol.kw())``."""
        return {"rtol": self.rtol, "atol": self.atol}

    def violation(self, got, want) -> float:
        """Worst-case ``|got-want| / (atol + rtol*|want|)`` over all
        elements (fp32 compare): ``<= 1.0`` means the pair passes."""
        g = np.asarray(got, np.float32)
        w = np.asarray(want, np.float32)
        denom = self.atol + self.rtol * np.abs(w)
        return float(np.max(np.abs(g - w) / denom)) if g.size else 0.0


def _dt(dtype) -> str:
    """Canonical dtype key ('float32' / 'bfloat16' / ...)."""
    return str(jnp.dtype(dtype))


# (kernel, dtype, direction) -> Tol; None kernel = dtype default.
_LADDER: Dict[Tuple[object, str, str], Tol] = {
    # dtype defaults
    (None, "float32", "fwd"): Tol(2e-5, 2e-5),
    (None, "bfloat16", "fwd"): Tol(2e-2, 2e-2),
    (None, "float32", "vjp"): Tol(2e-4, 2e-4),
    (None, "bfloat16", "vjp"): Tol(4e-2, 4e-2),
    # recurrent scans: decay-product accumulation + chunked regrouping
    ("mamba2_scan", "float32", "fwd"): Tol(1e-4, 1e-4),
    ("rwkv6_scan", "float32", "fwd"): Tol(1e-4, 1e-4),
    ("mamba2_scan", "float32", "vjp"): Tol(5e-4, 5e-4),
    ("rwkv6_scan", "float32", "vjp"): Tol(5e-4, 5e-4),
}


def forward_tol(kernel: str, dtype) -> Tol:
    """Forward-pass tolerance for ``kernel`` at ``dtype`` (per-kernel
    override first, dtype default second)."""
    return _lookup(kernel, dtype, "fwd")


def vjp_tol(kernel: str, dtype) -> Tol:
    """Gradient tolerance for ``kernel`` at ``dtype``."""
    return _lookup(kernel, dtype, "vjp")


def _lookup(kernel: str, dtype, direction: str) -> Tol:
    key = _dt(dtype)
    try:
        return _LADDER.get((kernel, key, direction), _LADDER[(None, key,
                                                              direction)])
    except KeyError:
        raise KeyError(f"no {direction!r} tolerance for dtype {key!r} — add "
                       f"a rung to repro.conformance.tolerances._LADDER"
                       ) from None


def ladder() -> Dict[str, Dict[str, float]]:
    """The full table as JSON-able rows (the bench file embeds it so a
    committed baseline records the policy it was judged under)."""
    out = {}
    for (kernel, dtype, direction), tol in sorted(
            _LADDER.items(), key=lambda kv: (kv[0][0] or "", kv[0][1],
                                             kv[0][2])):
        name = f"{kernel or 'default'}/{dtype}/{direction}"
        out[name] = {"rtol": tol.rtol, "atol": tol.atol}
    return out
