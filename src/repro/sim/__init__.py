"""Event-driven wall-clock federation simulator.

Converts the static per-round ledger a ``FedSession`` records
(``repro.telemetry`` step costs + strategy wire bytes) into simulated
seconds on heterogeneous device fleets, under sync, deadline-dropping, and
FedBuff-style buffered-async server schedules.

  * :mod:`repro.sim.fleet`  — device profiles, presets, seeded fleet sampling
  * :mod:`repro.sim.clock`  — roofline time model (ledger -> seconds)
  * :mod:`repro.sim.events` — the event-queue simulator over a round history
"""

from repro.sim.clock import (ClientTiming, client_timing, comm_time_s,
                             device_roofline_s, ledger_lists, resolve_fleet,
                             round_timings, step_time_s, sync_round_s)
from repro.sim.events import (RoundSim, SimReport, ledger_lines, simulate,
                              simulate_async, simulate_deadline,
                              simulate_sync)
from repro.sim.fleet import (FLEET_MIXES, FLEETS, PRESETS, DeviceProfile,
                             Fleet, gbps, make_fleet, mbps, sample_fleet)

__all__ = [
    "FLEETS", "FLEET_MIXES", "PRESETS", "ClientTiming", "DeviceProfile",
    "Fleet", "RoundSim", "SimReport", "client_timing", "comm_time_s",
    "device_roofline_s", "gbps", "ledger_lines", "ledger_lists",
    "make_fleet", "mbps",
    "resolve_fleet", "round_timings", "sample_fleet", "simulate",
    "simulate_async", "simulate_deadline", "simulate_sync", "step_time_s",
    "sync_round_s",
]
