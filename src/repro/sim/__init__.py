"""Event-driven wall-clock federation simulator.

Converts the static per-round ledger a ``FedSession`` records
(``repro.telemetry`` step costs + strategy wire bytes) into simulated
seconds on heterogeneous device fleets, under sync, deadline-dropping, and
FedBuff-style buffered-async server schedules.

  * :mod:`repro.sim.fleet`     — device profiles, presets, seeded sampling
  * :mod:`repro.sim.clock`     — roofline time model (ledger -> seconds),
    sequential and overlap (pipelined) clock modes
  * :mod:`repro.sim.events`    — the event-queue simulator over a round
    history (per-epoch skew-aware async replay)
  * :mod:`repro.sim.calibrate` — fit per-device MFU / effective-bandwidth
    factors to measured datapoints; calibrated preset registry anchored to
    the paper's 2x RTX 2080 Ti measurement
"""

from repro.sim.calibrate import (CALIBRATED_PRESETS, PAPER_2080TI_ANCHOR,
                                 PAPER_2080TI_EPOCH, PAPER_2080TI_ROUND,
                                 CalibrationPoint, EfficiencyFit, apply_fit,
                                 calibrate_presets, fit_device,
                                 predict_round_s, scale_device)
from repro.sim.clock import (ClientTiming, client_timing, comm_time_s,
                             device_roofline_s, ledger_lists, phase_total_s,
                             record_field, resolve_fleet, round_timings,
                             step_time_s, sync_round_s)
from repro.sim.events import (RoundSim, SimReport, emit_spans, ledger_lines,
                              simulate, simulate_async, simulate_deadline,
                              simulate_sync)
from repro.sim.fleet import (FLEET_MIXES, FLEETS, PRESETS, DeviceProfile,
                             Fleet, gbps, make_fleet, mbps, sample_fleet)

__all__ = [
    "CALIBRATED_PRESETS", "FLEETS", "FLEET_MIXES", "PAPER_2080TI_ANCHOR",
    "PAPER_2080TI_EPOCH", "PAPER_2080TI_ROUND", "PRESETS",
    "CalibrationPoint", "ClientTiming", "DeviceProfile", "EfficiencyFit",
    "Fleet", "RoundSim", "SimReport", "apply_fit", "calibrate_presets",
    "client_timing", "comm_time_s", "device_roofline_s", "emit_spans",
    "fit_device",
    "gbps", "ledger_lines", "ledger_lists", "make_fleet", "mbps",
    "phase_total_s", "predict_round_s", "record_field", "resolve_fleet",
    "round_timings", "sample_fleet",
    "scale_device", "simulate", "simulate_async", "simulate_deadline",
    "simulate_sync", "step_time_s", "sync_round_s",
]
