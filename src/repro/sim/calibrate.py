"""Calibrate the roofline clock against measured wall-clock datapoints.

The fleet presets (``repro.sim.fleet``) carry DATASHEET numbers — peak
FLOP/s and link bits/s — but no hardware sustains its datasheet peak: real
training runs at some model-FLOPs-utilization (MFU) fraction of the compute
ceiling, and a WAN link delivers some fraction of its nominal bandwidth.
This module fits those two per-device efficiency factors from one or more
measured datapoints and re-exports the presets with the factors applied, so
the simulator's absolute seconds can be quoted next to measured time (the
paper's 2x RTX 2080 Ti / 1 Gbps setup is the committed anchor).

The fitted model, for a device with datasheet profile ``dev``:

    step_s  = max(flops / (mfu x peak_flops), hbm / (mfu x hbm_bw))   [s]
    round_s = latency + down_bytes / (bw_eff x down_bw)
            + steps x step_s
            + latency + up_bytes / (bw_eff x up_bw)                   [s]

``mfu`` in (0, 1] scales BOTH roofline ceilings (the sustained fraction of
the datasheet compute and memory peaks — kernel efficiency, input pipeline,
and multi-GPU scaling all fold into it; the fit hard-caps it at 1.0, since
no device sustains more than its datasheet peak); ``bw_eff`` in (0, 1.5]
scales the WAN link (protocol overhead, shared campus links — it may
legitimately exceed 1 on an under-specced rating).  The fit is least
squares on RELATIVE residuals over all points, solved by a deterministic
zooming grid search in log-space (no scipy dependency) with a vanishing
ridge toward (1, 1) that only matters when a single datapoint leaves the
system underdetermined.

Workflow (the 2080 Ti anchor, end to end)::

    from repro.sim.calibrate import (PAPER_2080TI_ANCHOR, apply_fit,
                                     fit_device, predict_round_s)
    from repro.sim.fleet import PRESETS

    fit = fit_device(PAPER_2080TI_ANCHOR)           # mfu ~0.30, bw_eff ~0.70
    dev = apply_fit(PRESETS["rtx2080ti"], fit)      # calibrated profile
    predict_round_s(PAPER_2080TI_ROUND, dev)        # ~135.1 s (within 1%)

or just build calibrated fleets directly:
``make_fleet("paper-2080ti", n, calibrated=True)``.

>>> fit = fit_device(PAPER_2080TI_ANCHOR)
>>> 0.25 < fit.mfu < 0.35 and 0.6 < fit.bw_eff < 0.8
True
>>> fit.max_rel_err < 0.01
True
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.sim.clock import client_timing
from repro.sim.fleet import PRESETS, DeviceProfile


@dataclasses.dataclass(frozen=True)
class CalibrationPoint:
    """ONE measured wall-clock datapoint: a round of ``steps`` local steps
    on the ``fleet`` device preset took ``measured_round_s`` seconds.

    ``config`` is provenance (arch + batch shape the measurement ran);
    ``step_flops`` (FLOPs) and ``step_hbm_bytes`` (bytes) are the per-step
    ledger of that workload (``repro.telemetry.client_step_cost``);
    ``upload_bytes``/``download_bytes`` are the wire bytes moved each way
    (0 for a compute-only measurement — the per-transfer latency handshake
    is still modeled)."""

    config: str
    fleet: str                    # device preset name the measurement ran on
    steps: int                    # local optimizer steps in the round
    measured_round_s: float       # measured seconds for the whole round
    step_flops: float = 0.0       # per-step dot/conv FLOPs
    step_hbm_bytes: float = 0.0   # per-step HBM traffic, bytes
    upload_bytes: float = 0.0     # client->server bytes in the round
    download_bytes: float = 0.0   # server->client bytes in the round


@dataclasses.dataclass(frozen=True)
class EfficiencyFit:
    """Fitted per-device efficiency factors (both dimensionless).

    ``max_rel_err`` is the largest |predicted - measured| / measured over
    the fitted points — the fit's own residual, NOT a generalization
    claim."""

    mfu: float                    # sustained fraction of datasheet ceilings
    bw_eff: float                 # effective fraction of datasheet link bw
    max_rel_err: float
    n_points: int
    source: str = ""              # which measurements produced the fit


# ---------------------------------------------------------------------------
# The committed anchor: the paper's hardware (DistilBERT, 2x RTX 2080 Ti,
# 1 Gbps).  Ledger terms are this repo's own telemetry of the full
# distilbert-mlm config at batch 32 x seq 128 (repro.telemetry
# .client_step_cost — dot FLOPs 2.0208e12 / step, HBM 4.6418e10 B / step,
# dense fp32 upload 278_811_648 B); the measured seconds encode the
# paper-setup round at ~30% MFU and ~70% of the nominal 1 Gbps —
# order-of-magnitude-faithful stand-ins for the paper's unpublished raw
# timings, committed so calibration is reproducible.  The 2-GPU node is
# modeled as ONE client device; data-parallel scaling folds into the MFU.
# ---------------------------------------------------------------------------

PAPER_2080TI_EPOCH = CalibrationPoint(
    config="distilbert-mlm b32 s128 (local epoch, no sync)",
    fleet="rtx2080ti", steps=512, measured_round_s=128.7,
    step_flops=2020803084288.0, step_hbm_bytes=46417557152.0)

PAPER_2080TI_ROUND = CalibrationPoint(
    config="distilbert-mlm b32 s128 (full round incl. 1 Gbps sync)",
    fleet="rtx2080ti", steps=512, measured_round_s=135.1,
    step_flops=2020803084288.0, step_hbm_bytes=46417557152.0,
    upload_bytes=278811648.0, download_bytes=278811648.0)

PAPER_2080TI_ANCHOR: Tuple[CalibrationPoint, ...] = (PAPER_2080TI_EPOCH,
                                                     PAPER_2080TI_ROUND)


def scale_device(dev: DeviceProfile, mfu: float,
                 bw_eff: float) -> DeviceProfile:
    """Apply efficiency factors to a datasheet profile: compute and HBM
    ceilings x ``mfu``, both link directions x ``bw_eff`` (latency and
    dropout are not efficiency-scaled)."""
    return dataclasses.replace(
        dev, peak_flops=dev.peak_flops * mfu, hbm_bw=dev.hbm_bw * mfu,
        up_bw=dev.up_bw * bw_eff, down_bw=dev.down_bw * bw_eff)


def predict_round_s(point: CalibrationPoint, dev: DeviceProfile, *,
                    overlap: bool = False) -> float:
    """Seconds the roofline clock predicts for the point's workload on
    ``dev`` (pass a calibrated profile to check a fit; ``overlap`` selects
    the pipelined clock)."""
    t = client_timing(0, dev, n_steps=point.steps,
                      step_flops=point.step_flops,
                      step_hbm_bytes=point.step_hbm_bytes,
                      upload_bytes=point.upload_bytes,
                      download_bytes=point.download_bytes)
    return t.total(overlap)


def _objective(points: Sequence[CalibrationPoint], dev: DeviceProfile,
               log_mfu: np.ndarray, log_bw: np.ndarray) -> np.ndarray:
    """Mean squared RELATIVE residual over points, on a (log_mfu x log_bw)
    grid, plus a vanishing ridge toward (1, 1) that breaks ties when one
    datapoint cannot identify both factors."""
    mfu = np.exp(log_mfu)[:, None]          # (M, 1)
    bw = np.exp(log_bw)[None, :]            # (1, B)
    err = np.zeros((mfu.shape[0], bw.shape[1]))
    for p in points:
        step_s = np.maximum(p.step_flops / (dev.peak_flops * mfu),
                            p.step_hbm_bytes / (dev.hbm_bw * mfu))
        pred = (2.0 * dev.latency_s + p.steps * step_s
                + p.download_bytes / (dev.down_bw * bw)
                + p.upload_bytes / (dev.up_bw * bw))
        err += ((pred - p.measured_round_s) / p.measured_round_s) ** 2
    err /= len(points)
    return err + 1e-8 * (log_mfu[:, None] ** 2 + log_bw[None, :] ** 2)


def fit_device(points: Sequence[CalibrationPoint],
               dev: Optional[DeviceProfile] = None, *,
               bounds: Tuple[float, float] = (0.02, 1.5),
               grid: int = 41, zooms: int = 4) -> EfficiencyFit:
    """Least-squares fit of (mfu, bw_eff) for one device preset.

    ``points`` must all name the same preset (``dev`` defaults to
    ``PRESETS[points[0].fleet]``).  Deterministic zooming grid search:
    ``zooms`` passes of a ``grid x grid`` log-space lattice over
    ``bounds``, each pass shrinking the window around the incumbent — no
    random restarts, no scipy, resolution ~1e-4 relative.  The mfu axis is
    additionally capped at 1.0 regardless of ``bounds`` (no device
    sustains more than its datasheet peak — a fit pressing against the cap
    means the measured seconds or the ledger terms are wrong); ``bw_eff``
    may exceed 1 up to ``bounds[1]`` (a link can beat its nominal
    rating)."""
    if not points:
        raise ValueError("need at least one CalibrationPoint")
    names = {p.fleet for p in points}
    if len(names) > 1:
        raise ValueError(f"points span several presets {sorted(names)}; "
                         f"fit each preset separately")
    if dev is None:
        name = next(iter(names))
        if name not in PRESETS:
            raise ValueError(f"unknown preset {name!r}; pass dev= explicitly")
        dev = PRESETS[name]

    lo, hi = np.log(bounds[0]), np.log(bounds[1])
    hi_mfu = min(hi, 0.0)                   # log(1.0): physical MFU ceiling
    c_mfu = 0.5 * (lo + hi_mfu)
    c_bw = 0.5 * (lo + hi)
    half = 0.5 * (hi - lo)
    for _ in range(zooms):
        gm = np.linspace(c_mfu - half, c_mfu + half, grid)
        gb = np.linspace(c_bw - half, c_bw + half, grid)
        gm, gb = np.clip(gm, lo, hi_mfu), np.clip(gb, lo, hi)
        err = _objective(points, dev, gm, gb)
        i, j = np.unravel_index(int(np.argmin(err)), err.shape)
        c_mfu, c_bw = float(gm[i]), float(gb[j])
        half *= 2.5 / (grid - 1)            # next window: a few old cells
    mfu, bw_eff = float(np.exp(c_mfu)), float(np.exp(c_bw))

    fitted = scale_device(dev, mfu, bw_eff)
    rel = [abs(predict_round_s(p, fitted) - p.measured_round_s)
           / p.measured_round_s for p in points]
    return EfficiencyFit(mfu=mfu, bw_eff=bw_eff,
                         max_rel_err=float(max(rel)), n_points=len(points),
                         source="+".join(sorted({p.config for p in points})))


def apply_fit(dev: DeviceProfile, fit: EfficiencyFit, *,
              source: str = "") -> DeviceProfile:
    """Calibrated profile: ``dev`` with the fit's factors applied and
    ``calibrated_from`` recording the measurement provenance."""
    return dataclasses.replace(
        scale_device(dev, fit.mfu, fit.bw_eff),
        calibrated_from=source or fit.source)


def calibrate_presets(points: Optional[Sequence[CalibrationPoint]] = None, *,
                      presets: Optional[Dict[str, DeviceProfile]] = None
                      ) -> Dict[str, DeviceProfile]:
    """The calibrated preset registry: every preset with measured points
    gets its own fit; every other preset inherits the MEAN fitted factors
    as a transfer prior (marked ``calibrated_from="transfer:..."`` — the
    best available estimate until that device is measured).

    ``repro.sim.fleet.make_fleet(..., calibrated=True)`` samples from this
    registry's default instance (``CALIBRATED_PRESETS``)."""
    if points is None:
        points = PAPER_2080TI_ANCHOR
    presets = dict(PRESETS if presets is None else presets)
    by_preset: Dict[str, list] = {}
    for p in points:
        by_preset.setdefault(p.fleet, []).append(p)
    fits = {name: fit_device(ps, presets.get(name))
            for name, ps in by_preset.items()}
    if not fits:
        raise ValueError("no calibration points")
    mean_fit = EfficiencyFit(
        mfu=float(np.exp(np.mean([np.log(f.mfu) for f in fits.values()]))),
        bw_eff=float(np.exp(np.mean([np.log(f.bw_eff)
                                     for f in fits.values()]))),
        max_rel_err=max(f.max_rel_err for f in fits.values()),
        n_points=sum(f.n_points for f in fits.values()),
        source="transfer:" + "+".join(sorted(fits)))
    out = {}
    for name, dev in presets.items():
        fit = fits.get(name, mean_fit)
        out[name] = apply_fit(dev, fit)
    return out


# Default registry: the paper anchor's factors, fitted once at import (the
# fit is a few thousand numpy grid evaluations — microseconds).
CALIBRATED_PRESETS: Dict[str, DeviceProfile] = calibrate_presets()
