"""Event-driven wall-clock simulator over a ``FedSession`` round history.

Replays the per-client ledger each ``RoundResult`` records (steps, per-step
FLOPs/HBM bytes, wire bytes) on a heterogeneous ``Fleet`` under three server
schedules:

  * ``simulate_sync``     — FedAvg as the paper runs it: the round closes
    when the slowest sampled client uploads.  Device dropout is a seeded
    mid-round failure + restart, so one flaky phone stalls everyone.
  * ``simulate_deadline`` — over-select ``over_select x n`` clients, close
    the round at ``deadline_s``, DROP stragglers — but never below a quorum
    of ``ceil(quorum_frac x n)`` (the round extends to the quorum-th upload
    when too few beat the deadline).
  * ``simulate_async``    — FedBuff-style buffered async: clients train
    continuously against the version they last downloaded; the server
    aggregates whenever ``buffer_size`` updates are buffered and bumps its
    version.  Staleness tau = server_version_at_upload - version_at_download
    is recorded per update — feed the observed taus to
    ``AsyncFedAvg(staleness=...)`` to run the learning math the schedule
    implies (the simulator and the strategy share one discount rule).
    Each client replays its OWN recorded per-epoch workload (cycled round by
    round), not a fleet mean — under quantity skew (Dirichlet / Eq. 8
    partitions) big-data clients take proportionally longer per epoch, so
    staleness tau correlates with client data volume exactly as it would on
    a real fleet.  ``client_steps`` overrides the per-epoch step counts
    directly (thread ``repro.core.noniid.make_client_datasets()["steps"]``
    through it when the recorded ledger is rectangular, e.g. the parallel
    engine's).

Every schedule accepts ``overlap=True`` to time clients with the pipelined
clock (``repro.sim.clock.ClientTiming.total_overlap_s`` — download/compute
and compute/upload overlap; only latencies stay serial) instead of the
sequential phase sum.  All times are seconds.

Everything is deterministic in ``seed``: failures, over-selection draws, and
the event heap's tie-break (time, then client id) are all
``np.random.default_rng``-driven, so a simulated ledger is a reproducible
artifact of (history, fleet, mode, clock, seed).

``history`` records are duck-typed (``repro.sim.clock.record_field``): live
``RoundResult`` objects and their serialized dicts both replay, so the
``history`` a round checkpoint's ``FederatedState`` sidecar carries
(``repro.checkpoint``) feeds straight in — post-hoc replays, including the
skew-aware async staleness study, survive process restarts.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.clock import (ClientTiming, phase_total_s, record_field,
                             round_timings)
from repro.sim.fleet import Fleet


@dataclasses.dataclass(frozen=True)
class RoundSim:
    """One simulated server aggregation (a round in sync/deadline modes,
    one buffer flush in async mode).  ``t_start``/``t_end`` are seconds of
    simulated wall-clock since the session started; ``staleness`` entries
    are server-version deltas (dimensionless counts)."""

    round: int
    t_start: float
    t_end: float
    clients: Tuple[int, ...]              # whose updates were aggregated
    dropped: Tuple[int, ...] = ()         # selected but not aggregated
    staleness: Tuple[int, ...] = ()       # per aggregated update (async)
    timings: Tuple[ClientTiming, ...] = ()

    @property
    def round_s(self) -> float:
        """Seconds this aggregation took (t_end - t_start)."""
        return self.t_end - self.t_start


@dataclasses.dataclass(frozen=True)
class SimReport:
    """A full simulated session: ``mode`` is the server schedule
    (sync | deadline | async), ``overlap`` the clock mode, and every time
    property is seconds of simulated wall-clock."""

    mode: str
    fleet: str
    rounds: Tuple[RoundSim, ...]
    seed: int = 0
    overlap: bool = False

    @property
    def total_s(self) -> float:
        """Seconds from session start to the last aggregation."""
        return self.rounds[-1].t_end if self.rounds else 0.0

    @property
    def mean_round_s(self) -> float:
        """Mean seconds per aggregation."""
        return (float(np.mean([r.round_s for r in self.rounds]))
                if self.rounds else 0.0)

    @property
    def dropped_total(self) -> int:
        """Selected-but-not-aggregated client count over the session."""
        return sum(len(r.dropped) for r in self.rounds)

    def staleness_histogram(self) -> Dict[int, int]:
        """tau -> number of aggregated updates that arrived tau server
        versions stale (async mode; empty for sync/deadline)."""
        out: Dict[int, int] = {}
        for r in self.rounds:
            for tau in r.staleness:
                out[tau] = out.get(tau, 0) + 1
        return out


def _failed_compute_s(compute_s: float, dev_dropout: float,
                      rng: np.random.Generator) -> float:
    """Compute seconds including availability noise: with probability
    ``dropout`` the client dies at a uniform point of its local epoch and
    restarts from scratch (no local checkpointing), once per round."""
    extra = 0.0
    if dev_dropout > 0.0 and rng.random() < dev_dropout:
        extra = rng.random() * compute_s
    return compute_s + extra


def _phase_total(timing: ClientTiming, compute_s: float,
                 overlap: bool) -> float:
    """Assemble round seconds from phase terms under the chosen clock mode
    (``compute_s`` may carry availability noise on top of the timing's).
    Delegates to ``repro.sim.clock.phase_total_s`` — one clock rule for the
    live hook and the replays."""
    return phase_total_s(timing.down_s, compute_s, timing.up_s,
                         timing.latency_s, overlap)


def _noisy_total(timing: ClientTiming, dropout: float,
                 rng: np.random.Generator, overlap: bool = False) -> float:
    return _phase_total(timing,
                        _failed_compute_s(timing.compute_s, dropout, rng),
                        overlap)


# ---------------------------------------------------------------------------
# Sync FedAvg: wait for the slowest client
# ---------------------------------------------------------------------------

def simulate_sync(history: Sequence[Any], fleet: Fleet, *, seed: int = 0,
                  overlap: bool = False) -> SimReport:
    """Replay ``history`` as paper-style sync FedAvg: every round closes at
    the slowest sampled client's upload (seconds; seeded dropout-restart
    noise on the compute phase; ``overlap`` picks the clock mode)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    rounds: List[RoundSim] = []
    for rr in history:
        ts = round_timings(rr, fleet)
        totals = [_noisy_total(x, fleet[x.client].dropout, rng, overlap)
                  for x in ts]
        end = t + (max(totals) if totals else 0.0)
        rounds.append(RoundSim(record_field(rr, "round", 0), t, end,
                               tuple(x.client for x in ts),
                               timings=tuple(ts)))
        t = end
    return SimReport("sync", fleet.name, tuple(rounds), seed, overlap)


# ---------------------------------------------------------------------------
# Deadline + over-selection: drop stragglers, keep quorum
# ---------------------------------------------------------------------------

def _mean_work(rr: Any) -> Tuple[int, float, float, float, float]:
    """The round's average local workload — (steps, FLOPs/step, HBM
    bytes/step, upload bytes, download bytes) — assigned to over-selected
    extras (their data size is unknown to the replay — the server would
    hand them an average shard).  Defaults resolve through
    ``clock.ledger_lists`` so extras and sampled clients share one rule
    set."""
    from repro.sim.clock import ledger_lists
    _, steps, flops, hbm, up, down = ledger_lists(rr)
    return (int(round(np.mean(steps))), float(np.mean(flops)),
            float(np.mean(hbm)), float(np.mean(up)), float(down))


def simulate_deadline(history: Sequence[Any], fleet: Fleet, *,
                      deadline_s: float, over_select: float = 1.5,
                      quorum_frac: float = 0.8, seed: int = 0,
                      overlap: bool = False) -> SimReport:
    """Sync FedAvg with a round deadline (``deadline_s`` seconds): the
    server selects ``ceil(over_select x n)`` clients, aggregates whoever
    uploaded by ``deadline_s``, and drops the rest — but never below
    ``quorum = ceil(quorum_frac x n)``; when fewer beat the deadline the
    round runs long until the quorum-th upload (availability must not
    silently shrink the effective cohort).  ``overlap`` picks the clock
    mode for every client's phase seconds."""
    from repro.sim.clock import client_timing
    if not 0.0 < quorum_frac <= 1.0:
        raise ValueError(f"quorum_frac {quorum_frac} not in (0, 1]")
    rng = np.random.default_rng(seed)
    t = 0.0
    rounds: List[RoundSim] = []
    for rr in history:
        ts = list(round_timings(rr, fleet))
        n = len(ts)
        if n == 0:
            rounds.append(RoundSim(record_field(rr, "round", 0), t, t, ()))
            continue
        # over-select extra clients from the rest of the fleet, seeded
        m = min(len(fleet), max(n, math.ceil(over_select * n)))
        have = {x.client for x in ts}
        pool = [k for k in range(len(fleet)) if k not in have]
        extra = (sorted(rng.choice(pool, size=m - n, replace=False).tolist())
                 if m > n and pool else [])
        steps, flops, hbm, up, down = _mean_work(rr)
        for k in extra:
            ts.append(client_timing(k, fleet[k], n_steps=steps,
                                    step_flops=flops, step_hbm_bytes=hbm,
                                    upload_bytes=up, download_bytes=down))
        finish = sorted((_noisy_total(x, fleet[x.client].dropout, rng,
                                      overlap), x.client) for x in ts)
        quorum = max(1, math.ceil(quorum_frac * n))
        made_it = [(f, k) for f, k in finish if f <= deadline_s]
        if len(made_it) == len(finish):
            kept = made_it                  # nobody to wait for: close early
            round_s = finish[-1][0]
        elif len(made_it) >= quorum:
            kept = made_it
            round_s = deadline_s
        else:
            kept = finish[:quorum]          # run long to the quorum-th upload
            round_s = kept[-1][0]
        kept_ids = {k for _, k in kept}
        rounds.append(RoundSim(
            record_field(rr, "round", 0), t, t + round_s,
            tuple(sorted(kept_ids)),
            dropped=tuple(sorted(x.client for x in ts
                                 if x.client not in kept_ids)),
            timings=tuple(ts)))
        t += round_s
    return SimReport("deadline", fleet.name, tuple(rounds), seed, overlap)


# ---------------------------------------------------------------------------
# Buffered async (FedBuff): aggregate every buffer_size uploads
# ---------------------------------------------------------------------------

def simulate_async(history: Sequence[Any], fleet: Fleet, *,
                   buffer_size: int = 2, seed: int = 0,
                   overlap: bool = False,
                   client_steps: Optional[Any] = None) -> SimReport:
    """FedBuff schedule: every client loops download -> local epoch ->
    upload, immediately restarting on the server's CURRENT version; the
    server flushes its buffer every ``buffer_size`` uploads.  Runs until as
    many aggregations happened as the history had rounds, so sync and async
    ledgers describe the same number of model updates.

    Each client's i-th epoch replays its i-th RECORDED round (cycled), so
    per-client quantity skew survives into the schedule: a client holding
    2x the documents runs ~2x the local steps per epoch, uploads half as
    often, and its updates land with larger staleness tau — the correlation
    the non-IID study needs (a fleet-mean replay would flatten it).
    ``client_steps`` (sequence indexed by client id, or {client: steps}
    dict) overrides the recorded per-epoch step counts — each epoch's
    compute seconds are rescaled to ``steps_k x`` that epoch's per-step
    seconds.  Use it to thread partition sizes
    (``repro.core.noniid.make_client_datasets()["steps"]``) through a
    rectangular ledger (the parallel engine pads every client to
    ``max_steps``).

    Staleness per update is recorded; its histogram is the fleet's (and the
    partition's) heterogeneity made visible — feed the taus to
    ``AsyncFedAvg(staleness=...)`` for the matching aggregation math."""
    if buffer_size < 1:
        raise ValueError(f"buffer_size {buffer_size} < 1")
    rng = np.random.default_rng(seed)
    # per-client recorded epochs, in round order (cycled during replay)
    per_client: Dict[int, List[ClientTiming]] = {}
    for rr in history:
        for x in round_timings(rr, fleet):
            per_client.setdefault(x.client, []).append(x)
    if not per_client:
        return SimReport("async", fleet.name, (), seed, overlap)

    def steps_for(k: int) -> Optional[int]:
        if client_steps is None:
            return None
        if isinstance(client_steps, dict):
            return client_steps.get(k)
        return client_steps[k] if 0 <= k < len(client_steps) else None

    epoch_i: Dict[int, int] = {k: 0 for k in per_client}

    def next_finish(k: int, now: float) -> float:
        xs = per_client[k]
        x = xs[epoch_i[k] % len(xs)]
        epoch_i[k] += 1
        compute = x.compute_s
        override = steps_for(k)
        if override is not None and x.n_steps > 0:
            compute = override * (x.compute_s / x.n_steps)
        # availability noise: seeded failure mid-epoch + restart
        compute = _failed_compute_s(compute, fleet[k].dropout, rng)
        return now + _phase_total(x, compute, overlap)

    n_agg_target = len(history)
    heap: List[Tuple[float, int]] = []      # (finish time, client)
    version_at_start: Dict[int, int] = {}
    server_version = 0
    for k in sorted(per_client):
        version_at_start[k] = 0
        heapq.heappush(heap, (next_finish(k, 0.0), k))

    buffer: List[Tuple[int, int]] = []      # (client, staleness)
    rounds: List[RoundSim] = []
    t_prev = 0.0
    while heap and len(rounds) < n_agg_target:
        t, k = heapq.heappop(heap)
        buffer.append((k, server_version - version_at_start[k]))
        if len(buffer) >= buffer_size:
            server_version += 1
            rounds.append(RoundSim(
                len(rounds), t_prev, t,
                tuple(c for c, _ in buffer),
                staleness=tuple(tau for _, tau in buffer)))
            t_prev = t
            buffer = []
        version_at_start[k] = server_version
        heapq.heappush(heap, (next_finish(k, t), k))
    return SimReport("async", fleet.name, tuple(rounds), seed, overlap)


# ---------------------------------------------------------------------------
# Driver surface
# ---------------------------------------------------------------------------

def simulate(history: Sequence[Any], fleet: Fleet, *, mode: str = "sync",
             seed: int = 0, deadline_s: float = 0.0,
             over_select: float = 1.5, quorum_frac: float = 0.8,
             buffer_size: int = 2, overlap: bool = False,
             client_steps: Optional[Any] = None) -> SimReport:
    """One entry point over the three schedules (see the module docstring).
    ``overlap`` selects the pipelined clock for any mode; ``client_steps``
    is the async schedule's per-client step override (ignored elsewhere —
    sync/deadline replay the ledger's own per-client counts)."""
    if mode == "sync":
        return simulate_sync(history, fleet, seed=seed, overlap=overlap)
    if mode == "deadline":
        return simulate_deadline(history, fleet, deadline_s=deadline_s,
                                 over_select=over_select,
                                 quorum_frac=quorum_frac, seed=seed,
                                 overlap=overlap)
    if mode == "async":
        return simulate_async(history, fleet, buffer_size=buffer_size,
                              seed=seed, overlap=overlap,
                              client_steps=client_steps)
    raise ValueError(f"unknown mode {mode!r} (sync | deadline | async)")


def emit_spans(report: SimReport, tracer: Any = None) -> int:
    """Replay a simulated session onto the span tracer as synthetic spans
    so simulated and measured rounds render side-by-side in one Perfetto
    timeline (the sim lands in its own process lane, ``PID_SIM``).

    Track layout: tid 0 is the server — one ``sim.round`` span per
    aggregation over ``[t_start, t_end]``.  tid ``client+1`` is that
    client's track: one ``sim.client`` span whose duration is EXACTLY
    ``timing.total(report.overlap)`` (the number the drift monitor and
    parity tests join against), containing ``sim.down``/``sim.compute``/
    ``sim.up`` phase spans.  Phases are laid out sequentially from
    ``t_start``; under the overlap clock the durations stay truthful while
    the layout is nominal (the real phases pipeline).  Async reports carry
    no per-client timings — only server spans are emitted.

    Returns the number of spans emitted (0 when the tracer is disabled —
    synthetic spans respect the same opt-in as measured ones)."""
    from repro.obs.trace import PID_SIM, get_tracer
    tracer = tracer if tracer is not None else get_tracer()
    if not tracer.enabled:
        return 0
    n = 0
    for r in report.rounds:
        tracer.add_span("sim.round", ts_s=r.t_start, dur_s=r.round_s,
                        cat="sim", pid=PID_SIM, tid=0, round=r.round,
                        mode=report.mode, clients=len(r.clients),
                        dropped=len(r.dropped))
        n += 1
        for tm in r.timings:
            tid = int(tm.client) + 1
            tracer.add_span("sim.client", ts_s=r.t_start,
                            dur_s=tm.total(report.overlap), cat="sim",
                            pid=PID_SIM, tid=tid, round=r.round,
                            client=tm.client, device=tm.device,
                            n_steps=tm.n_steps)
            t = r.t_start
            for phase, dur in (("down", tm.down_s),
                               ("compute", tm.compute_s),
                               ("up", tm.up_s)):
                tracer.add_span(f"sim.{phase}", ts_s=t, dur_s=dur,
                                cat="sim", pid=PID_SIM, tid=tid,
                                round=r.round, client=tm.client)
                t += dur
            n += 4
    return n


def ledger_lines(report: SimReport) -> List[str]:
    """Human-readable per-aggregation ledger (the train driver prints it)."""
    clock = " clock=overlap" if report.overlap else ""
    out = [f"simulated wall-clock [{report.mode}] fleet={report.fleet}{clock} "
           f"total={report.total_s:.1f}s mean_round={report.mean_round_s:.1f}s"
           f" dropped={report.dropped_total}"]
    for r in report.rounds:
        extra = ""
        if r.dropped:
            extra += f" dropped={list(r.dropped)}"
        if r.staleness:
            extra += f" staleness={list(r.staleness)}"
        out.append(f"  agg {r.round:3d}  t={r.t_end:9.1f}s  "
                   f"round={r.round_s:8.2f}s  clients={list(r.clients)}{extra}")
    return out
