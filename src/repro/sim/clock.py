"""Roofline time model: per-round ledger -> per-client seconds on a fleet.

Maps the static compute/comm ledger a ``FedSession`` round records (per-step
dot FLOPs and HBM bytes from ``repro.telemetry``, wire bytes from the
strategy) onto a ``DeviceProfile``:

    step_s    = max(flops / peak_flops, hbm_bytes / hbm_bw)   (roofline)
    compute_s = n_steps x step_s
    down_s    = latency + download_bytes / down_bw
    up_s      = latency + upload_bytes / up_bw

The model is intentionally first-order: no overlap of compute with
communication, no batching of the two transfer directions.  That is the
conservative sync-FL schedule (download, train, upload) every deployment
starts from; the event simulator (``repro.sim.events``) layers dropouts,
deadlines, and async aggregation on top of these per-client terms.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

from repro.sim.fleet import DeviceProfile, Fleet


@dataclasses.dataclass(frozen=True)
class ClientTiming:
    """One client's simulated round, split into the sync-FL phases."""

    client: int
    device: str
    down_s: float
    compute_s: float
    up_s: float

    @property
    def total_s(self) -> float:
        return self.down_s + self.compute_s + self.up_s


def step_time_s(step_flops: float, step_hbm_bytes: float,
                dev: DeviceProfile) -> float:
    """Roofline time of ONE local step: bounded by compute or HBM traffic,
    whichever is slower on this device."""
    return max(step_flops / dev.peak_flops, step_hbm_bytes / dev.hbm_bw)


def comm_time_s(nbytes: float, bw: float, latency_s: float) -> float:
    return latency_s + nbytes / max(bw, 1.0)


def client_timing(k: int, dev: DeviceProfile, *, n_steps: int,
                  step_flops: float, step_hbm_bytes: float,
                  upload_bytes: float, download_bytes: float) -> ClientTiming:
    return ClientTiming(
        client=k, device=dev.name,
        down_s=comm_time_s(download_bytes, dev.down_bw, dev.latency_s),
        compute_s=n_steps * step_time_s(step_flops, step_hbm_bytes, dev),
        up_s=comm_time_s(upload_bytes, dev.up_bw, dev.latency_s))


def ledger_lists(rr: Any):
    """Resolve a round's per-client replay ledger with its defaults:
    ``(clients, steps, step_flops, step_hbm, upload_bytes, down_each)``.

    ``rr`` is duck-typed on the ``RoundResult`` replay fields
    (``clients``, ``client_steps``, ``client_step_flops``,
    ``client_step_hbm``, ``client_upload_bytes``, ``download_bytes``);
    missing per-client lists fall back to even splits of the round totals.
    The single source of the default rules — the event simulator's
    mean-workload extras average THIS function's output."""
    clients = list(rr.clients) if rr.clients is not None else []
    n = len(clients)
    if n == 0:
        return [], [], [], [], [], 0
    steps = list(rr.client_steps) if rr.client_steps else [1] * n
    flops = (list(rr.client_step_flops) if rr.client_step_flops
             else [0.0] * n)
    hbm = list(rr.client_step_hbm) if rr.client_step_hbm else [0.0] * n
    up = (list(rr.client_upload_bytes) if rr.client_upload_bytes
          else [rr.upload_bytes // n] * n)
    down_each = rr.download_bytes // n if rr.download_bytes else 0
    return clients, steps, flops, hbm, up, down_each


def round_timings(rr: Any, fleet: Fleet) -> List[ClientTiming]:
    """Per-client timings for one recorded round (see ``ledger_lists`` for
    the accepted record shape).  Sessions run with ``telemetry=False``
    record zero compute terms — the simulation then degenerates to
    comm-only time; run with telemetry on for wall-clock numbers."""
    clients, steps, flops, hbm, up, down_each = ledger_lists(rr)
    return [client_timing(k, fleet[k], n_steps=steps[i],
                          step_flops=flops[i], step_hbm_bytes=hbm[i],
                          upload_bytes=up[i], download_bytes=down_each)
            for i, k in enumerate(clients)]


def sync_round_s(rr: Any, fleet: Fleet) -> float:
    """Ideal (dropout-free) synchronous round time: the server waits for the
    slowest sampled client.  This is what ``RoundPlan.simulate`` records
    live; ``repro.sim.events`` adds availability noise and other modes."""
    ts = round_timings(rr, fleet)
    return max((t.total_s for t in ts), default=0.0)


def resolve_fleet(spec: Any, n_clients: int, seed: int = 0) -> Fleet:
    """Accept a ``Fleet``, a named-fleet string, or a mixture dict."""
    from repro.sim.fleet import make_fleet, sample_fleet
    if isinstance(spec, Fleet):
        return spec
    if isinstance(spec, str):
        return make_fleet(spec, n_clients, seed=seed)
    if isinstance(spec, dict):
        return sample_fleet(spec, n_clients, seed=seed)
    raise TypeError(f"cannot resolve fleet from {spec!r}")


def device_roofline_s(flops: float, hbm_bytes: float, comm_bytes: float,
                      dev: DeviceProfile) -> dict:
    """Ledger totals -> the three roofline terms in seconds on one device
    (``benchmarks/roofline.py`` merges session rounds through this)."""
    return {"compute": flops / dev.peak_flops,
            "memory": hbm_bytes / dev.hbm_bw,
            "collective": comm_bytes / max(dev.up_bw, 1.0)}
