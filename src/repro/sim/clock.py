"""Roofline time model: per-round ledger -> per-client seconds on a fleet.

Maps the static compute/comm ledger a ``FedSession`` round records (per-step
dot FLOPs and HBM bytes from ``repro.telemetry``, wire bytes from the
strategy) onto a ``DeviceProfile``.  Inputs are FLOPs / bytes / bytes-per-
second; every output is SECONDS:

    step_s    = max(flops / peak_flops, hbm_bytes / hbm_bw)   (roofline)
    compute_s = n_steps x step_s
    down_s    = latency + download_bytes / down_bw
    up_s      = latency + upload_bytes / up_bw

Two clock modes turn the phase terms into a round:

  * sequential (default) — download, train, upload, one after the other:
    ``total_s = down_s + compute_s + up_s``.  The conservative sync-FL
    schedule every deployment starts from.
  * overlap — download/compute and compute/upload pipeline (the client
    streams the next parameters while stepping and streams its update out
    as layers finish): only the per-transfer latencies stay serial and the
    longest phase gates the round,
    ``total_overlap_s = 2 x latency + max(down_xfer, compute_s, up_xfer)``.
    Always <= the sequential total (pinned as a property test in
    tests/test_sim.py).

The event simulator (``repro.sim.events``) layers dropouts, deadlines, and
async aggregation on top of these per-client terms; both modes are
selectable there and from ``repro.launch.train --overlap``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence

import numpy as np

from repro.core.accounting import split_bytes
from repro.sim.fleet import DeviceProfile, Fleet


@dataclasses.dataclass(frozen=True)
class ClientTiming:
    """One client's simulated round, split into the sync-FL phases.

    All fields are seconds except ``client`` (id), ``device`` (preset name)
    and ``n_steps`` (local optimizer steps behind ``compute_s``).
    ``latency_s`` is the per-transfer handshake already INCLUDED in
    ``down_s``/``up_s`` — kept so the overlap clock can separate the serial
    handshake from the pipelinable transfer."""

    client: int
    device: str
    down_s: float                 # latency + download_bytes / down_bw
    compute_s: float              # n_steps x roofline step seconds
    up_s: float                   # latency + upload_bytes / up_bw
    n_steps: int = 0
    latency_s: float = 0.0

    @property
    def total_s(self) -> float:
        """Sequential round seconds: down, then compute, then up."""
        return phase_total_s(self.down_s, self.compute_s, self.up_s,
                             self.latency_s, False)

    @property
    def total_overlap_s(self) -> float:
        """Pipelined round seconds: latencies stay serial, the longest of
        {download transfer, compute, upload transfer} gates the round."""
        return phase_total_s(self.down_s, self.compute_s, self.up_s,
                             self.latency_s, True)

    def total(self, overlap: bool = False) -> float:
        """Round seconds under the chosen clock mode."""
        return self.total_overlap_s if overlap else self.total_s


def phase_total_s(down_s: float, compute_s: float, up_s: float,
                  latency_s: float, overlap: bool) -> float:
    """THE round-assembly rule, in one place: phase seconds -> round
    seconds.  Sequential is the plain sum; overlap keeps only the two
    per-transfer handshakes serial and lets the longest of {download
    transfer, compute, upload transfer} gate the round.  Both
    ``ClientTiming.total*`` and the event simulator's noisy totals
    (``repro.sim.events``) delegate here, so the clock model cannot
    desync between the live hook and the replays.

    >>> phase_total_s(2.0, 5.0, 3.0, 0.5, False)
    10.0
    >>> phase_total_s(2.0, 5.0, 3.0, 0.5, True)    # 2*0.5 + max(1.5, 5, 2.5)
    6.0
    """
    if overlap:
        return 2.0 * latency_s + max(down_s - latency_s, compute_s,
                                     up_s - latency_s)
    return down_s + compute_s + up_s


def step_time_s(step_flops: float, step_hbm_bytes: float,
                dev: DeviceProfile) -> float:
    """Roofline seconds of ONE local step: bounded by compute (FLOPs at
    ``dev.peak_flops`` FLOP/s) or HBM traffic (bytes at ``dev.hbm_bw``
    bytes/s), whichever is slower on this device."""
    return max(step_flops / dev.peak_flops, step_hbm_bytes / dev.hbm_bw)


def comm_time_s(nbytes: float, bw: float, latency_s: float) -> float:
    """Seconds to move ``nbytes`` bytes over a ``bw`` bytes/s link after a
    fixed ``latency_s`` seconds handshake.

    >>> comm_time_s(1_000_000, 1e6, 0.05)
    1.05
    """
    return latency_s + nbytes / max(bw, 1.0)


def client_timing(k: int, dev: DeviceProfile, *, n_steps: int,
                  step_flops: float, step_hbm_bytes: float,
                  upload_bytes: float, download_bytes: float) -> ClientTiming:
    """One client's phase seconds for a round of ``n_steps`` local steps of
    (``step_flops`` FLOPs, ``step_hbm_bytes`` bytes) each, moving
    ``download_bytes``/``upload_bytes`` bytes over the device's link."""
    return ClientTiming(
        client=k, device=dev.name,
        down_s=comm_time_s(download_bytes, dev.down_bw, dev.latency_s),
        compute_s=n_steps * step_time_s(step_flops, step_hbm_bytes, dev),
        up_s=comm_time_s(upload_bytes, dev.up_bw, dev.latency_s),
        n_steps=n_steps, latency_s=dev.latency_s)


def record_field(rr: Any, name: str, default: Any = None) -> Any:
    """Duck-typed round-record access: ``rr`` may be a ``RoundResult``
    (attributes) or its serialized dict (keys) — a checkpoint's JSON
    ``history`` (``repro.checkpoint.FederatedState``) feeds straight into
    the replays without reconstructing ``RoundResult`` objects."""
    if isinstance(rr, dict):
        return rr.get(name, default)
    return getattr(rr, name, default)


def ledger_lists(rr: Any):
    """Resolve a round's per-client replay ledger with its defaults:
    ``(clients, steps, step_flops, step_hbm, upload_bytes, down_each)`` —
    client ids, local step counts, per-STEP FLOPs, per-STEP HBM bytes,
    per-client upload bytes, and the per-client download bytes share.

    ``rr`` is duck-typed on the ``RoundResult`` replay fields
    (``clients``, ``client_steps``, ``client_step_flops``,
    ``client_step_hbm``, ``client_upload_bytes``, ``download_bytes``) —
    either attributes or dict keys (``record_field``); missing per-client
    lists fall back to even splits of the round totals.  The single source
    of the default rules — the event simulator's mean-workload extras
    average THIS function's output."""
    raw_clients = record_field(rr, "clients")
    clients = list(raw_clients) if raw_clients is not None else []
    n = len(clients)
    if n == 0:
        return [], [], [], [], [], 0
    c_steps = record_field(rr, "client_steps")
    steps = list(c_steps) if c_steps else [1] * n
    c_flops = record_field(rr, "client_step_flops")
    flops = list(c_flops) if c_flops else [0.0] * n
    c_hbm = record_field(rr, "client_step_hbm")
    hbm = list(c_hbm) if c_hbm else [0.0] * n
    c_up = record_field(rr, "client_upload_bytes")
    if c_up:
        up = list(c_up)
    else:
        # one remainder rule with the engines' ledger: shares must sum to
        # the exact round total (an even // split drops total % n bytes)
        up = split_bytes(record_field(rr, "upload_bytes", 0), n)
    down = record_field(rr, "download_bytes", 0)
    down_each = down // n if down else 0
    return clients, steps, flops, hbm, up, down_each


def round_timings(rr: Any, fleet: Fleet) -> List[ClientTiming]:
    """Per-client phase seconds for one recorded round (see ``ledger_lists``
    for the accepted record shape).  Sessions run with ``telemetry=False``
    record zero compute terms — the simulation then degenerates to
    comm-only time; run with telemetry on for wall-clock numbers."""
    clients, steps, flops, hbm, up, down_each = ledger_lists(rr)
    return [client_timing(k, fleet[k], n_steps=steps[i],
                          step_flops=flops[i], step_hbm_bytes=hbm[i],
                          upload_bytes=up[i], download_bytes=down_each)
            for i, k in enumerate(clients)]


# cohort size at which sync_round_s switches from the per-client object
# loop to the vectorized numpy clock (identical IEEE-754 arithmetic — the
# cutover is invisible; pinned exact in tests/test_cohort.py)
VECTOR_MIN_CLIENTS = 2048


@functools.lru_cache(maxsize=8)
def _fleet_arrays(fleet: Fleet):
    """Per-device attribute columns for the vectorized clock, cached per
    (hashable, frozen) Fleet.  Bandwidths pre-clamped like ``comm_time_s``
    (``max(bw, 1.0)``) so the vector path divides by the same numbers."""
    devs = fleet.devices
    return {
        "peak_flops": np.asarray([d.peak_flops for d in devs], np.float64),
        "hbm_bw": np.asarray([d.hbm_bw for d in devs], np.float64),
        "up_bw": np.asarray([max(d.up_bw, 1.0) for d in devs], np.float64),
        "down_bw": np.asarray([max(d.down_bw, 1.0) for d in devs],
                              np.float64),
        "latency_s": np.asarray([d.latency_s for d in devs], np.float64),
    }


def _sync_round_s_vec(clients, steps, flops, hbm, up, down_each, fleet,
                      overlap: bool) -> float:
    """Vectorized ``sync_round_s`` body.  Op-for-op the same float64
    arithmetic as ``client_timing``/``phase_total_s`` (same operand order,
    same clamps), so it returns BITWISE the number the object loop does —
    just without building 100k ``ClientTiming`` per round."""
    arr = _fleet_arrays(fleet)
    idx = np.asarray(clients, np.int64)
    lat = arr["latency_s"][idx]
    down_s = lat + float(down_each) / arr["down_bw"][idx]
    comp = np.asarray(steps, np.float64) * np.maximum(
        np.asarray(flops, np.float64) / arr["peak_flops"][idx],
        np.asarray(hbm, np.float64) / arr["hbm_bw"][idx])
    up_s = lat + np.asarray(up, np.float64) / arr["up_bw"][idx]
    if overlap:
        tot = 2.0 * lat + np.maximum(np.maximum(down_s - lat, comp),
                                     up_s - lat)
    else:
        tot = down_s + comp + up_s
    return float(tot.max()) if tot.size else 0.0


def sync_round_s(rr: Any, fleet: Fleet, *, overlap: bool = False) -> float:
    """Ideal (dropout-free) synchronous round SECONDS: the server waits for
    the slowest sampled client.  This is what ``RoundPlan.simulate`` records
    live; ``repro.sim.events`` adds availability noise and other modes.
    ``overlap=True`` uses the pipelined clock (``ClientTiming.
    total_overlap_s``) instead of the sequential phase sum.

    Mega-cohort rounds (>= ``VECTOR_MIN_CLIENTS`` participants) take a
    vectorized numpy path that computes the identical float64 numbers
    without materializing per-client ``ClientTiming`` objects."""
    clients, steps, flops, hbm, up, down_each = ledger_lists(rr)
    if len(clients) >= VECTOR_MIN_CLIENTS:
        return _sync_round_s_vec(clients, steps, flops, hbm, up, down_each,
                                 fleet, overlap)
    ts = [client_timing(k, fleet[k], n_steps=steps[i],
                        step_flops=flops[i], step_hbm_bytes=hbm[i],
                        upload_bytes=up[i], download_bytes=down_each)
          for i, k in enumerate(clients)]
    return max((t.total(overlap) for t in ts), default=0.0)


def resolve_fleet(spec: Any, n_clients: int, seed: int = 0) -> Fleet:
    """Accept a ``Fleet``, a named-fleet string, or a mixture dict."""
    from repro.sim.fleet import make_fleet, sample_fleet
    if isinstance(spec, Fleet):
        return spec
    if isinstance(spec, str):
        return make_fleet(spec, n_clients, seed=seed)
    if isinstance(spec, dict):
        return sample_fleet(spec, n_clients, seed=seed)
    raise TypeError(f"cannot resolve fleet from {spec!r}")


def device_roofline_s(flops: float, hbm_bytes: float, comm_bytes: float,
                      dev: DeviceProfile) -> dict:
    """Ledger totals (FLOPs, HBM bytes, wire bytes) -> the three roofline
    terms in SECONDS on one device (``benchmarks/roofline.py`` merges
    session rounds through this)."""
    return {"compute": flops / dev.peak_flops,
            "memory": hbm_bytes / dev.hbm_bw,
            "collective": comm_bytes / max(dev.up_bw, 1.0)}
