"""Heterogeneous device fleets for the wall-clock federation simulator.

A ``DeviceProfile`` is the hardware a federated client trains on: sustained
dense FLOP/s at training precision, HBM bandwidth, and the asymmetric WAN
link to the server (uplink is the scarce direction for residential clients).
``dropout`` is the per-round probability the client fails mid-round — the
availability process the event simulator samples.

Presets span the deployment spectrum the FL-foundation-model surveys flag
as the open systems problem: datacenter accelerators (the regime where the
paper's FLOP ledger translates ~directly to time) down to edge boxes and
phones (where uplink and stragglers dominate and FFDAPT's compute saving is
diluted).  Numbers are public-spec order-of-magnitude figures — the
simulator's claims are *relative* (FDAPT vs FFDAPT, sync vs async on the
same fleet), which is insensitive to absolute calibration.

A ``Fleet`` maps client k -> its device.  Sampling is deterministic in
``seed`` (``np.random.default_rng``): the same (mix, n, seed) always
produces the same fleet, so simulated ledgers are reproducible artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


def mbps(x: float) -> float:
    """Megabits/s -> bytes/s.

    >>> mbps(8.0)
    1000000.0
    """
    return x * 1e6 / 8.0


def gbps(x: float) -> float:
    """Gigabits/s -> bytes/s.

    >>> gbps(1.0)
    125000000.0
    """
    return x * 1e9 / 8.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One client's hardware + link, the inputs of the roofline time model.

    Units: ``peak_flops`` is FLOP/s, the two ``*_bw`` fields and ``hbm_bw``
    are bytes/s, ``latency_s`` is seconds, ``dropout`` is a probability.
    ``calibrated_from`` is empty for datasheet presets; a calibrated profile
    (``repro.sim.calibrate``) names the measurement it was fitted to, so a
    ledger simulated on it carries its own provenance.
    """

    name: str
    peak_flops: float             # sustained dense FLOP/s (training precision)
    hbm_bw: float                 # bytes/s accelerator memory bandwidth
    up_bw: float                  # client->server bytes/s
    down_bw: float                # server->client bytes/s
    dropout: float = 0.0          # P(mid-round failure) per round
    latency_s: float = 0.05       # fixed per-transfer overhead (RTT + setup), s
    calibrated_from: str = ""     # provenance: "" = datasheet numbers


PRESETS: Dict[str, DeviceProfile] = {
    # datacenter accelerators: fat pipes, never drop
    "h100": DeviceProfile("h100", 9.9e14, 3.35e12, gbps(25), gbps(25),
                          latency_s=0.005),
    "a100": DeviceProfile("a100", 3.12e14, 2.0e12, gbps(10), gbps(10),
                          latency_s=0.005),
    "tpu-v4": DeviceProfile("tpu-v4", 2.75e14, 1.2e12, gbps(10), gbps(10),
                            latency_s=0.005),
    # the paper's own hardware (2x RTX 2080 Ti, 1 Gbps campus link)
    "rtx2080ti": DeviceProfile("rtx2080ti", 2.69e13, 6.16e11, gbps(1),
                               gbps(1), dropout=0.01),
    # prosumer / edge
    "rtx4090": DeviceProfile("rtx4090", 1.65e14, 1.01e12, mbps(500),
                             mbps(500), dropout=0.02),
    "jetson-orin": DeviceProfile("jetson-orin", 1.0e13, 2.05e11, mbps(100),
                                 mbps(200), dropout=0.05),
    "laptop": DeviceProfile("laptop", 7.0e12, 1.0e11, mbps(30), mbps(300),
                            dropout=0.08, latency_s=0.1),
    "phone": DeviceProfile("phone", 2.0e12, 5.1e10, mbps(10), mbps(50),
                           dropout=0.15, latency_s=0.2),
}


# named mixtures: fleet name -> {preset: sampling weight}
FLEET_MIXES: Dict[str, Dict[str, float]] = {
    # homogeneous references
    "uniform-a100": {"a100": 1.0},
    "uniform-tpu": {"tpu-v4": 1.0},
    "paper-2080ti": {"rtx2080ti": 1.0},
    # heterogeneous: the cross-silo GPU spread of a real consortium
    "silo-mixed": {"h100": 0.2, "a100": 0.4, "rtx4090": 0.25,
                   "rtx2080ti": 0.15},
    # heterogeneous: cross-device, uplink- and straggler-dominated
    "edge-mixed": {"a100": 0.1, "rtx4090": 0.2, "rtx2080ti": 0.2,
                   "jetson-orin": 0.2, "laptop": 0.2, "phone": 0.1},
    "crossdevice": {"laptop": 0.4, "jetson-orin": 0.2, "phone": 0.4},
}

FLEETS: Tuple[str, ...] = tuple(sorted(FLEET_MIXES))


@dataclasses.dataclass(frozen=True)
class Fleet:
    """devices[k] is client k's hardware for the whole session."""

    name: str
    devices: Tuple[DeviceProfile, ...]
    seed: int = 0

    def __len__(self) -> int:
        return len(self.devices)

    def __getitem__(self, k: int) -> DeviceProfile:
        # strict: a history replayed on a too-small fleet is a caller bug
        # (silent modulo aliasing would double-book devices)
        if not 0 <= k < len(self.devices):
            raise IndexError(
                f"client {k} outside fleet of {len(self.devices)} devices — "
                f"build the fleet with n >= the session's client count")
        return self.devices[k]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.devices:
            out[d.name] = out.get(d.name, 0) + 1
        return out


def sample_fleet(mix: Dict[str, float], n: int, *, seed: int = 0,
                 name: str = "custom", calibrated: bool = False) -> Fleet:
    """Draw n devices i.i.d. from ``mix`` (preset -> weight), deterministically
    in ``seed``.  Preset order is sorted, so dict ordering cannot change the
    draw.  ``calibrated=True`` draws from the measurement-anchored registry
    (``repro.sim.calibrate.CALIBRATED_PRESETS``) instead of the datasheet
    presets — same names, same sampling, fitted efficiency factors."""
    names = sorted(mix)
    w = np.asarray([mix[p] for p in names], dtype=np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"bad mixture weights {mix!r}")
    presets = PRESETS
    if calibrated:
        from repro.sim.calibrate import CALIBRATED_PRESETS
        presets = CALIBRATED_PRESETS
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(names), size=n, p=w / w.sum())
    return Fleet(name, tuple(presets[names[i]] for i in idx), seed)


def make_fleet(name: str, n: int, *, seed: int = 0,
               calibrated: bool = False) -> Fleet:
    """Build a named fleet (see ``FLEETS``) of n clients.  With
    ``calibrated=True`` every device comes from the calibrated registry
    (datasheet peaks scaled by the fitted MFU / effective-bandwidth factors
    of ``repro.sim.calibrate.PAPER_2080TI_ANCHOR``)."""
    if name not in FLEET_MIXES:
        raise ValueError(f"unknown fleet {name!r} (want one of {FLEETS})")
    return sample_fleet(FLEET_MIXES[name], n, seed=seed, name=name,
                        calibrated=calibrated)
