"""RWKV6 "Finch" block: data-dependent-decay time mixing + channel mixing.

Faithful to arXiv:2404.05892: token-shift with data-dependent low-rank
interpolation (ddlerp) over the five mix targets (w,k,v,r,g), low-rank
data-dependent decay ``w = exp(-exp(w0 + tanh(x_w A1) A2))``, per-head WKV
state with bonus ``u``, per-head GroupNorm, and squared-ReLU channel mixing.

The WKV recurrence itself runs through :mod:`repro.kernels`
(``impl="pallas"``) or the pure-jnp oracle (``impl="xla"``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.layers import apply_layernorm
from repro.nn.param import ParamCtx

LORA = 32          # ddlerp low-rank dim
LORA_W = 64        # decay low-rank dim
HEAD_DIM = 64      # rwkv6 head size


def rwkv_heads(d_model: int, ssm_heads: int = 0) -> int:
    return ssm_heads or max(1, d_model // HEAD_DIM)


def init_rwkv_time_mix(ctx: ParamCtx, d: int, n_heads: int):
    hd = d // n_heads
    lw = min(LORA_W, d)
    la = min(LORA, d)
    return {
        "mu_x": ctx.param("mu_x", (d,), P.uniform(0.5), (P.EMBED,)),
        "mu_5": ctx.param("mu_5", (5, d), P.uniform(0.5), (None, P.EMBED)),
        "ddlerp_a": ctx.param("ddlerp_a", (d, 5, la), P.normal(0.01),
                              (P.EMBED, None, None)),
        "ddlerp_b": ctx.param("ddlerp_b", (5, la, d), P.normal(0.01),
                              (None, None, P.EMBED)),
        "w0": ctx.param("w0", (d,), P.normal(0.5), (P.EMBED,)),
        "w_a": ctx.param("w_a", (d, lw), P.normal(0.01), (P.EMBED, None)),
        "w_b": ctx.param("w_b", (lw, d), P.normal(0.01), (None, P.EMBED)),
        "wr": ctx.param("wr", (d, d), P.fan_in(), (P.EMBED, P.HEADS)),
        "wk": ctx.param("wk", (d, d), P.fan_in(), (P.EMBED, P.HEADS)),
        "wv": ctx.param("wv", (d, d), P.fan_in(), (P.EMBED, P.HEADS)),
        "wg": ctx.param("wg", (d, d), P.fan_in(), (P.EMBED, P.HEADS)),
        "wo": ctx.param("wo", (d, d), P.fan_in(), (P.HEADS, P.EMBED)),
        "u": ctx.param("u", (n_heads, hd), P.normal(0.5), (None, P.HEAD_DIM)),
        "ln_x": {
            "scale": ctx.param("lnx_scale", (d,), P.ones(), (P.EMBED,)),
            "bias": ctx.param("lnx_bias", (d,), P.zeros(), (P.EMBED,)),
        },
    }


def init_rwkv_channel_mix(ctx: ParamCtx, d: int, d_ff: int):
    return {
        "mu_k": ctx.param("mu_k", (d,), P.uniform(0.5), (P.EMBED,)),
        "mu_r": ctx.param("mu_r", (d,), P.uniform(0.5), (P.EMBED,)),
        "wk": ctx.param("wk", (d, d_ff), P.fan_in(), (P.EMBED, P.FFN)),
        "wr": ctx.param("wr", (d, d), P.fan_in(), (P.EMBED, P.HEADS)),
        "wv": ctx.param("wv", (d_ff, d), P.fan_in(), (P.FFN, P.EMBED)),
    }


def _shift(x, last):
    """Token shift: x_prev[t] = x[t-1]; position 0 takes ``last`` (decode
    carry-in, zeros at sequence start).  x: (B,T,d); last: (B,d)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(params, y, n_heads, eps=64e-5):
    """Per-head LayerNorm (RWKV's GroupNorm with groups=heads)."""
    B, T, d = y.shape
    yh = y.reshape(B, T, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    yh = yh.reshape(B, T, d)
    return (yh * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(y.dtype)


def apply_rwkv_time_mix(params, x, n_heads, *, last_x, state, impl="xla"):
    """x: (B,T,d); last_x: (B,d); state: (B,H,hd,hd).
    Returns (out, new_last_x, new_state)."""
    B, T, d = x.shape
    hd = d // n_heads
    dt = x.dtype

    xprev = _shift(x, last_x)
    dx = xprev - x
    xxx = x + dx * params["mu_x"].astype(dt)
    # data-dependent lerp deltas for the five targets (w,k,v,r,g)
    a = jnp.tanh(jnp.einsum("btd,dfa->btfa", xxx, params["ddlerp_a"].astype(dt)))
    deltas = jnp.einsum("btfa,fad->btfd", a, params["ddlerp_b"].astype(dt))
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (
        params["mu_5"].astype(dt)[None, None] + deltas)        # (B,T,5,d)
    x_w, x_k, x_v, x_r, x_g = [mixed[:, :, i, :] for i in range(5)]

    r = x_r @ params["wr"].astype(dt)
    k = x_k @ params["wk"].astype(dt)
    v = x_v @ params["wv"].astype(dt)
    g = x_g @ params["wg"].astype(dt)
    wlog = params["w0"].astype(jnp.float32) + jnp.einsum(
        "btd,dl->btl", x_w.astype(jnp.float32), params["w_a"].astype(jnp.float32)
    ) @ params["w_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))                                 # (B,T,d) in (0,1)

    def heads(z):
        return z.reshape(B, T, n_heads, hd)

    if impl == "pallas":
        from repro.kernels import ops as kops
        y, new_state = kops.rwkv6_scan(heads(r), heads(k), heads(v),
                                       heads(w.astype(dt)), params["u"], state)
    elif impl == "chunked" and T > 1:
        from repro.kernels import ref as kref
        y, new_state = kref.rwkv6_scan_chunked(
            heads(r), heads(k), heads(v), heads(w.astype(dt)), params["u"],
            state)
    else:
        from repro.kernels import ref as kref
        y, new_state = kref.rwkv6_scan(heads(r), heads(k), heads(v),
                                       heads(w.astype(dt)), params["u"], state)

    y = _group_norm(params["ln_x"], y.reshape(B, T, d), n_heads)
    out = (y * jax.nn.silu(g)) @ params["wo"].astype(dt)
    return out, x[:, -1, :], new_state


def apply_rwkv_channel_mix(params, x, *, last_x):
    dt = x.dtype
    xprev = _shift(x, last_x)
    dx = xprev - x
    x_k = x + dx * params["mu_k"].astype(dt)
    x_r = x + dx * params["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(x_k @ params["wk"].astype(dt)))
    out = jax.nn.sigmoid(x_r @ params["wr"].astype(dt)) * (k @ params["wv"].astype(dt))
    return out, x[:, -1, :]
