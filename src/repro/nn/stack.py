"""Scan-over-layers stacks with *static* freeze segmentation.

``init_stack`` builds one stacked parameter tree (leading ``layers`` dim) by
vmapping a single-layer initializer over per-layer keys — one tree, one scan,
fast compiles even for nemotron's 96 layers.

``scan_stack`` runs the layers with ``jax.lax.scan`` (optionally remat'd) and
implements FFDAPT's frozen-consecutive-window as *program structure*: the
stack is split at static boundaries into trainable / frozen segments, and the
frozen segment's parameters pass through ``stop_gradient`` — so XLA's
autodiff never builds the dW graph for frozen layers.  This is what turns the
paper's Algorithm 1 into a real backward-FLOP reduction rather than a masked
update (both modes exist; see ``repro.core.ffdapt``).

The freeze window may wrap around the end of the stack (Algorithm 1's
``else`` branch); segmentation handles up to two frozen runs.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.param import ParamCtx


def init_stack(ctx: ParamCtx, name: str, n: int, init_one: Callable[[ParamCtx], Any]):
    """Stacked params: init_one(ctx)->boxed tree; returns the same tree with a
    leading (n,) layers dim on every leaf and ``LAYERS`` prepended to axes."""
    base_key = ctx._key_for(name)
    dtype = ctx.dtype

    def one_vals(key):
        return P.unbox(init_one(ParamCtx(key, dtype)))

    keys = jax.random.split(base_key, n)
    vals = jax.vmap(one_vals)(keys)
    template = jax.eval_shape(lambda k: init_one(ParamCtx(k, dtype)), base_key)
    axes = P.box_axes(template)
    stacked_axes = jax.tree.map(lambda a: (P.LAYERS,) + tuple(a), axes,
                                is_leaf=lambda x: isinstance(x, tuple) or x is None)
    return P.rebox(vals, stacked_axes)


# ---------------------------------------------------------------------------
# Freeze segmentation (Algorithm 1 geometry)
# ---------------------------------------------------------------------------

def freeze_window_mask(n: int, window: Optional[Tuple[int, int]]) -> Tuple[bool, ...]:
    """(start, n_frozen) -> per-layer frozen mask.

    The window is the set {(start + i) % n : i < n_frozen} — consecutive with
    wrap-around, exactly Algorithm 1's two branches.
    """
    mask = [False] * n
    if window is None or n == 0:
        return tuple(mask)
    start, nf = window
    start %= n
    for i in range(min(nf, n)):
        mask[(start + i) % n] = True
    return tuple(mask)


def mask_segments(frozen: Sequence[bool]) -> Sequence[Tuple[int, int, bool]]:
    """Static per-layer mask -> ordered contiguous [(lo, hi, frozen)] runs."""
    segs = []
    lo = 0
    n = len(frozen)
    for i in range(1, n + 1):
        if i == n or frozen[i] != frozen[lo]:
            segs.append((lo, i, bool(frozen[lo])))
            lo = i
    return segs


def _slice_tree(tree, lo, hi):
    return jax.tree.map(lambda t: t[lo:hi], tree)


def scan_stack(params: Any, x: Any, body: Callable, *, aux: Any = None,
               remat: bool = True, frozen: Optional[Sequence[bool]] = None,
               unroll: bool = False):
    """Run ``x', out_l = body(layer_params, x, aux_l)`` over the stack.

    params: unboxed stacked tree (leading layer dim on every leaf).
    aux:    optional per-layer scanned inputs (e.g. KV-cache slices).
    frozen: optional STATIC per-layer bool mask -> the stack is split into
            contiguous runs and frozen runs scan over stop_gradient'd params.
    Returns (x, outs) where outs stacks each layer's ``out_l`` (or None).
    """
    n = jax.tree.leaves(params)[0].shape[0]

    def step(carry, xs):
        p, a = xs
        y, out = body(p, carry, a)
        return y, out

    f = jax.checkpoint(step) if remat else step

    segs = mask_segments(tuple(frozen)) if frozen is not None else [(0, n, False)]
    outs = []
    for lo, hi, frz in segs:
        pseg = _slice_tree(params, lo, hi)
        if frz:
            pseg = jax.tree.map(jax.lax.stop_gradient, pseg)
        aseg = _slice_tree(aux, lo, hi) if aux is not None else None
        x, out = jax.lax.scan(f, x, (pseg, aseg),
                              unroll=(hi - lo) if unroll else 1)
        outs.append(out)

    if not outs or all(o is None for o in outs):
        return x, None
    merged = jax.tree.map(lambda *ts: jnp.concatenate(ts, axis=0), *outs)
    return x, merged
