"""Mamba2 (SSD) block for the zamba2 hybrid architecture.

arXiv:2405.21060 structure: fused in-projection -> causal depthwise conv on
(x,B,C) -> selective scan with scalar-per-head A -> gated RMSNorm -> out
projection.  The recurrence runs through :mod:`repro.kernels`
(``mamba2_scan``) or its jnp oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.param import ParamCtx

HEAD_P = 64     # mamba2 head dim


def mamba_dims(d_model: int, d_state: int, expand: int = 2, conv_dim: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // HEAD_P
    conv_ch = d_inner + 2 * d_state           # x ++ B ++ C get convolved
    return d_inner, n_heads, conv_ch


def init_mamba2(ctx: ParamCtx, d_model: int, d_state: int, *, expand=2,
                conv_dim=4):
    d_inner, n_heads, conv_ch = mamba_dims(d_model, d_state, expand, conv_dim)
    proj_out = 2 * d_inner + 2 * d_state + n_heads   # z ++ xBC ++ dt
    return {
        "in_proj": ctx.param("in_proj", (d_model, proj_out), P.fan_in(),
                             (P.EMBED, P.FFN)),
        "conv_w": ctx.param("conv_w", (conv_dim, conv_ch), P.normal(0.1),
                            (P.DCONV, P.FFN)),
        "conv_b": ctx.param("conv_b", (conv_ch,), P.zeros(), (P.FFN,)),
        "a_log": ctx.param("a_log", (n_heads,), P.uniform(1.0), (None,)),
        "dt_bias": ctx.param("dt_bias", (n_heads,), P.normal(0.5), (None,)),
        "d_skip": ctx.param("d_skip", (n_heads,), P.ones(), (None,)),
        "norm_scale": ctx.param("norm_scale", (d_inner,), P.ones(), (P.FFN,)),
        "out_proj": ctx.param("out_proj", (d_inner, d_model), P.fan_in(),
                              (P.FFN, P.EMBED)),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv.  x: (B,T,C); w: (W,C); conv_state: (B,W-1,C)
    carry-in (zeros at sequence start).  Returns (y, new_conv_state)."""
    B, T, C = x.shape
    W = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B,T+W-1,C)
    y = sum(xp[:, i:i + T, :] * w[i][None, None, :] for i in range(W))
    return y + b[None, None, :], xp[:, T:, :]      # last W-1 inputs


def apply_mamba2(params, x, cfg, *, conv_state=None, ssm_state=None,
                 impl="xla"):
    """x: (B,T,d) -> (out, new_conv_state, new_ssm_state)."""
    B, T, d = x.shape
    dt_ = x.dtype
    d_state = cfg.ssm_state
    d_inner, n_heads, conv_ch = mamba_dims(d, d_state, cfg.ssm_expand,
                                           cfg.conv_dim)

    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    xBC, new_conv = _causal_conv(xBC, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_), conv_state)
    xBC = jax.nn.silu(xBC)
    xs, bmat, cmat = jnp.split(xBC, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,T,H)

    xh = xs.reshape(B, T, n_heads, HEAD_P)
    if ssm_state is None:
        ssm_state = jnp.zeros((B, n_heads, HEAD_P, d_state), jnp.float32)

    if impl == "pallas":
        from repro.kernels import ops as kops
        y, new_ssm = kops.mamba2_scan(xh, dt.astype(dt_), params["a_log"],
                                      bmat, cmat, ssm_state)
    elif impl == "chunked" and T > 1:
        from repro.kernels import ref as kref
        y, new_ssm = kref.mamba2_scan_chunked(xh, dt.astype(dt_),
                                              params["a_log"], bmat, cmat,
                                              ssm_state, chunk=cfg.ssm_chunk)
    else:
        from repro.kernels import ref as kref
        y, new_ssm = kref.mamba2_scan(xh, dt.astype(dt_), params["a_log"],
                                      bmat, cmat, ssm_state)

    y = y + params["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B, T, d_inner)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)
          * params["norm_scale"].astype(jnp.float32)).astype(dt_)
    return yz @ params["out_proj"].astype(dt_), new_conv, new_ssm
