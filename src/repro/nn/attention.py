"""Grouped-query attention with KV caches, sliding windows and cross-attention.

Three execution paths share one parameter layout:
  * ``mode="train"``    — full-sequence self-attention (causal or bidirectional),
  * ``mode="prefill"``  — causal self-attention that also fills a KV cache,
  * ``mode="decode"``   — one new token against an existing cache (ring-buffer
                          indexing when ``sliding_window`` is set).

``impl`` selects the attention-math backend: ``"xla"`` (einsum, used on CPU and
for the dry-run) or ``"pallas"`` (the flash-attention kernel in
``repro.kernels``; interpret-mode on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.layers import apply_rope, apply_rmsnorm
from repro.nn.param import ParamCtx
from repro.sharding.ctx import constrain

NEG_INF = -1e30


@dataclasses.dataclass
class KVCache:
    """Stacked-over-layers KV cache. k/v: (layers, batch, cache_len, n_kv, head_dim);
    ``index``: number of tokens already written (scalar int32)."""
    k: jax.Array
    v: jax.Array
    index: jax.Array

    def tree_flatten(self):
        return (self.k, self.v, self.index), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, lambda aux, ch: KVCache(*ch))


def make_cache(n_layers, batch, cache_len, n_kv, head_dim, dtype):
    shape = (n_layers, batch, cache_len, n_kv, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   index=jnp.zeros((), jnp.int32))


def abstract_cache(n_layers, batch, cache_len, n_kv, head_dim, dtype):
    s = jax.ShapeDtypeStruct((n_layers, batch, cache_len, n_kv, head_dim), dtype)
    return KVCache(k=s, v=s, index=jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(ctx: ParamCtx, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, *, qkv_bias=False, qk_norm=False):
    p = {
        "wq": ctx.param("wq", (d_model, n_heads, head_dim), P.fan_in(),
                        (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "wk": ctx.param("wk", (d_model, n_kv, head_dim), P.fan_in(),
                        (P.EMBED, P.KV_HEADS, P.HEAD_DIM)),
        "wv": ctx.param("wv", (d_model, n_kv, head_dim), P.fan_in(),
                        (P.EMBED, P.KV_HEADS, P.HEAD_DIM)),
        "wo": ctx.param("wo", (n_heads, head_dim, d_model), P.fan_in(),
                        (P.HEADS, P.HEAD_DIM, P.EMBED)),
    }
    if qkv_bias:
        p["bq"] = ctx.param("bq", (n_heads, head_dim), P.zeros(), (P.HEADS, P.HEAD_DIM))
        p["bk"] = ctx.param("bk", (n_kv, head_dim), P.zeros(), (P.KV_HEADS, P.HEAD_DIM))
        p["bv"] = ctx.param("bv", (n_kv, head_dim), P.zeros(), (P.KV_HEADS, P.HEAD_DIM))
    if qk_norm:
        p["q_norm"] = {"scale": ctx.param("q_norm", (head_dim,), P.ones(), (P.HEAD_DIM,))}
        p["k_norm"] = {"scale": ctx.param("k_norm", (head_dim,), P.ones(), (P.HEAD_DIM,))}
    return p


def _project_qkv(params, x, kv_x, *, qk_norm, norm_eps):
    dt = x.dtype
    q = jnp.einsum("...d,dhk->...hk", x, params["wq"].astype(dt))
    k = jnp.einsum("...d,dhk->...hk", kv_x, params["wk"].astype(dt))
    v = jnp.einsum("...d,dhk->...hk", kv_x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if qk_norm:
        q = apply_rmsnorm(params["q_norm"], q, norm_eps)
        k = apply_rmsnorm(params["k_norm"], k, norm_eps)
    return q, k, v


def _gqa_scores_combine(q, k, v, mask, *, softcap=0.0):
    """q: (B,S,H,D); k/v: (B,T,Kv,D); mask: broadcastable (B,1,S,T) additive."""
    B, S, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + mask[:, :, None, :, :] if mask.ndim == 4 else scores + mask
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


def _causal_mask(S, T, offset=0, window=0):
    """Additive (S,T) mask: query i attends to keys j with j <= i+offset and,
    if window>0, j > i+offset-window."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    ok = kj <= qi
    if window:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def apply_attention(params, x, cfg, *, mode="train", causal=True,
                    cache_k=None, cache_v=None, cache_index=None,
                    positions=None, kv_x=None, impl="xla"):
    """Returns (out, new_cache_k, new_cache_v).

    train:   x (B,S,d); caches None.
    prefill: x (B,S,d); cache_(k,v) (B,C,Kv,D) zero-filled, C>=S; writes [0,S).
    decode:  x (B,1,d); cache holds `cache_index` tokens; writes 1 token
             (ring-indexed when cfg.sliding_window>0 and C==window).
    cross-attention: kv_x (B,Tkv,d) given, causal=False, caches None.
    """
    B, S, _ = x.shape
    cross = kv_x is not None
    q, k, v = _project_qkv(params, x, kv_x if cross else x,
                           qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps)
    # activation shardings (no-ops outside a mesh context): queries may shard
    # their *sequence* dim over the model axis (ATTN_SEQ rule) when the head
    # count does not divide it — context-parallel attention instead of
    # replication.  K/V replicate over model (GQA kv heads are few).
    q = constrain(q, (P.BATCH, P.ATTN_SEQ, P.HEADS, P.HEAD_DIM))
    k = constrain(k, (P.BATCH, None, P.KV_HEADS, P.HEAD_DIM))
    v = constrain(v, (P.BATCH, None, P.KV_HEADS, P.HEAD_DIM))
    hd = cfg.head_dim_
    if positions is None:
        positions = jnp.arange(S)[None, :]

    if cfg.use_rope and not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window
    if mode == "train" or (mode == "prefill" and cache_k is None):
        if cross:
            mask = jnp.zeros((S, k.shape[1]), jnp.float32)
        elif causal:
            mask = _causal_mask(S, S, window=window)
        else:
            mask = jnp.zeros((S, S), jnp.float32)
        if impl == "pallas" and not cross:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                       softcap=cfg.attn_logit_softcap)
        else:
            out = _gqa_scores_combine(q, k, v, mask, softcap=cfg.attn_logit_softcap)
        new_k, new_v = cache_k, cache_v

    elif mode == "prefill":
        C = cache_k.shape[1]
        if window and C == window:
            # keep last `window` tokens of the prompt in the ring
            sl = jax.lax.dynamic_slice_in_dim(k, max(0, S - window), min(S, window), axis=1)
            sv = jax.lax.dynamic_slice_in_dim(v, max(0, S - window), min(S, window), axis=1)
            new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, sl, 0, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, sv, 0, axis=1)
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, 0, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, 0, axis=1)
        mask = _causal_mask(S, S, window=window)
        out = _gqa_scores_combine(q, k, v, mask, softcap=cfg.attn_logit_softcap)

    elif mode == "decode":
        C = cache_k.shape[1]
        idx = cache_index
        if window and C == window:
            slot = jnp.mod(idx, window)
        else:
            slot = idx
        new_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
        kj = jnp.arange(C)
        if window and C == window:
            valid = kj < jnp.minimum(idx + 1, window)       # ring: all written slots valid
        else:
            valid = kj <= idx
        mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (C,)
        mask = mask[None, None, None, :]                    # (1,1,1,C): bcast B, heads, S
        out = _gqa_scores_combine(q, new_k, new_v, mask,
                                  softcap=cfg.attn_logit_softcap)
    else:
        raise ValueError(mode)

    dt = x.dtype
    out = constrain(out, (P.BATCH, P.ATTN_SEQ, P.HEADS, P.HEAD_DIM))
    out = jnp.einsum("...hk,hkd->...d", out, params["wo"].astype(dt))
    out = constrain(out, (P.BATCH, P.SEQ, P.EMBED))
    return out, new_k, new_v
