"""Mixture-of-Experts layer: top-k router + static-capacity dispatch.

The dispatch is the standard drop-token formulation (GShard/Switch family,
MaxText-style): every token picks its top-k experts; each expert has a static
per-step capacity ``C = ceil(T * top_k / E * capacity_factor)``; tokens beyond
capacity are dropped (their expert contribution is zero — the residual stream
carries them through).  This keeps the program shape static under jit and the
FLOPs proportional to *active* experts, which is what the roofline needs for
olmoe's 64 experts — computing all experts densely would inflate compute 8x.

The (E, C, d) x (E, d, f) grouped matmuls are the compute hot-spot; ``impl=
"pallas"`` routes them through :mod:`repro.kernels.moe_gmm`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import param as P
from repro.nn.param import ParamCtx
from repro.sharding.ctx import constrain


def init_moe(ctx: ParamCtx, d_model: int, d_ff: int, n_experts: int):
    """SwiGLU experts + linear router.

    Expert weights shard over the EXPERT dim only: FSDP-sharding their d_model
    dim over "data" forces GSPMD to all-gather the (groups, E, C, d) token
    buffers instead of the (much smaller) weights under local dispatch —
    measured as the dominant collective in the olmoe baseline (§Perf)."""
    return {
        "router": ctx.param("router", (d_model, n_experts), P.normal(0.02),
                            (P.EMBED, P.EXPERTS)),
        "wi_gate": ctx.param("wi_gate", (n_experts, d_model, d_ff), P.fan_in(),
                             (P.EXPERTS, None, None)),
        "wi_up": ctx.param("wi_up", (n_experts, d_model, d_ff), P.fan_in(),
                           (P.EXPERTS, None, None)),
        "wo": ctx.param("wo", (n_experts, d_ff, d_model), P.fan_in(),
                        (P.EXPERTS, None, None)),
    }


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float = 1.25) -> int:
    cap = int(np.ceil(n_tokens * top_k / n_experts * capacity_factor))
    # pad to a lane-friendly multiple of 8 (128 on real TPU shapes)
    return max(8, ((cap + 7) // 8) * 8)


def route_topk(router_logits: jax.Array, top_k: int):
    """(T, E) logits -> (gates (T,k) fp32 normalized, idx (T,k) int32, probs)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e (f from all top-k picks)."""
    T = probs.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * idx.shape[-1], 1)
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def dispatch_indices(idx: jax.Array, capacity: int, n_experts: int):
    """Assignment slots.

    Returns:
      buf:   (E, C) int32 — token id feeding each expert slot (T = dummy row).
      gatep: (E, C) int32 — which of the token's k picks this slot is.
      valid: (E, C) bool.
    """
    T, k = idx.shape
    flat_e = idx.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                  # 1-based position
    pos_in_e = jnp.sum(pos, axis=-1) - 1                       # (T*k,)
    keep = pos_in_e < capacity
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    pick = jnp.tile(jnp.arange(k, dtype=jnp.int32), T)
    # scatter into (E, C); dropped assignments scatter to a dummy column.
    e_tgt = jnp.where(keep, flat_e, n_experts)                 # dummy expert row
    c_tgt = jnp.where(keep, pos_in_e, 0)
    buf = jnp.full((n_experts + 1, capacity), T, jnp.int32)
    buf = buf.at[e_tgt, c_tgt].set(jnp.where(keep, tok, T))
    gatep = jnp.zeros((n_experts + 1, capacity), jnp.int32)
    gatep = gatep.at[e_tgt, c_tgt].set(jnp.where(keep, pick, 0))
    buf, gatep = buf[:n_experts], gatep[:n_experts]
    valid = buf < T
    return buf, gatep, valid


def _moe_tokens(params, xt: jax.Array, top_k: int, capacity_factor: float,
                impl: str):
    """MoE over one flat token block xt (T, d) -> (y (T,d), aux)."""
    T, d = xt.shape
    E = params["router"].shape[-1]
    C = expert_capacity(T, E, top_k, capacity_factor)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates, idx, probs = route_topk(logits, top_k)
    aux = load_balance_loss(probs, idx, E)

    buf, gatep, valid = dispatch_indices(idx, C, E)
    # gather expert inputs; dummy token T reads a zero row.
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xpad[buf]                                             # (E, C, d)
    xe = constrain(xe, (P.EXPERTS, None, None))                # expert-parallel

    if impl == "pallas":
        from repro.kernels import ops as kops
        ye = kops.moe_ffn(xe, params["wi_gate"].astype(xt.dtype),
                          params["wi_up"].astype(xt.dtype),
                          params["wo"].astype(xt.dtype))
    else:
        g = jnp.einsum("ecd,edf->ecf", xe, params["wi_gate"].astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, params["wi_up"].astype(xt.dtype))
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(xt.dtype))
        ye = constrain(ye, (P.EXPERTS, None, None))

    # combine: weight each slot by its token's gate, scatter-add back.
    slot_gate = gates[jnp.clip(buf, 0, T - 1), gatep]          # (E, C) fp32
    slot_gate = jnp.where(valid, slot_gate, 0.0).astype(xt.dtype)
    y = jnp.zeros((T + 1, d), xt.dtype)
    y = y.at[buf.reshape(-1)].add((ye * slot_gate[..., None]).reshape(-1, d))
    return y[:T], aux


def apply_moe(params, x: jax.Array, top_k: int, *,
              capacity_factor: float = 1.25, impl: str = "xla",
              groups: int = 0):
    """x: (..., d) -> (y, aux_loss).  Leading dims are flattened to tokens.

    ``groups`` > 1 enables LOCAL DISPATCH (beyond-paper, §Perf): routing,
    cumsum and gather/scatter run independently per token group (one group
    per data shard, capacity C/G each), so the dispatch bookkeeping never
    crosses shards — without it GSPMD replicates the (T*k, E) cumsum on
    every device and all-reduces whole expert buffers (the collective-bound
    olmoe baseline).  Per-group capacity drops tokens per group rather than
    globally — standard expert-parallel semantics.
    """
    d = x.shape[-1]
    lead = x.shape[:-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    if groups > 1 and T % groups == 0 and (T // groups) >= top_k:
        y, aux = _moe_grouped(params, xt.reshape(groups, T // groups, d),
                              top_k, capacity_factor)
        return y.reshape(*lead, d), aux
    y, aux = _moe_tokens(params, xt, top_k, capacity_factor, impl)
    return y.reshape(*lead, d), aux


def _moe_grouped(params, xg: jax.Array, top_k: int, capacity_factor: float):
    """Local-dispatch path: xg (G, Tl, d), one group per data shard.

    Routing/cumsum/gather/scatter are group-local (vmapped integer work);
    the expert FFN keeps G and E as explicit einsum axes sharded
    (data, model) so the grouped matmuls run with NO gathered activations.
    """
    G, Tl, d = xg.shape
    E = params["router"].shape[-1]
    C = expert_capacity(Tl, E, top_k, capacity_factor)
    dt = xg.dtype
    xg = constrain(xg, (P.BATCH, None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates, idx, probs = jax.vmap(lambda l: route_topk(l, top_k))(logits)
    aux = jnp.mean(jax.vmap(lambda p, i: load_balance_loss(p, i, E))(probs, idx))

    buf, gatep, valid = jax.vmap(lambda i: dispatch_indices(i, C, E))(idx)
    xpad = jnp.concatenate([xg, jnp.zeros((G, 1, d), dt)], axis=1)
    xe = jax.vmap(lambda xp, b: xp[b])(xpad, buf)              # (G, E, C, d)
    xe = constrain(xe, (P.BATCH, P.EXPERTS, None, None))

    g_ = jnp.einsum("gecd,edf->gecf", xe, params["wi_gate"].astype(dt))
    u_ = jnp.einsum("gecd,edf->gecf", xe, params["wi_up"].astype(dt))
    h = jax.nn.silu(g_) * u_
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(dt))
    ye = constrain(ye, (P.BATCH, P.EXPERTS, None, None))

    slot_gate = jax.vmap(lambda g, b, gp: g[jnp.clip(b, 0, Tl - 1), gp])(
        gates, buf, gatep)                                     # (G, E, C)
    slot_gate = jnp.where(valid, slot_gate, 0.0).astype(dt)

    def combine(b, y_e, sg):
        out = jnp.zeros((Tl + 1, d), dt)
        return out.at[b.reshape(-1)].add(
            (y_e * sg[..., None]).reshape(-1, d))[:Tl]

    y = jax.vmap(combine)(buf, ye, slot_gate)                  # (G, Tl, d)
    return constrain(y, (P.BATCH, None, None)), aux
