"""Core layers: norms, RoPE, MLPs, embeddings.

Every ``init_*`` takes a :class:`~repro.nn.param.ParamCtx` and returns a boxed
pytree; every ``apply_*`` takes the *unboxed* params.  All apply functions are
shape-polymorphic over leading batch/seq dims where possible.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import param as P
from repro.nn.param import Box, ParamCtx
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(ctx: ParamCtx, d: int):
    return {"scale": ctx.param("scale", (d,), P.ones(), (P.EMBED,))}


def apply_rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(ctx: ParamCtx, d: int):
    return {
        "scale": ctx.param("scale", (d,), P.ones(), (P.EMBED,)),
        "bias": ctx.param("bias", (d,), P.zeros(), (P.EMBED,)),
    }


def apply_layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def init_norm(ctx: ParamCtx, d: int, kind: str):
    return init_rmsnorm(ctx, d) if kind == "rmsnorm" else init_layernorm(ctx, d)


def apply_norm(params, x, kind: str, eps: float = 1e-5):
    return (apply_rmsnorm if kind == "rmsnorm" else apply_layernorm)(params, x, eps)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(ctx: ParamCtx, d_model: int, d_ff: int, kind: str):
    p = {}
    if kind == "swiglu":
        p["wi_gate"] = ctx.param("wi_gate", (d_model, d_ff), P.fan_in(), (P.EMBED, P.FFN))
        p["wi_up"] = ctx.param("wi_up", (d_model, d_ff), P.fan_in(), (P.EMBED, P.FFN))
        p["wo"] = ctx.param("wo", (d_ff, d_model), P.fan_in(), (P.FFN, P.EMBED))
    elif kind in ("gelu", "relu2"):
        p["wi"] = ctx.param("wi", (d_model, d_ff), P.fan_in(), (P.EMBED, P.FFN))
        p["bi"] = ctx.param("bi", (d_ff,), P.zeros(), (P.FFN,))
        p["wo"] = ctx.param("wo", (d_ff, d_model), P.fan_in(), (P.FFN, P.EMBED))
        p["bo"] = ctx.param("bo", (d_model,), P.zeros(), (P.EMBED,))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return p


def _ffn_axes(h):
    return (P.BATCH,) + (None,) * (h.ndim - 2) + (P.FFN,)


def apply_mlp(params, x, kind: str):
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(x.dtype))
        h = constrain(jax.nn.silu(g) * u, _ffn_axes(g))
        return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype)) + params["bi"].astype(x.dtype)
    h = constrain(h, _ffn_axes(h))
    if kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":                       # Nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype)) + params["bo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embedding(ctx: ParamCtx, vocab: int, d_model: int):
    return {"table": ctx.param("table", (vocab, d_model), P.normal(0.02), (P.VOCAB, P.EMBED))}


def apply_embedding(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def init_positional(ctx: ParamCtx, max_len: int, d_model: int):
    return {"table": ctx.param("pos_table", (max_len, d_model), P.normal(0.02), (P.SEQ, P.EMBED))}


def apply_positional(params, positions, dtype):
    return params["table"].astype(dtype)[positions]


def init_lm_head(ctx: ParamCtx, d_model: int, vocab: int):
    return {"w": ctx.param("w", (d_model, vocab), P.fan_in(), (P.EMBED, P.VOCAB))}


def apply_lm_head(params, x, *, embedding_table=None):
    """Logits; if embedding_table is given, weights are tied (head params unused)."""
    if embedding_table is not None:
        return jnp.einsum("...d,vd->...v", x, embedding_table.astype(x.dtype))
    return jnp.einsum("...d,dv->...v", x, params["w"].astype(x.dtype))
