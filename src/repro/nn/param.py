"""Parameter containers with logical sharding axes.

The framework is pure JAX (no flax/haiku). Every ``init_*`` function returns a
pytree whose leaves are :class:`Box` — an array (or, under ``jax.eval_shape``,
a ``ShapeDtypeStruct``) tagged with a tuple of *logical axis names*, one per
dimension.  The sharding layer (``repro.sharding``) resolves logical names to
mesh ``PartitionSpec``s; the training layer strips the boxes and works on plain
array pytrees.

Keeping value and axes in a single tree (rather than two parallel trees built
by duplicated code) makes it impossible for the sharding annotation to drift
out of sync with the parameter structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary.  ``repro.sharding.rules`` maps these to mesh axes.
EMBED = "embed"          # d_model
FFN = "ffn"              # feed-forward hidden
VOCAB = "vocab"          # vocabulary
HEADS = "heads"          # query heads
KV_HEADS = "kv_heads"    # key/value heads
HEAD_DIM = "head_dim"    # per-head dim
LAYERS = "layers"        # stacked (scanned) layer dim — never mesh-sharded
LORA = "lora"            # PEFT low-rank bottleneck dim — never mesh-sharded
EXPERTS = "experts"      # MoE experts
DSTATE = "dstate"        # SSM state dim
DCONV = "dconv"          # conv kernel dim
SEQ = "seq"              # sequence (activations / caches)
ATTN_SEQ = "attn_seq"    # query seq dim inside attention (context parallel)
BATCH = "batch"          # batch (activations / caches)
CLIENT = "client"        # federated client dim (maps to the "pod" mesh axis)
NONE = None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Box:
    """An array tagged with per-dimension logical axis names."""

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __post_init__(self):
        if hasattr(self.value, "ndim") and len(self.axes) != self.value.ndim:
            raise ValueError(
                f"axes {self.axes} rank-mismatch value shape {self.value.shape}"
            )


def is_box(x: Any) -> bool:
    return isinstance(x, Box)


def unbox(tree: Any) -> Any:
    """Strip Box wrappers -> plain value pytree."""
    return jax.tree.map(lambda b: b.value if is_box(b) else b, tree, is_leaf=is_box)


def unbox_if(tree: Any) -> Any:
    """``unbox`` that is a no-op on already-plain trees (apply functions accept
    either form)."""
    return unbox(tree)


def box_axes(tree: Any) -> Any:
    """Extract the logical-axes pytree (same structure as ``unbox(tree)``)."""
    return jax.tree.map(lambda b: b.axes if is_box(b) else None, tree, is_leaf=is_box)


def rebox(values: Any, axes: Any) -> Any:
    """Inverse of (unbox, box_axes)."""
    return jax.tree.map(lambda v, a: Box(v, a) if a is not None else v, values, axes,
                        is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


def normal(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    return init


def fan_in(scale: float = 1.0) -> Initializer:
    """LeCun-style: stddev = sqrt(scale / fan_in); fan_in = prod of all dims but last."""
    def init(key, shape, dtype):
        fin = max(1, int(np.prod(shape[:-1])))
        std = (scale / fin) ** 0.5
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    return init


def zeros() -> Initializer:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)
    return init


def ones() -> Initializer:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)
    return init


def uniform(scale: float = 1.0) -> Initializer:
    def init(key, shape, dtype):
        return (scale * jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0)).astype(dtype)
    return init


class ParamCtx:
    """Deterministic per-name RNG folding for init functions.

    ``ctx.param("wq", (d, h, hd), fan_in(), (EMBED, HEADS, HEAD_DIM))`` creates a
    Box with an rng derived from ``fold_in(key, hash(name))`` — stable across
    structural refactors as long as names are stable.
    """

    def __init__(self, key: jax.Array, dtype: Any):
        self.key = key
        self.dtype = dtype
        self._names: set[str] = set()

    def _key_for(self, name: str) -> jax.Array:
        if name in self._names:
            raise ValueError(f"duplicate param name {name!r} in one ParamCtx")
        self._names.add(name)
        # Stable 31-bit hash (python hash() is salted per-process).
        h = 2166136261
        for ch in name.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        return jax.random.fold_in(self.key, int(h) & 0x7FFFFFFF)

    def param(self, name: str, shape: Sequence[int], init: Initializer,
              axes: Sequence[Any], dtype: Any = None) -> Box:
        dtype = self.dtype if dtype is None else dtype
        value = init(self._key_for(name), tuple(shape), dtype)
        return Box(value, tuple(axes))

    def sub(self, name: str) -> "ParamCtx":
        return ParamCtx(self._key_for(f"__sub__{name}"), self.dtype)


def count_params(tree: Any) -> int:
    leaves = jax.tree.leaves(unbox(tree))
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def param_bytes(tree: Any) -> int:
    leaves = jax.tree.leaves(unbox(tree))
    return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves))


def abstract_init(init_fn: Callable, *args, **kwargs) -> Any:
    """Run an init function under ``eval_shape`` — returns the boxed tree with
    ShapeDtypeStruct values and logical axes preserved.  No allocation: this is
    how the 340B dry-run builds its parameter specs on a CPU host."""
    return jax.eval_shape(init_fn, *args, **kwargs)
