"""DistilBERT [arXiv:1910.01108] — the paper's own backbone: 6-layer
post-norm MLM encoder, learned positions, GELU, tied MLM head.  This is the
FDAPT/FFDAPT reference model for the parity and efficiency benchmarks."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="distilbert-mlm",
    arch_type="mlm",
    n_layers=6,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30522,
    use_rope=False,
    qkv_bias=True,
    mlp_type="gelu",
    norm_type="layernorm",
    norm_position="post",
    norm_eps=1e-12,
    objective="mlm",
    tie_embeddings=True,
    max_seq_len=4096,
    param_dtype="float32",
    compute_dtype="float32",
    source="arXiv:1910.01108 (paper backbone)",
)
