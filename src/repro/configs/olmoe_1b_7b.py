"""OLMoE-1B-7B [arXiv:2409.02060]: MoE, 64 experts top-8, per-expert
d_ff=1024, MHA-ish GQA 16Q/16KV."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2409.02060",
)
