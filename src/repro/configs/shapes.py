"""Assigned input shapes + allocation-free input specs.

``input_specs(cfg, shape)`` returns *boxed ShapeDtypeStruct* trees for every
model input of the (arch, shape) pair — weak-type-correct, shardable, zero
allocation.  This is what the multi-pod dry-run lowers against.

Decode shapes lower ``serve_step`` (ONE token + a seq_len KV cache); the
long_500k shape substitutes the sliding-window config variant for
full-attention archs (``long_variant``) so the cache is O(window).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import cache_struct
from repro.nn import param as P
from repro.nn.param import Box

LONG_WINDOW = 8192        # sliding-window variant for full-attention archs


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def long_variant(cfg: ModelConfig) -> ModelConfig:
    """Config actually used for long_500k: SSM/hybrid run natively (O(1)
    state); attention archs get the sliding-window variant (beyond-paper
    addition — see DESIGN §4)."""
    if cfg.arch_type in ("ssm", "hybrid"):
        return cfg
    return cfg.with_window(LONG_WINDOW)


def shape_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    return long_variant(cfg) if shape == "long_500k" else cfg


def _tok(shape, axes=(P.BATCH, P.SEQ)):
    return Box(jax.ShapeDtypeStruct(shape, jnp.int32), axes)


def _emb(shape):
    return Box(jax.ShapeDtypeStruct(shape, jnp.bfloat16),
               (P.BATCH, None, P.EMBED))


def batch_specs(cfg: ModelConfig, spec: ShapeSpec, *,
                global_batch: int = 0) -> Dict[str, Any]:
    """Boxed SDS for the data batch of (arch, shape)."""
    B = global_batch or spec.global_batch
    S = 1 if spec.kind == "decode" else spec.seq_len
    batch: Dict[str, Any] = {"tokens": _tok((B, S))}
    if spec.kind == "train":
        batch["targets"] = _tok((B, S))
        batch["loss_mask"] = Box(jax.ShapeDtypeStruct((B, S), jnp.float32),
                                 (P.BATCH, P.SEQ))
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = _emb((B, cfg.n_image_tokens, cfg.d_model))
    if cfg.arch_type == "audio" and spec.kind != "decode":
        batch["frames"] = _emb((B, cfg.n_audio_frames, cfg.d_model))
    return batch


def input_specs(cfg: ModelConfig, shape: str, *, global_batch: int = 0
                ) -> Dict[str, Any]:
    """All boxed-SDS inputs for the step the shape lowers:
    train  -> {"batch": ...}
    prefill-> {"batch": ...}
    decode -> {"batch": ..., "cache": ...} (cache pre-filled to seq_len)."""
    spec = SHAPES[shape]
    cfg = shape_config(cfg, shape)
    B = global_batch or spec.global_batch
    out: Dict[str, Any] = {"batch": batch_specs(cfg, spec, global_batch=B)}
    if spec.kind == "decode":
        out["cache"] = cache_struct(cfg, B, spec.seq_len)
    return out


def applicable(cfg: ModelConfig, shape: str) -> bool:
    """Shape admissibility (every assigned arch admits all 4 shapes here:
    long_500k via the window variant / native SSM; mlm is train-only)."""
    if cfg.arch_type == "mlm":
        return shape == "train_4k"
    return True
