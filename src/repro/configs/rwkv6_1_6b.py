"""RWKV6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, data-dependent
decay WKV recurrence, 32 heads of 64, squared-ReLU channel mix (d_ff=3.5d)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    ssm_heads=32,
    use_rope=False,
    norm_type="layernorm",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2404.05892",
)
