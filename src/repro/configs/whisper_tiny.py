"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder; the mel+conv frontend is
a STUB per the brief — ``input_specs`` supplies (1500, d_model) frame
embeddings.  Learned absolute positions (table sized for prefill_32k;
positions clamp beyond it), LayerNorm+GELU, MHA (6 heads, kv=6)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    n_layers=4,                  # decoder layers
    encoder_layers=4,
    n_audio_frames=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    use_rope=False,
    qkv_bias=True,
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2212.04356",
)
