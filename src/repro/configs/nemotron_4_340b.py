"""Nemotron-4-340B [arXiv:2402.16819]: dense, GQA (96Q/8KV), squared-ReLU
MLP, RoPE, no-bias LayerNorm.  The memory-pressure arch of the pool — bf16
moments + microbatching are required to fit v5e-256 (EXPERIMENTS.md §Perf)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    rope_theta=10_000.0,
    mlp_type="relu2",
    norm_type="layernorm",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2402.16819",
)
