"""Qwen3-14B [hf:Qwen/Qwen3-8B family card]: dense, GQA (40Q/8KV), qk_norm."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:Qwen/Qwen3-8B",
)
