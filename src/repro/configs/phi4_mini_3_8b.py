"""Phi-4-mini-3.8B [arXiv:2412.08905]: dense, RoPE + SwiGLU + GQA 24Q/8KV,
200k vocab, tied embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2412.08905",
)
