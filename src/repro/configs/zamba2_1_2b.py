"""Zamba2-1.2B [arXiv:2411.15242]: hybrid — 38 Mamba2 blocks + one SHARED
full-attention transformer block applied at 6 depths (params shared across
applications, zamba's signature trick).  ssm_state=64, MHA 32 heads."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    conv_dim=4,
    shared_attn_positions=(5, 11, 17, 23, 29, 35),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2411.15242",
)
