"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled]: dense
decoder with gated cross-attention image layers every 5th layer.  The ViT
vision encoder + projector is a STUB per the brief — ``input_specs``
supplies (1601, d_model) patch embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1601,
    rope_theta=500_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
