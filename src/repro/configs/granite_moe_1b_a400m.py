"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]: MoE,
32 experts top-8, per-expert d_ff=512, GQA 16Q/8KV."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
