"""Architecture registry: one module per assigned arch (+ the paper's own
DistilBERT-MLM).  ``get_config("qwen2-7b")`` / ``--arch qwen2-7b``."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "qwen2-7b": "qwen2_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen3-14b": "qwen3_14b",
    "nemotron-4-340b": "nemotron_4_340b",
    "whisper-tiny": "whisper_tiny",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "distilbert-mlm": "distilbert_mlm",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "distilbert-mlm"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
