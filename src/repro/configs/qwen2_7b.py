"""Qwen2-7B [arXiv:2407.10671]: dense, GQA (28Q/4KV), QKV bias, SwiGLU."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2407.10671",
)
