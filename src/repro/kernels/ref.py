"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references: slow, simple, obviously-right
implementations.  The kernel tests sweep shapes/dtypes and assert_allclose
against these; the model code can also run on them directly (``impl="xla"``),
which is what CPU smoke tests and the dry-run use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash attention oracle: plain GQA softmax attention.
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,S,H,D); k/v: (B,T,Kv,D). Additive causal/window mask."""
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    if causal:
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(T)[None, :]
        ok = kj <= qi
        if window:
            ok &= kj > qi - window
        scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# RWKV6 WKV oracle: data-dependent-decay linear attention recurrence.
# ---------------------------------------------------------------------------

def rwkv6_scan(r, k, v, w, u, s0):
    """RWKV6 "Finch" recurrence.

    r,k,v,w: (B,T,H,D);  u: (H,D) bonus;  s0: (B,H,D,D) initial state
    (state layout: [key_dim, value_dim]).

      y_t[j] = sum_i r_t[i] * (S[i,j] + u[i] * k_t[i] * v_t[j])
      S[i,j] <- w_t[i] * S[i,j] + k_t[i] * v_t[j]

    Returns (y (B,T,H,D), s_T (B,H,D,D)).  All math in fp32.
    """
    dtype = r.dtype
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)
    s0 = s0.astype(jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                 # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,D,D)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    rkvw = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))   # (T,B,H,D)
    sT, ys = jax.lax.scan(step, s0, rkvw)
    return jnp.moveaxis(ys, 0, 1).astype(dtype), sT


# ---------------------------------------------------------------------------
# Mamba2 SSD oracle: selective state-space recurrence (scalar A per head).
# ---------------------------------------------------------------------------

def mamba2_scan(x, dt, a_log, b, c, h0):
    """Mamba2 recurrence.

    x:  (B,T,H,P)   per-head inputs
    dt: (B,T,H)     softplus'd step sizes
    a_log: (H,)     A = -exp(a_log)
    b,c: (B,T,N)    input/output projections (single group, broadcast to heads)
    h0: (B,H,P,N)   initial state

      h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * x_t (outer) B_t
      y_t = h_t @ C_t
    Returns (y (B,T,H,P), h_T).  fp32 internally.
    """
    dtype = x.dtype
    x, dt, b, c = (z.astype(jnp.float32) for z in (x, dt, b, c))
    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,)
    h0 = h0.astype(jnp.float32)

    def step(h, xs):
        xt, dtt, bt, ct = xs                  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a)                               # (B,H)
        dbx = (dtt[..., None] * xt)[..., :, None] * bt[:, None, None, :]
        h = decay[..., None, None] * h + dbx                   # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(dtype), hT


def rwkv6_scan_chunked(r, k, v, w, u, s0, *, chunk: int = 16):
    """Chunked WKV6 (flash-linear-attention style block decomposition).

    Same signature/semantics as :func:`rwkv6_scan`.  Within a chunk
    (chunk-local inclusive log-decay ``lw_t = sum_{r<=t} log w_r``, per
    channel):

      y_t = (r_t . e^{lw_{t-1}}) @ S_in                       [carry-in]
          + sum_{s<t} [(r_t e^{lw_{t-1}}) . (k_s e^{-lw_s})] v_s   [intra]
          + (sum_i r_i u_i k_i) v_t                           [bonus diag]
      S_out = e^{lw_Q} (x) S_in + sum_s (k_s e^{lw_Q - lw_s}) v_s^T

    RWKV's decay is PER-CHANNEL, so unlike the scalar-decay SSD the pairwise
    ratio cannot be safely factorized as e^{lw_t} * e^{-lw_s} (channels with
    strong decay saturate both factors — double-clamp corruption).  The
    intra-chunk term therefore uses the DIRECT exponent e^{lw_{t-1} - lw_s}
    on a chunk-local (B,Q,S,H,D) tensor: the argument is always <= 0, so a
    single clamp at -40 only zeroes negligible contributions.  Chunk-local
    tensors cost Q*D per token instead of the naive D^2 state round-trip —
    a ~D/Q HBM reduction; the Pallas kernel (VMEM-resident state) removes
    the rest on real TPU.  State hand-off stays factorized (exponents <= 0).
    """
    dtype = r.dtype
    B, T, H, D = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(x_, zp) for x_ in (r, k, v))
        w = jnp.pad(w, zp, constant_values=1.0)        # decay 1 = no-op
    NC = (T + pad) // chunk

    def cc(x_):
        return x_.reshape(B, NC, chunk, H, D).astype(jnp.float32)

    rc, kc, vc, wc = cc(r), cc(k), cc(v), cc(w)
    u32 = u.astype(jnp.float32)
    tri_strict = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def per_chunk(s, xs):
        rq, kq, vq, wq = xs                            # (B,Q,H,D)
        lw = jnp.cumsum(jnp.log(jnp.maximum(wq, 1e-38)), axis=1)   # <= 0
        lw_prev = jnp.concatenate(
            [jnp.zeros_like(lw[:, :1]), lw[:, :-1]], axis=1)       # lw_{t-1}
        r_dec = rq * jnp.exp(jnp.maximum(lw_prev, -40.0))
        # carry-in
        y_in = jnp.einsum("bqhi,bhij->bqhj", r_dec, s)
        # intra-chunk: direct pairwise decay ratio (always <= 0 pre-clamp)
        ldiff = lw_prev[:, :, None] - lw[:, None, :, :, :]         # (B,Q,S,H,D)
        dec = jnp.exp(jnp.clip(ldiff, -40.0, 0.0))
        scores = jnp.einsum("bqhi,bqshi,bshi->bqsh", rq, dec, kq) * \
            tri_strict[None, :, :, None]
        y_intra = jnp.einsum("bqsh,bshj->bqhj", scores, vq)
        ruk = jnp.sum(rq * u32[None, None] * kq, axis=-1)          # (B,Q,H)
        y = y_in + y_intra + ruk[..., None] * vq
        # state hand-off (exponents <= 0: safe factorized form)
        lwQ = lw[:, -1:]                                           # (B,1,H,D)
        k_dec = kq * jnp.exp(jnp.maximum(lwQ - lw, -40.0))
        s = (jnp.exp(jnp.maximum(lwQ[:, 0], -40.0))[..., None] * s
             + jnp.einsum("bshi,bshj->bhij", k_dec, vq))
        return s, y

    xs = tuple(jnp.moveaxis(x_, 1, 0) for x_ in (rc, kc, vc, wc))
    sT, ys = jax.lax.scan(per_chunk, s0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T + pad, H, D)[:, :T]
    return y.astype(dtype), sT


def mamba2_scan_chunked(x, dt, a_log, b, c, h0, *, chunk: int = 128):
    """Chunked SSD formulation of the Mamba2 recurrence (same signature and
    semantics as :func:`mamba2_scan`).

    The naive form reads/writes the (B,H,P,N) state from HBM every timestep —
    at train_4k that is the single worst memory-roofline term in the zoo
    (zamba2: 5,147 s/step).  The SSD block decomposition (Dao & Gu, 2024)
    turns it into per-chunk MATMULS with one state hand-off per chunk:

      within a chunk (inclusive log-decay  la_t = sum_{r<=t} dt_r*A):
        y_t = e^{la_t} (C_t . h_in)
              + sum_{s<=t} e^{la_t - la_s} dt_s (C_t . B_s) x_s
        h_out = e^{la_Q} h_in + sum_s e^{la_Q - la_s} dt_s  x_s (x) B_s

    Numerically stable: A < 0 so every exponent is <= 0.  HBM traffic drops
    by ~chunk; the pairwise terms are MXU-shaped (Q x Q) matmuls.
    """
    dtype = x.dtype
    Bn, T, H, Pd = x.shape
    N = b.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))     # dt=0: no-op steps
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    NC = (T + pad) // chunk

    xc = x.reshape(Bn, NC, chunk, H, Pd).astype(jnp.float32)
    dtc = dt.reshape(Bn, NC, chunk, H).astype(jnp.float32)
    bc = b.reshape(Bn, NC, chunk, N).astype(jnp.float32)
    cc = c.reshape(Bn, NC, chunk, N).astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))              # (H,) < 0

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))          # s <= t

    def per_chunk(h, xs):
        xq, dtq, bq, cq = xs                 # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        la = jnp.cumsum(dtq * a[None, None, :], axis=1)            # (B,Q,H) <= 0
        # inter-chunk: carry-in state read out at every position
        y_inter = jnp.exp(la)[..., None] * jnp.einsum("bqn,bhpn->bqhp", cq, h)
        # intra-chunk: pairwise decay-weighted (C_t . B_s) attention.
        g = jnp.einsum("bqn,bsn->bqs", cq, bq)                     # (B,Q,Q)
        # decay(t,s) = exp(la_t - la_s) on the DIRECT pairwise difference:
        # the kept (t >= s) exponents are always <= 0, so a single exp in
        # fp32 is exact.  A factorized exp(la_t) * exp(-la_s) form loses the
        # entire mantissa once |la| grows past ~40 inside a chunk (long
        # chunks x strong decay), which is a 1e1-scale output error — the
        # (B,Q,S,H) fp32 buffer is the price of a correct oracle; the Pallas
        # kernel keeps its state in VMEM and never materializes it.
        ldiff = la[:, :, None, :] - la[:, None, :, :]              # (B,Q,S,H)
        dec = jnp.exp(jnp.minimum(ldiff, 0.0))                     # t<s masked next
        m = (g * tri[None])[..., None] * dec * dtq[:, None, :, :]  # (B,Q,S,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", m, xq)
        # state hand-off: same direct-difference rule as the y path (and as
        # the sequential reference's step-by-step products)
        laQ = la[:, -1:, :]                                        # (B,1,H)
        wgt = jnp.exp(laQ - la) * dtq                              # (B,Q,H)
        h = (jnp.exp(laQ)[:, 0, :, None, None] * h
             + jnp.einsum("bsh,bshp,bsn->bhpn", wgt, xq, bq))
        return h, y_inter + y_intra

    h0 = h0.astype(jnp.float32)
    xs = tuple(jnp.moveaxis(z, 1, 0) for z in (xc, dtc, bc, cc))
    hT, ys = jax.lax.scan(per_chunk, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bn, T + pad, H, Pd)[:, :T]
    return y.astype(dtype), hT


# ---------------------------------------------------------------------------
# MoE grouped-matmul oracle: per-expert SwiGLU FFN on capacity buffers.
# ---------------------------------------------------------------------------

def moe_ffn(xe, wi_gate, wi_up, wo):
    """xe: (E,C,d); wi_*: (E,d,f); wo: (E,f,d) -> (E,C,d)."""
    g = jnp.einsum("ecd,edf->ecf", xe, wi_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, wi_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, wo)
