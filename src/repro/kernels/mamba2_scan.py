"""Mamba2 SSD recurrence as a Pallas TPU kernel.

Grid: (batch, time-chunks); the (H, P, N) fp32 state is VMEM scratch carried
across sequential time-chunk steps.  All heads of one batch element are
updated together so the per-step einsums have an MXU-friendly (H*P, N)
shape.  Like the RWKV kernel this is a memory-bound streaming kernel: one
HBM read of x/dt/B/C and one write of y per token.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref, h_scr,
            *, chunk, H, Pd, N, nt):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (chunk, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, H)
    a = -jnp.exp(a_ref[...].astype(jnp.float32))   # (H,)
    b = b_ref[0].astype(jnp.float32)          # (chunk, N)
    c = c_ref[0].astype(jnp.float32)          # (chunk, N)

    def body(i, h):
        decay = jnp.exp(dt[i] * a)                          # (H,)
        dbx = (dt[i][:, None] * x[i])[..., None] * b[i][None, None, :]
        h = decay[:, None, None] * h + dbx                  # (H,P,N)
        y = jax.lax.dot_general(h.reshape(H * Pd, N), c[i][:, None],
                                (((1,), (0,)), ((), ())))   # (H*P, 1)
        y_ref[0, i] = y.reshape(H, Pd).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, body, h_scr[...])

    @pl.when(t == nt - 1)
    def _fin():
        hT_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan(x, dt, a_log, b, c, h0, *, chunk=128, interpret=True):
    """See ref.mamba2_scan: x (B,T,H,P), dt (B,T,H), a_log (H,), b/c (B,T,N),
    h0 (B,H,P,N) -> (y (B,T,H,P), hT)."""
    B, T, H, Pd = x.shape
    N = b.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> decay=1, no input
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nt = Tp // chunk

    y, hT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, H=H, Pd=Pd, N=N, nt=nt),
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, H, Pd), lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda i, t: (i, t, 0)),
            pl.BlockSpec((H,), lambda i, t: (0,)),
            pl.BlockSpec((1, chunk, N), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, H, Pd, N), lambda i, t: (i, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, Pd), lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((1, H, Pd, N), lambda i, t: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, H, Pd), x.dtype),
            jax.ShapeDtypeStruct((B, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, Pd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c, h0)

    return y[:, :T], hT
