"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The recurrence has no attention analogue: a per-head (D,D) state matrix with
*data-dependent per-channel decay* ``w_t``.  TPU adaptation: the state lives
in fp32 VMEM scratch and is carried across sequential grid steps along the
time-chunk axis; the grid's leading axis is (batch x heads), which is the
embarrassingly-parallel dim.  Inside a chunk the time loop is a
``lax.fori_loop`` over VMEM-resident (chunk, D) tiles — HBM traffic is one
read of r/k/v/w and one write of y per token, i.e. the kernel is
memory-bound by design (arithmetic intensity ~ D ops/byte).

The y_t contraction uses the algebraic split
    y_t = r_t @ S + (sum_i r_i u_i k_i) * v_t
which avoids materializing the (D,D) bonus outer product per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr,
            *, chunk, D, nt):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (chunk, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (D,)
    ruk = jnp.sum(r * u[None, :] * k, axis=-1)  # (chunk,)

    def body(i, s):
        rt, kt, vt, wt = r[i], k[i], v[i], w[i]
        y = rt @ s + ruk[i] * vt                              # (D,)
        y_ref[0, i] = y.astype(y_ref.dtype)
        return wt[:, None] * s + kt[:, None] * vt[None, :]

    s_scr[...] = jax.lax.fori_loop(0, chunk, body, s_scr[...])

    @pl.when(t == nt - 1)
    def _fin():
        sT_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0, *, chunk=128, interpret=True):
    """r,k,v,w: (B,T,H,D); u: (H,D); s0: (B,H,D,D) -> (y, sT). See ref.rwkv6_scan."""
    B, T, H, D = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    BH = B * H

    def fold(x):  # (B,T,H,D) -> (BH, Tp, D)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(BH, T + pad, D)

    rf, kf, vf = fold(r), fold(k), fold(v)
    # padded decay=1, k=0: state passes through unchanged on padding steps.
    wf = fold(w)
    if pad:
        tmask = (jnp.arange(T + pad) < T)[None, :, None]
        wf = jnp.where(tmask, wf, 1.0)
    uf = jnp.broadcast_to(u[None], (B, H, D)).reshape(BH, D)
    s0f = s0.reshape(BH, D, D)
    nt = (T + pad) // chunk

    y, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, D=D, nt=nt),
        grid=(BH, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, D), lambda i, t: (i, 0)),
            pl.BlockSpec((1, D, D), lambda i, t: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, D, D), lambda i, t: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T + pad, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0f)

    y = y[:, :T].reshape(B, H, T, D).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, D, D)
