"""Reference backward passes pairing the Pallas forward kernels.

``pallas_call`` has no autodiff rule in this jax version (interpret mode
included), so ``repro.kernels.ops`` wires each kernel into a
``jax.custom_vjp`` whose forward is the Pallas kernel and whose backward
is one of the functions here.  Two styles, chosen per kernel:

  * **Hand-derived backwards** (``attention_bwd``, ``moe_ffn_bwd``) — the
    classic recompute-from-inputs formulations a TPU backward kernel would
    implement (flash-style softmax recompute; SwiGLU chain rule).  They
    are written independently of the oracle's autodiff, so comparing
    ``jax.grad`` of the Pallas op against ``jax.grad`` of the oracle is a
    real differential test of the gradient math, not a tautology.
  * **Chunked-formulation VJPs** (``rwkv6_bwd``, ``mamba2_bwd``) — jax
    autodiff of the *chunked* reference (``ref.rwkv6_scan_chunked`` /
    ``ref.mamba2_scan_chunked``).  The chunked and sequential forms
    regroup the decay products completely differently (the PR 2 mantissa
    fix lives exactly there), so grad-vs-sequential-oracle is again a
    differential test — and the backward inherits the chunked form's
    HBM-traffic advantage when it runs compiled.

All math in fp32; gradients are cast back to the primal input dtypes
(what ``custom_vjp`` requires).  Tolerances for the resulting
kernel-vs-oracle gradient comparisons live in
``repro.conformance.tolerances`` (the ``vjp`` rungs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref

NEG_INF = ref.NEG_INF


def _like(grad, primal):
    return grad.astype(primal.dtype)


# ---------------------------------------------------------------------------
# Flash attention backward: softmax recompute (GQA / causal / window /
# softcap), mirroring ref.attention's exact masking semantics.
# ---------------------------------------------------------------------------

def attention_bwd(q, k, v, dy, *, causal=True, window=0, softcap=0.0):
    """dy: (B,S,H,D) cotangent of the attention output -> (dq, dk, dv)."""
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    inv = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qg = q.reshape(B, S, Kv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dyg = dy.reshape(B, S, Kv, G, D).astype(jnp.float32)

    s0 = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * inv
    s = softcap * jnp.tanh(s0 / softcap) if softcap else s0
    if causal:
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(T)[None, :]
        ok = kj <= qi
        if window:
            ok &= kj > qi - window
        masked = jnp.where(ok[None, None, None], s, NEG_INF)
    else:
        masked = s
    w = jax.nn.softmax(masked, axis=-1)

    # dv and the softmax backward
    dv = jnp.einsum("bkgst,bskgd->btkd", w, dyg)
    dw = jnp.einsum("bskgd,btkd->bkgst", dyg, vf)
    ds = w * (dw - jnp.sum(w * dw, axis=-1, keepdims=True))
    if causal:
        ds = jnp.where(ok[None, None, None], ds, 0.0)
    if softcap:
        ds = ds * (1.0 - jnp.square(jnp.tanh(s0 / softcap)))

    dq = jnp.einsum("bkgst,btkd->bskgd", ds, kf) * inv
    dk = jnp.einsum("bkgst,bskgd->btkd", ds, qg) * inv
    return (_like(dq.reshape(B, S, H, D), q), _like(dk, k), _like(dv, v))


# ---------------------------------------------------------------------------
# MoE SwiGLU FFN backward: per-expert chain rule over the fused
# silu(x Wg) * (x Wu) @ Wo, recomputed from inputs.
# ---------------------------------------------------------------------------

def moe_ffn_bwd(xe, wi_gate, wi_up, wo, dy):
    """dy: (E,C,d) cotangent -> (dx, dwi_gate, dwi_up, dwo)."""
    x = xe.astype(jnp.float32)
    wg = wi_gate.astype(jnp.float32)
    wu = wi_up.astype(jnp.float32)
    wof = wo.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)

    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wu)
    sg = jax.nn.sigmoid(g)
    silu = g * sg
    h = silu * u

    dh = jnp.einsum("ecd,efd->ecf", dyf, wof)
    dwo = jnp.einsum("ecf,ecd->efd", h, dyf)
    du = dh * silu
    dg = dh * u * (sg * (1.0 + g * (1.0 - sg)))    # d silu(g)/dg

    dx = (jnp.einsum("ecf,edf->ecd", dg, wg)
          + jnp.einsum("ecf,edf->ecd", du, wu))
    dwg = jnp.einsum("ecd,ecf->edf", x, dg)
    dwu = jnp.einsum("ecd,ecf->edf", x, du)
    return (_like(dx, xe), _like(dwg, wi_gate), _like(dwu, wi_up),
            _like(dwo, wo))


# ---------------------------------------------------------------------------
# Recurrent scans: VJP of the chunked reference formulation.
# ---------------------------------------------------------------------------

def rwkv6_bwd(r, k, v, w, u, s0, cts, *, chunk):
    """cts = (dy, ds_T) cotangents of (y, s_T) -> grads for all six
    inputs, via autodiff of the chunked WKV6 form."""
    _, pull = jax.vjp(
        lambda r_, k_, v_, w_, u_, s_: ref.rwkv6_scan_chunked(
            r_, k_, v_, w_, u_, s_, chunk=chunk), r, k, v, w, u, s0)
    return pull(cts)


def mamba2_bwd(x, dt, a_log, b, c, h0, cts, *, chunk):
    """cts = (dy, dh_T) cotangents of (y, h_T) -> grads for all six
    inputs, via autodiff of the chunked SSD form (direct pairwise decay —
    the |la|>40-safe formulation)."""
    _, pull = jax.vjp(
        lambda x_, dt_, a_, b_, c_, h_: ref.mamba2_scan_chunked(
            x_, dt_, a_, b_, c_, h_, chunk=chunk), x, dt, a_log, b, c, h0)
    return pull(cts)
