"""MoE grouped-matmul Pallas TPU kernel: fused per-expert SwiGLU FFN.

Computes, for every expert e over its (C, d) capacity buffer:
    y_e = (silu(x_e @ Wg_e) * (x_e @ Wu_e)) @ Wo_e
as ONE kernel, so the (C, f) hidden activations never round-trip to HBM —
the fusion that makes expert-parallel MoE on TPU bandwidth-sane.

Grid: (experts, capacity-blocks, ffn-blocks); the ffn-block axis is innermost
(sequential), accumulating partial y in fp32 VMEM scratch.  Tiles: x (bc, d),
Wg/Wu (d, bf), Wo (bf, d) — with bc=bf=128 and d a multiple of 128 every
matmul hits the MXU at full shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wo_ref, y_ref, acc_ref, *, nf):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (bc, d)
    wg = wg_ref[0].astype(jnp.float32)        # (d, bf)
    wu = wu_ref[0].astype(jnp.float32)
    g = jax.lax.dot(x, wg)
    u = jax.lax.dot(x, wu)
    h = (g * jax.lax.logistic(g)) * u         # silu(g) * u
    acc_ref[...] += jax.lax.dot(h, wo_ref[0].astype(jnp.float32))

    @pl.when(f == nf - 1)
    def _out():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_ffn(xe, wi_gate, wi_up, wo, *, block_c=128, block_f=128,
            interpret=True):
    """xe: (E,C,d); wi_gate/wi_up: (E,d,f); wo: (E,f,d) -> (E,C,d)."""
    E, C, d = xe.shape
    f = wi_gate.shape[-1]
    bc = min(block_c, max(C, 8))
    bf = min(block_f, max(f, 8))
    pc, pf = (-C) % bc, (-f) % bf
    if pc:
        xe = jnp.pad(xe, ((0, 0), (0, pc), (0, 0)))
    if pf:
        wi_gate = jnp.pad(wi_gate, ((0, 0), (0, 0), (0, pf)))
        wi_up = jnp.pad(wi_up, ((0, 0), (0, 0), (0, pf)))
        wo = jnp.pad(wo, ((0, 0), (0, pf), (0, 0)))
    Cp, fp = C + pc, f + pf
    nc, nf = Cp // bc, fp // bf

    y = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c, f_: (e, c, 0)),
            pl.BlockSpec((1, d, bf), lambda e, c, f_: (e, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e, c, f_: (e, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e, c, f_: (e, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, f_: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(xe, wi_gate, wi_up, wo)

    return y[:, :C]
