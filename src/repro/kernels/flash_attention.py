"""Flash attention Pallas TPU kernel (GQA-aware, causal / sliding-window).

Layout: the wrapper folds (batch, kv_head) into the grid's first axis and
keeps the GQA group dim attached to the query block, so K/V are *not*
repeated in HBM (a Kv-head's K/V tile is loaded once and shared by its G
query heads — the point of GQA on a bandwidth-bound decode/prefill).

Tiling: q blocks (bq, G, D) x kv blocks (bk, D) with the classic online-
softmax accumulation in fp32 VMEM scratch; the kv-block grid axis is
innermost, i.e. sequential on TPU, which is what makes the scratch carry
legal.  Matmul shapes are (bq*G, D) @ (D, bk) — with bq=128, G>=1, D in
{64,128} both MXU dims are 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq, bk, G, D, causal, window, softcap, t_real, nk, scale):
    j = pl.program_id(1)          # q block
    kk = pl.program_id(2)         # kv block (sequential)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32).reshape(bq * G, D) * scale
    k = k_ref[0].astype(jnp.float32)                       # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq*G, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    rows = jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 1)
    pos_q = j * bq + rows // G
    pos_k = kk * bk + cols
    ok = pos_k < t_real                                    # mask kv padding
    if causal:
        ok &= pos_k <= pos_q
        if window:
            ok &= pos_k > pos_q - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)

    @pl.when(kk == nk - 1)
    def _out():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully-masked rows
        out = (acc_ref[...] / l[:, None]).reshape(bq, G, D)
        o_ref[0] = out.astype(o_ref.dtype)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=True):
    """q: (B,S,H,D); k,v: (B,T,Kv,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    bq, bk = min(block_q, max(S, 8)), min(block_k, max(T, 8))

    # fold kv-head into the leading grid axis; q rows ordered (seq, group).
    qf = q.reshape(B, S, Kv, G, D).transpose(0, 2, 1, 3, 4).reshape(B * Kv, S, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, T, D)
    qf = _pad_to(qf, bq, 1)
    kf = _pad_to(kf, bk, 1)
    vf = _pad_to(vf, bk, 1)
    Sp, Tp = qf.shape[1], kf.shape[1]
    nq, nk = Sp // bq, Tp // bk

    kern = functools.partial(
        _kernel, bq=bq, bk=bk, G=G, D=D, causal=causal, window=window,
        softcap=softcap, t_real=T, nk=nk, scale=1.0 / (D ** 0.5))

    out = pl.pallas_call(
        kern,
        grid=(B * Kv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, G, D), lambda i, j, kk: (i, j, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, bk, D), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, D), lambda i, j, kk: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv, Sp, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, D), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out[:, :S].reshape(B, Kv, S, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S, H, D)
