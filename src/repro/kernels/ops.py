"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes step-by-step in Python, exercising exactly the same BlockSpec
tiling/indexing that would run on TPU.  On a TPU backend the same call sites
compile to Mosaic.  ``impl="xla"`` callers bypass kernels entirely and use
:mod:`repro.kernels.ref` (that is what the dry-run lowers, keeping the
roofline numbers kernel-agnostic).
"""

from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gmm import moe_ffn as _moe_ffn
from repro.kernels.mamba2_scan import mamba2_scan as _mamba2
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, s0, *, chunk=128):
    return _rwkv6(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())


def mamba2_scan(x, dt, a_log, b, c, h0, *, chunk=128):
    return _mamba2(x, dt, a_log, b, c, h0, chunk=chunk, interpret=_interpret())


def moe_ffn(xe, wi_gate, wi_up, wo, *, block_c=128, block_f=128):
    return _moe_ffn(xe, wi_gate, wi_up, wo, block_c=block_c, block_f=block_f,
                    interpret=_interpret())


# re-exported oracles (impl="xla" path)
ref = _ref
