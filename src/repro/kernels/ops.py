"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes step-by-step in Python, exercising exactly the same BlockSpec
tiling/indexing that would run on TPU.  On a TPU backend the same call sites
compile to Mosaic.  ``impl="xla"`` callers bypass kernels entirely and use
:mod:`repro.kernels.ref` (that is what the dry-run lowers, keeping the
roofline numbers kernel-agnostic).

Every wrapper is a ``jax.custom_vjp``: ``pallas_call`` has no autodiff rule
here, so the forward runs the Pallas kernel and the backward runs the
paired reference backward from :mod:`repro.kernels.vjp` (hand-derived
recompute for attention/MoE, chunked-formulation VJP for the scans).  That
makes ``jax.grad`` flow through ``impl="pallas"``/``impl="chunked"`` call
sites, and it is what the conformance harness's gradient differential
tests (``repro.conformance``) exercise against the sequential oracles.
"""

from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels import vjp as _vjp
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gmm import moe_ffn as _moe_ffn
from repro.kernels.mamba2_scan import mamba2_scan as _mamba2
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128):
    @jax.custom_vjp
    def fa(q, k, v):
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      block_q=block_q, block_k=block_k,
                      interpret=_interpret())

    def fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def bwd(res, dy):
        return _vjp.attention_bwd(*res, dy, causal=causal, window=window,
                                  softcap=softcap)

    fa.defvjp(fwd, bwd)
    return fa(q, k, v)


def rwkv6_scan(r, k, v, w, u, s0, *, chunk=128):
    @jax.custom_vjp
    def wkv(r, k, v, w, u, s0):
        return _rwkv6(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())

    def fwd(r, k, v, w, u, s0):
        return wkv(r, k, v, w, u, s0), (r, k, v, w, u, s0)

    def bwd(res, cts):
        return _vjp.rwkv6_bwd(*res, cts, chunk=chunk)

    wkv.defvjp(fwd, bwd)
    return wkv(r, k, v, w, u, s0)


def mamba2_scan(x, dt, a_log, b, c, h0, *, chunk=128):
    @jax.custom_vjp
    def ssd(x, dt, a_log, b, c, h0):
        return _mamba2(x, dt, a_log, b, c, h0, chunk=chunk,
                       interpret=_interpret())

    def fwd(x, dt, a_log, b, c, h0):
        return ssd(x, dt, a_log, b, c, h0), (x, dt, a_log, b, c, h0)

    def bwd(res, cts):
        return _vjp.mamba2_bwd(*res, cts, chunk=chunk)

    ssd.defvjp(fwd, bwd)
    return ssd(x, dt, a_log, b, c, h0)


def moe_ffn(xe, wi_gate, wi_up, wo, *, block_c=128, block_f=128):
    @jax.custom_vjp
    def gmm(xe, wi_gate, wi_up, wo):
        return _moe_ffn(xe, wi_gate, wi_up, wo, block_c=block_c,
                        block_f=block_f, interpret=_interpret())

    def fwd(xe, wi_gate, wi_up, wo):
        return gmm(xe, wi_gate, wi_up, wo), (xe, wi_gate, wi_up, wo)

    def bwd(res, dy):
        return _vjp.moe_ffn_bwd(*res, dy)

    gmm.defvjp(fwd, bwd)
    return gmm(xe, wi_gate, wi_up, wo)


# re-exported oracles (impl="xla" path)
ref = _ref
