"""Non-IID partitioners — Appendix C / Eqs. 8-10.

Each partitioner maximizes ONE statistic's cross-client standard deviation
while pinning the others (the paper's "maximise a single metric discrepancy
... keeping other metrics almost the same"):

  * ``iid``        — shuffled equal split (all sigmas ~ 0).
  * ``quantity``   — Eq. 8: client i gets i / sum(j) of the documents;
                     assignment is random, so length/vocab stay flat.
  * ``length``     — Eq. 9: equal counts; documents sorted by mean sentence
                     length and split contiguously -> max sigma(L).
  * ``vocab``      — Eq. 10: equal counts; documents sorted by their lexicon
                     offset (vocabulary-pool position) and split contiguously
                     -> client union-vocabulary sizes diverge while lengths
                     stay flat (pool windows are length-independent).
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.data.corpus import Document, corpus_stats

SKEWS = ("iid", "quantity", "length", "vocab")


def quantity_split_sizes(n_docs: int, k: int) -> List[int]:
    """Eq. 8: Q_i = i / sum_j(j) * Q — client i+1's DOCUMENT count out of
    ``n_docs`` (largest-remainder rounding; conserves the total).  The
    resulting per-client step counts are what the async simulator replays
    as the quantity-skew schedule.

    >>> quantity_split_sizes(100, 4)
    [10, 20, 30, 40]
    >>> sum(quantity_split_sizes(101, 4))
    101
    """
    denom = k * (k + 1) // 2
    raw = [(i + 1) / denom * n_docs for i in range(k)]
    sizes = [int(x) for x in raw]
    rem = n_docs - sum(sizes)
    fracs = sorted(range(k), key=lambda i: raw[i] - sizes[i], reverse=True)
    for i in fracs[:rem]:
        sizes[i] += 1
    return sizes


def _doc_vocab_key(d: Document) -> float:
    """Surrogate for the doc's lexicon-window position: lexicographically
    smallest word — contiguous-sorted split clusters shared pools."""
    return min(min(s) for s in d.sentences)


def partition(docs: Sequence[Document], k: int, skew: str = "iid",
              *, seed: int = 0) -> List[List[Document]]:
    """Partition docs into k client shards per the requested skew."""
    rng = np.random.default_rng(seed)
    docs = list(docs)
    n = len(docs)
    order = rng.permutation(n)

    if skew == "iid":
        shards = [[] for _ in range(k)]
        for pos, di in enumerate(order):
            shards[pos % k].append(docs[di])
        return shards

    if skew == "quantity":
        sizes = quantity_split_sizes(n, k)
        shards, at = [], 0
        for s in sizes:
            shards.append([docs[i] for i in order[at:at + s]])
            at += s
        return shards

    if skew == "length":
        idx = sorted(range(n), key=lambda i: docs[i].mean_sentence_length)
        per = n // k
        shards = [[docs[i] for i in idx[c * per:(c + 1) * per]] for c in range(k)]
        for j, i in enumerate(idx[k * per:]):    # spread the remainder
            shards[j % k].append(docs[i])
        return shards

    if skew == "vocab":
        # maximize sigma of per-client vocabulary-union size at equal counts:
        # "narrow" clients take contiguous runs of vocab-sorted docs (shared
        # pools -> small union); "wide" clients stride across the remainder
        # (disjoint pools -> large union).  Length stays pinned because the
        # vocab key is independent of sentence length.
        idx = sorted(range(n), key=lambda i: _doc_vocab_key(docs[i]))
        per = n // k
        n_narrow = (k + 1) // 2
        shards: List[List[Document]] = []
        at = 0
        for _ in range(n_narrow):
            shards.append([docs[i] for i in idx[at:at + per]])
            at += per
        rest = idx[at:]
        n_wide = k - n_narrow
        for c in range(n_wide):
            shards.append([docs[rest[j]] for j in range(c, n_wide * per, n_wide)])
        for j, i in enumerate(rest[n_wide * per:]):
            shards[j % k].append(docs[i])
        return shards

    raise ValueError(f"unknown skew {skew!r}; have {SKEWS}")


class ClientPool:
    """Virtual population of ``n_clients`` federated clients backed by a
    small pool of real data shards — the lazy client-data provider the
    round engines consume (``batches_for`` / ``sizes`` / ``max_steps`` /
    ``__len__``).

    Cross-device populations are sampled, not enumerated: a 100k–1M-client
    round touches only its cohort, so materializing every client's batches
    up front is both impossible (memory) and pointless.  Virtual client
    ``k`` serves pool shard ``k % P``; a pool shard's batches build on
    FIRST access (``builders[i]`` is a zero-arg callable) and are cached,
    so a run materializes at most ``P`` datasets no matter how many
    clients exist or participate.

    >>> pool = ClientPool(6, [lambda: ["a", "b"], lambda: ["c"]], sizes=[2, 1])
    >>> len(pool), pool.batches_for(3)
    (6, ['c'])
    >>> pool.sizes
    [2, 1, 2, 1, 2, 1]
    >>> pool.materialized        # only shard 1 was ever built
    [1]
    """

    def __init__(self, n_clients: int, builders: Sequence, sizes: Sequence[int],
                 *, limit: int = 0):
        if len(builders) != len(sizes):
            raise ValueError(f"{len(builders)} builders vs {len(sizes)} sizes")
        if n_clients < 1 or not builders:
            raise ValueError("need n_clients >= 1 and a non-empty pool")
        self._n = int(n_clients)
        self._builders = list(builders)
        self._pool_sizes = [int(s) for s in sizes]
        self._limit = int(limit)              # >0: cap local steps per epoch
        self._cache: dict = {}

    def __len__(self) -> int:
        return self._n

    @property
    def sizes(self) -> List[int]:
        """Virtual n_k aggregation weights: the pool sizes, cycled."""
        p = len(self._builders)
        return [self._pool_sizes[k % p] for k in range(self._n)]

    @property
    def max_steps(self) -> int:
        """Longest local epoch across the pool (materializes the pool — at
        most P builds, cached; never per virtual client)."""
        return max(len(self._shard(i)) for i in range(len(self._builders)))

    @property
    def materialized(self) -> List[int]:
        """Pool shard indices built so far (laziness observability)."""
        return sorted(self._cache)

    def _shard(self, i: int):
        if i not in self._cache:
            built = self._builders[i]()
            self._cache[i] = built[:self._limit] if self._limit else built
        return self._cache[i]

    def batches_for(self, k: int):
        return self._shard(k % len(self._builders))


def client_stats_table(shards: Sequence[Sequence[Document]]) -> dict:
    """Table-3 analogue: mean and sigma of (quantity, sentence length,
    union vocabulary, per-doc vocabulary) across clients.  The per-doc
    metric is quantity-invariant (the paper's near-zero vocab sigma under
    quantity skew); the union metric is what Eq. 10 maximizes."""
    per = [corpus_stats(s) for s in shards]
    for p, s in zip(per, shards):
        p["doc_vocab"] = float(np.mean([len(d.unique_words) for d in s])) \
            if s else 0.0
    out = {}
    for key in ("quantity", "mean_sentence_length", "unique_words", "doc_vocab"):
        vals = np.asarray([p[key] for p in per], np.float64)
        out[key] = {"mean": float(vals.mean()), "sigma": float(vals.std()),
                    "per_client": vals.tolist()}
    return out
