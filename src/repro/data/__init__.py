from repro.data.tokenizer import HashWordTokenizer  # noqa: F401
from repro.data.corpus import Document, generate_corpus  # noqa: F401
from repro.data.partition import partition  # noqa: F401
