"""Synthetic biomedical-style corpus with controllable text statistics.

PubMed and the 9 downstream sets are unavailable offline (repro band 2/5);
what the paper's non-IID study actually needs from the data is *controllable
per-document sentence-length and vocabulary statistics* so the three skews of
Appendix C are constructible and measurable.  Documents are generated from a
Zipf-weighted synthetic lexicon; each document draws its own mean sentence
length and its own vocabulary *pool window* — the spread across documents is
what the max-sigma partitioners exploit.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

_PREFIXES = ("cardio", "neuro", "hepato", "immuno", "cyto", "gen", "path",
             "onco", "derm", "hemo", "pharma", "bio", "micro", "endo", "osteo")
_STEMS = ("vascul", "toxic", "genomic", "receptor", "protein", "kinase",
          "lesion", "therap", "clinic", "syndrom", "inhibit", "antigen",
          "enzym", "mutat", "metabol")
_SUFFIXES = ("ar", "ity", "osis", "emia", "itis", "ase", "oma", "ine", "al",
             "ic", "ogen", "opathy")


def build_lexicon(size: int) -> List[str]:
    words = []
    i = 0
    for p in _PREFIXES:
        for s in _STEMS:
            for x in _SUFFIXES:
                words.append(p + s + x)
                i += 1
                if i >= size:
                    return words
    # extend synthetically if size > combinatorial pool
    while len(words) < size:
        words.append(f"term{len(words):06d}")
    return words


@dataclasses.dataclass
class Document:
    sentences: List[List[str]]

    @property
    def n_sentences(self) -> int:
        return len(self.sentences)

    @property
    def mean_sentence_length(self) -> float:
        return float(np.mean([len(s) for s in self.sentences]))

    @property
    def unique_words(self) -> set:
        return {w for s in self.sentences for w in s}

    @property
    def n_words(self) -> int:
        return sum(len(s) for s in self.sentences)


def generate_corpus(n_docs: int, *, seed: int = 0, lexicon_size: int = 12_000,
                    sentences_per_doc: int = 12,
                    sent_len_lo: float = 12.0, sent_len_hi: float = 56.0,
                    pool_lo: int = 120, pool_hi: int = 2_400
                    ) -> List[Document]:
    """Each doc draws mean-sentence-length U[lo,hi] and a vocabulary pool
    window of size U[pool_lo,pool_hi] at a random offset into the lexicon —
    so doc-level length/vocab stats vary widely (the skews need spread)."""
    rng = np.random.default_rng(seed)
    lex = np.asarray(build_lexicon(lexicon_size))
    docs: List[Document] = []
    for _ in range(n_docs):
        mean_len = rng.uniform(sent_len_lo, sent_len_hi)
        pool_n = int(rng.integers(pool_lo, pool_hi))
        off = int(rng.integers(0, max(1, lexicon_size - pool_n)))
        pool = lex[off:off + pool_n]
        # zipfian start + local random-walk continuation: adjacent words are
        # correlated, so masked-LM prediction from context is actually
        # learnable (i.i.d. draws would leave only the unigram prior)
        ranks = np.arange(1, pool_n + 1)
        pz = (1.0 / ranks) / np.sum(1.0 / ranks)
        sents = []
        for _ in range(sentences_per_doc):
            L = max(3, int(rng.normal(mean_len, mean_len * 0.15)))
            i = int(rng.choice(pool_n, p=pz))
            idx = []
            for _ in range(L):
                idx.append(i)
                i = int((i + rng.integers(-2, 3)) % pool_n)
            sents.append([str(pool[i]) for i in idx])
        docs.append(Document(sents))
    return docs


def split_holdout(docs: Sequence[Document], held_sentences: int = 2
                  ) -> tuple:
    """(train_docs, held_docs): carve the last ``held_sentences`` sentences
    of every document into the held-out set.  Document-level holdout is NOT
    distribution-matched here — each synthetic document draws its own
    vocabulary-pool window, so unseen documents constitute a domain shift;
    the paper evaluates in-domain."""
    train, held = [], []
    for d in docs:
        if d.n_sentences <= held_sentences:
            train.append(d)
            continue
        train.append(Document(d.sentences[:-held_sentences]))
        held.append(Document(d.sentences[-held_sentences:]))
    return train, held


def corpus_stats(docs: Sequence[Document]) -> dict:
    return {
        "quantity": len(docs),
        "mean_sentence_length": float(np.mean(
            [d.mean_sentence_length for d in docs])) if docs else 0.0,
        "unique_words": len(set().union(*[d.unique_words for d in docs]))
        if docs else 0,
    }
