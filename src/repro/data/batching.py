"""Tokenize + pack client shards into model batches.

CLM: packed token stream, ``targets`` = next token, full loss mask.
MLM (the paper's DistilBERT objective): BERT-style 15% masking — 80% [MASK],
10% random id, 10% kept; loss only at masked positions.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.data.corpus import Document
from repro.data.tokenizer import MASK, N_SPECIALS, HashWordTokenizer


def tokenize_shard(docs: Sequence[Document], tok: HashWordTokenizer
                   ) -> np.ndarray:
    ids: List[int] = []
    for d in docs:
        ids.extend(tok.encode_document(d.sentences))
    return np.asarray(ids, np.int32)


def _pack(stream: np.ndarray, batch: int, seq: int) -> np.ndarray:
    n_tok = batch * seq
    n_steps = len(stream) // n_tok
    if n_steps == 0:
        reps = int(np.ceil(n_tok / max(len(stream), 1)))
        stream = np.tile(stream, reps + 1)
        n_steps = 1
    used = stream[:n_steps * n_tok]
    return used.reshape(n_steps, batch, seq)


def clm_batches(stream: np.ndarray, batch: int, seq: int) -> List[Dict]:
    toks = _pack(stream, batch, seq + 1)
    out = []
    for step in toks:
        out.append({
            "tokens": step[:, :-1].astype(np.int32),
            "targets": step[:, 1:].astype(np.int32),
            "loss_mask": np.ones((batch, seq), np.float32),
        })
    return out


def mlm_batches(stream: np.ndarray, batch: int, seq: int, vocab: int,
                *, mask_rate: float = 0.15, seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    toks = _pack(stream, batch, seq)
    out = []
    for step in toks:
        targets = step.astype(np.int32)
        sel = rng.random(step.shape) < mask_rate
        r = rng.random(step.shape)
        inputs = targets.copy()
        inputs[sel & (r < 0.8)] = MASK
        rand_ids = rng.integers(N_SPECIALS, vocab, size=step.shape)
        swap = sel & (r >= 0.8) & (r < 0.9)
        inputs[swap] = rand_ids[swap]
        out.append({
            "tokens": inputs,
            "targets": targets,
            "loss_mask": sel.astype(np.float32),
        })
    return out


def shard_batches(docs: Sequence[Document], cfg, batch: int, seq: int,
                  *, seed: int = 0) -> List[Dict]:
    tok = HashWordTokenizer(cfg.vocab_size)
    stream = tokenize_shard(docs, tok)
    if cfg.objective == "mlm":
        return mlm_batches(stream, batch, seq, cfg.vocab_size,
                           mask_rate=cfg.mlm_mask_rate, seed=seed)
    return clm_batches(stream, batch, seq)
