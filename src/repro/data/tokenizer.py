"""Deterministic hash-word tokenizer (offline stand-in for WordPiece).

Words map to ``5 + FNV1a(word) % (V-5)``; ids 0-4 are specials.  Collisions
are acceptable for pre-training-loss experiments; the mapping is stable
across processes (no salted ``hash()``).
"""

from __future__ import annotations

from typing import Iterable, List

PAD, UNK, MASK, BOS, EOS = 0, 1, 2, 3, 4
N_SPECIALS = 5


def _fnv1a(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h


class HashWordTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > N_SPECIALS
        self.vocab_size = vocab_size

    def token(self, word: str) -> int:
        return N_SPECIALS + _fnv1a(word) % (self.vocab_size - N_SPECIALS)

    def encode_sentence(self, words: Iterable[str]) -> List[int]:
        return [self.token(w) for w in words]

    def encode_document(self, sentences: Iterable[Iterable[str]],
                        *, bos: bool = True, eos: bool = True) -> List[int]:
        ids: List[int] = [BOS] if bos else []
        for s in sentences:
            ids.extend(self.encode_sentence(s))
        if eos:
            ids.append(EOS)
        return ids
