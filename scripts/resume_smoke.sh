#!/usr/bin/env bash
# Resume smoke: a run interrupted after round 1 and resumed from its
# checkpoint must produce a ledger (losses, client selections, byte
# accounting) and final params BITWISE identical to the uninterrupted run.
# CI runs this via bench_smoke.sh; run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT
ARGS=(--arch distilbert-mlm --clients 2 --rounds 2 --docs 40 --batch-size 2
      --seq-len 32 --max-steps-per-round 2 --strategy fedavgm --ffdapt)

echo "-- uninterrupted run --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ledger-out "$TMP/full.json"

echo "-- interrupted after round 1 (checkpoint written) --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ckpt-dir "$TMP/ckpt" --ckpt-every 1 --stop-after 1

echo "-- resumed from the checkpoint --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ckpt-dir "$TMP/ckpt" --resume --ledger-out "$TMP/resumed.json"

diff "$TMP/full.json" "$TMP/resumed.json"
echo "resume smoke OK: ledger + final params bitwise identical"
