#!/usr/bin/env bash
# PEFT smoke: the ParamSpace path end to end at tiny scale — a LoRA
# federated run (clients train and ship only the adapter bank) must
# checkpoint and resume BITWISE, its final checkpoint must serve through
# the decode engine (bank merged into the base at load), and the
# downstream probe benchmark must emit a schema-complete payload.
# CI runs this via bench_smoke.sh and as its own step; run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT
ARGS=(--arch qwen2-7b --clients 2 --rounds 2 --docs 40 --batch-size 2
      --seq-len 32 --max-steps-per-round 2 --param-space lora --lora-rank 4)

echo "-- LoRA run, uninterrupted --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ledger-out "$TMP/full.json"

echo "-- LoRA run, interrupted after round 1 (bank checkpointed) --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ckpt-dir "$TMP/ckpt" --ckpt-every 1 --stop-after 1

echo "-- resumed from the bank checkpoint --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ckpt-dir "$TMP/ckpt" --resume --ledger-out "$TMP/resumed.json"

diff "$TMP/full.json" "$TMP/resumed.json"
echo "peft resume OK: ledger + final params bitwise identical"

echo "-- serve the LoRA checkpoint (bank merged at load) --"
bash scripts/serve_env.sh python -m repro.launch.serve --arch qwen2-7b \
    --ckpt-dir "$TMP/ckpt" --requests 2 --slots 2 --prompt-len 8 \
    --tokens 4 | tee "$TMP/serve.log"
grep -q "checkpoint step" "$TMP/serve.log"

echo "-- downstream probe (tiny) + schema check --"
python benchmarks/downstream.py --tiny --out "$TMP/BENCH_downstream.json"
python scripts/bench_check.py "$TMP/BENCH_downstream.json"

echo "peft smoke OK: train -> resume -> serve merged -> downstream probe"
