#!/usr/bin/env bash
# Serving smoke: train a real 2-round FedSession at tiny scale, serve its
# checkpoint through the continuous-batching engine AND the static baseline
# under the same seeded Poisson arrivals, and hold the result to the
# acceptance bar: bitwise-equal outputs, continuous throughput >= static,
# and a schema-complete BENCH_serve.json.  Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT

echo "== serving benchmark (tiny: 2-round checkpoint -> Poisson traffic) =="
bash scripts/serve_env.sh python benchmarks/serving.py --tiny \
    --out "$TMP/BENCH_serve.json"

echo "== BENCH_serve.json schema =="
python - "$TMP/BENCH_serve.json" <<'EOF'
import json, sys
from repro.serve import BENCH_MODE_KEYS

bench = json.load(open(sys.argv[1]))
for key in ("benchmark", "arch", "arch_type", "checkpoint", "engine",
            "workload", "modes", "throughput_ratio", "parity_bitwise"):
    assert key in bench, f"missing top-level key {key!r}"
assert bench["benchmark"] == "serve"
assert bench["checkpoint"]["step"] >= 1, "did not serve a real checkpoint"
for mode in ("continuous", "static"):
    missing = set(BENCH_MODE_KEYS) - set(bench["modes"][mode])
    assert not missing, f"{mode} summary missing {sorted(missing)}"
    assert bench["modes"][mode]["generated_tokens"] > 0
assert bench["parity_bitwise"] is True
assert bench["throughput_ratio"] >= 1.0
print("serve smoke OK: schema complete, parity bitwise, "
      f"ratio {bench['throughput_ratio']}")
EOF
