#!/usr/bin/env bash
# Serving smoke: train a real 2-round FedSession at tiny scale, serve its
# checkpoint through the continuous-batching engine AND the static baseline
# under the same seeded Poisson arrivals, and hold the result to the
# acceptance bar: bitwise-equal outputs, continuous throughput >= static,
# and a schema-complete BENCH_serve.json.  Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT

echo "== serving benchmark (tiny: 2-round checkpoint -> Poisson traffic) =="
bash scripts/serve_env.sh python benchmarks/serving.py --tiny \
    --out "$TMP/BENCH_serve.json"

echo "== BENCH_serve.json schema (shared rules: scripts/bench_check.py) =="
python scripts/bench_check.py "$TMP/BENCH_serve.json"
