#!/usr/bin/env bash
# Observability smoke: one tiny train run and one tiny serve run with every
# obs flag on, then hold the emitted artifacts to the acceptance bar:
#   * the Chrome trace is valid JSON carrying the expected measured spans
#     (round/dispatch/aggregate/checkpoint, admit/decode), compile events,
#     AND the synthetic simulated timeline (sim.round/sim.client);
#   * the drift ledger has exactly one row per round, each priced by the
#     fleet predictor with a finite ratio;
#   * the metrics JSONL parses and carries the train/serve counters.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT
ROUNDS=2

echo "== train (tiny, traced, simulated fleet, drift monitored) =="
scripts/train_env.sh python -m repro.launch.train \
    --arch distilbert-mlm --clients 3 --rounds "$ROUNDS" --docs 40 \
    --batch-size 2 --seq-len 32 --max-steps-per-round 2 \
    --fleet paper-2080ti --ckpt-dir "$TMP/ckpt" \
    --ledger-out "$TMP/ledger.json" \
    --trace-out "$TMP/train_trace.json" \
    --metrics-out "$TMP/train_metrics.jsonl" \
    --drift-out "$TMP/train_drift.json" --drift-warn 1000

echo "== serve (tiny, traced, decode-step drift) =="
bash scripts/serve_env.sh python -m repro.launch.serve \
    --arch qwen2-7b --requests 4 --slots 2 --prompt-len 8 --tokens 6 \
    --trace-out "$TMP/serve_trace.json" \
    --metrics-out "$TMP/serve_metrics.jsonl" \
    --drift-out "$TMP/serve_drift.json" --drift-warn 100000

echo "== artifact assertions =="
python - "$TMP" "$ROUNDS" <<'EOF'
import json, sys
tmp, rounds = sys.argv[1], int(sys.argv[2])

# -- train trace: measured + simulated spans in one Perfetto timeline ----
trace = json.load(open(f"{tmp}/train_trace.json"))
assert trace.get("displayTimeUnit") == "ms", "not a Chrome trace payload"
events = trace["traceEvents"]
names = {e.get("name") for e in events}
for want in ("train.round", "train.dispatch", "train.aggregate",
             "train.checkpoint", "sim.round", "sim.client"):
    assert want in names, f"train trace missing span {want!r}"
assert any(n and n.startswith("compile/") for n in names), \
    "train trace carries no compile events"
n_rounds = sum(1 for e in events if e.get("name") == "train.round")
assert n_rounds == rounds, f"{n_rounds} train.round spans != {rounds}"
pids = {e.get("pid") for e in events if e.get("ph") == "X"}
assert {1, 2} <= pids, "measured and simulated lanes must both be present"

# -- drift ledger: one fleet-priced row per round -----------------------
drift = json.load(open(f"{tmp}/train_drift.json"))
assert drift["n_rows"] == rounds, \
    f"drift ledger has {drift['n_rows']} rows, want {rounds}"
for row in drift["rows"]:
    assert row["source"] == "fleet", f"row priced by {row['source']!r}"
    assert row["ratio"] is not None and row["ratio"] > 0

# -- metrics JSONL: parses, carries the train counters ------------------
train_metrics = {json.loads(l)["name"]: json.loads(l)
                 for l in open(f"{tmp}/train_metrics.jsonl") if l.strip()}
assert train_metrics["train.rounds"]["value"] == rounds
assert train_metrics["train.round_s"]["count"] == rounds
assert train_metrics["compile.events"]["value"] > 0

# -- serve artifacts ----------------------------------------------------
strace = json.load(open(f"{tmp}/serve_trace.json"))
snames = {e.get("name") for e in strace["traceEvents"]}
for want in ("serve.admit", "serve.decode_step"):
    assert want in snames, f"serve trace missing span {want!r}"
sdrift = json.load(open(f"{tmp}/serve_drift.json"))
assert sdrift["n_rows"] == 1 and sdrift["rows"][0]["phase"] == "decode_step"
serve_metrics = {json.loads(l)["name"]: json.loads(l)
                 for l in open(f"{tmp}/serve_metrics.jsonl") if l.strip()}
assert serve_metrics["serve.admits"]["value"] >= 4
assert serve_metrics["serve.decode_steps"]["value"] > 0

print(f"obs smoke OK: {len(events)} train events ({n_rounds} rounds, "
      f"sim lane present), {len(strace['traceEvents'])} serve events, "
      f"drift rows {drift['n_rows']}+{sdrift['n_rows']}, metrics "
      f"{len(train_metrics)}+{len(serve_metrics)}")
EOF
