#!/usr/bin/env python
"""Intra-repo link checker for the markdown docs (CI: the docs job).

Scans README.md and docs/*.md for markdown links `[text](target)` and
verifies every RELATIVE target resolves to a file or directory in the repo
(anchors are stripped; `http(s)://` and `mailto:` targets are skipped —
this checker owns only what a commit can break).  Exit code 1 lists every
broken link.

    python scripts/check_docs.py [files...]      # default: README + docs/
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# [text](target) — target must not contain spaces/parens (our style)
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")


def doc_files(args):
    if args:
        return [Path(a).resolve() for a in args]
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def check(path: Path):
    broken = []
    text = path.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                broken.append((path, lineno, target))
    return broken


def main() -> int:
    files = doc_files(sys.argv[1:])
    broken = []
    for f in files:
        if not f.exists():
            broken.append((f, 0, "<file missing>"))
            continue
        broken.extend(check(f))
    if broken:
        for path, lineno, target in broken:
            try:
                shown = path.relative_to(REPO)
            except ValueError:
                shown = path
            print(f"BROKEN {shown}:{lineno}: {target}")
        return 1
    print(f"docs links ok: {len(files)} files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
