#!/usr/bin/env bash
# Benchmark smoke: run the efficiency benchmarks in tiny-config mode so the
# scripts cannot silently rot (CI runs this after tier-1; see
# .github/workflows/ci.yml).  Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== comm_efficiency (tiny) =="
python benchmarks/comm_efficiency.py --tiny

echo "== ffdapt_efficiency (tiny) =="
python benchmarks/ffdapt_efficiency.py --tiny

echo "== wallclock (tiny, calibrated + overlap checks) =="
python benchmarks/wallclock.py --tiny --calibrated

echo "== committed BENCH_*.json schemas =="
python scripts/bench_check.py

echo "== round_throughput (tiny) =="
scripts/train_env.sh python benchmarks/round_throughput.py --tiny

echo "== kernel conformance smoke (tiny grid + schema check) =="
bash scripts/kernel_smoke.sh

echo "== resume smoke (checkpoint -> resume bitwise parity) =="
bash scripts/resume_smoke.sh

echo "== cohort smoke (cohort-scan vs full-width bitwise parity) =="
bash scripts/cohort_smoke.sh

echo "== serve smoke (federated checkpoint -> continuous batching) =="
bash scripts/serve_smoke.sh

echo "== peft smoke (LoRA train -> resume -> serve merged -> probe) =="
bash scripts/peft_smoke.sh

echo "== obs smoke (trace/metrics/drift artifacts) =="
bash scripts/obs_smoke.sh
