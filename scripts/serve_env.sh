#!/usr/bin/env bash
# Serving runtime hygiene: exec a command under the allocator and XLA
# settings that matter for a long-lived decode process.
#
#   scripts/serve_env.sh python -m repro.launch.serve --arch qwen2-7b ...
#   SERVE_DEVICES=8 scripts/serve_env.sh python benchmarks/serving.py --tiny
#
# Everything is opt-out (existing values win) and degrades gracefully on
# machines without the optional pieces.
set -euo pipefail

# tcmalloc: glibc malloc fragments badly under the steady churn of
# per-request host buffers; preload tcmalloc when the machine has it, and
# keep its large-alloc warnings out of the logs (cache pools are big).
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -f "$TCMALLOC" ]]; then
  export LD_PRELOAD="$TCMALLOC"
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# quiet TF/XLA init chatter; serving logs should be the engine's own
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# float32 by default: the reduced-config CPU path assumes it, and silent
# x64 promotion doubles every cache slot
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# SERVE_DEVICES=N simulates an N-device host platform (useful for sharded
# serving experiments on one machine)
XLA_EXTRA=""
if [[ -n "${SERVE_DEVICES:-}" ]]; then
  XLA_EXTRA="--xla_force_host_platform_device_count=${SERVE_DEVICES}"
fi

# decode-relevant GPU flags (harmless on CPU: only applied when a GPU is
# visible): latency-hiding scheduling and command buffers keep the
# one-token-per-step launch overhead off the critical path
if command -v nvidia-smi >/dev/null 2>&1 && nvidia-smi >/dev/null 2>&1; then
  XLA_EXTRA="$XLA_EXTRA --xla_gpu_enable_latency_hiding_scheduler=true \
--xla_gpu_enable_command_buffer=FUSION,CUBLAS,CUDNN \
--xla_gpu_all_reduce_combine_threshold_bytes=134217728"
fi
if [[ -n "$XLA_EXTRA" ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-}${XLA_FLAGS:+ }${XLA_EXTRA}"
fi

exec "$@"
