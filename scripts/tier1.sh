#!/usr/bin/env bash
# Tier-1 verify entry point (see ROADMAP.md) — run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
