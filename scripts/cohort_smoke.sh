#!/usr/bin/env bash
# Cohort-scan smoke: a 1k-virtual-client population (lazy 4-shard data
# pool), sampled cohort, run once with the full-width parallel round and
# once under --cohort-shard — the two deterministic ledgers (losses,
# client selections, byte accounting, final params sha256) must be
# byte-identical, because the streaming fold is shard-invariant.  Also
# validates the ledger schema the sim replays consume.  CI runs this via
# bench_smoke.sh; run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT
ARGS=(--arch distilbert-mlm --clients 1000 --client-pool 4 --engine parallel
      --participation 0.016 --rounds 2 --docs 60 --batch-size 2 --seq-len 32
      --max-steps-per-round 1 --fleet crossdevice)

echo "-- full-width vmapped round (cohort_shard=0) --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --ledger-out "$TMP/full.json"

echo "-- cohort-scan round (cohort_shard=8) --"
scripts/train_env.sh python -m repro.launch.train "${ARGS[@]}" \
    --cohort-shard 8 --ledger-out "$TMP/scan.json"

diff "$TMP/full.json" "$TMP/scan.json"

python - "$TMP/scan.json" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    led = json.load(f)
assert isinstance(led["params_sha256"], str) and len(led["params_sha256"]) == 64
rounds = led["rounds"]
assert len(rounds) == 2, len(rounds)
for rr in rounds:
    # the replay ledger schema repro.sim consumes (clock.ledger_lists)
    for key in ("round", "loss", "clients", "client_steps",
                "client_step_flops", "client_step_hbm",
                "client_upload_bytes", "upload_bytes", "download_bytes",
                "comm_bytes", "flops_estimate", "sim_round_s"):
        assert key in rr, f"ledger missing {key}"
    m = len(rr["clients"])
    assert m == 16, m                      # 0.016 of 1000 virtual clients
    assert len(rr["client_steps"]) == m
    assert len(rr["client_upload_bytes"]) == m
    assert sum(rr["client_upload_bytes"]) == rr["upload_bytes"]
    assert rr["flops_estimate"] > 0 and rr["sim_round_s"] > 0
print("cohort smoke OK: shard parity + ledger schema")
EOF
