#!/usr/bin/env python
"""Validate BENCH_*.json perf-trajectory files against the shared schema.

One schema per benchmark family, held in ONE place (here) instead of
drifting between inline heredocs in each smoke script:

  * ``serve``            — ``benchmarks/serving.py`` (two-mode payload with
    bitwise parity + throughput ratio) and ``repro.launch.serve
    --bench-out`` (single-mode payload);
  * ``round_throughput`` — ``benchmarks/round_throughput.py``;
  * ``kernels``          — ``benchmarks/kernel_bench.py`` (conformance grid:
    every row must pass its tolerance rung; a ``grid: "full"`` payload must
    also cover all four kernels with >= 40 cases incl. VJP + chain, and a
    non-interpret payload must pin per-kernel speed wins);
  * ``train_step``       — ``benchmarks/kernel_bench.py`` (warm-round train
    hot path + analytic step cost + measured-vs-predicted drift row);
  * ``downstream``       — ``benchmarks/downstream.py`` (FDAPT vs FFDAPT vs
    LoRA-FDAPT probe: accuracies in [0,1], the paper's <1% fluctuation
    bound at full probe size, LoRA upload >= 10x smaller).

Usage::

    python scripts/bench_check.py FILE [FILE ...]   # validate these files
    python scripts/bench_check.py                   # committed BENCH_*.json

Exits non-zero naming the first violation.  CI runs this twice: over the
committed trajectory files (schema rot) and over freshly-generated tiny
runs (producer rot) — see scripts/bench_smoke.sh / serve_smoke.sh.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.serve.metrics import BENCH_MODE_KEYS  # noqa: E402

PERCENTILE_KEYS = ("mean", "p50", "p99")


def _require(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise AssertionError(f"{path}: {msg}")


def _check_mode_summary(path: str, mode: str, summary: dict) -> None:
    missing = set(BENCH_MODE_KEYS) - set(summary)
    _require(not missing, path, f"{mode} summary missing {sorted(missing)}")
    _require(summary["generated_tokens"] > 0, path,
             f"{mode}: generated_tokens must be > 0")
    for field in ("ttft_s", "latency_s"):
        got = set(summary[field])
        _require(got == set(PERCENTILE_KEYS), path,
                 f"{mode}.{field} keys {sorted(got)} != "
                 f"{sorted(PERCENTILE_KEYS)}")


def check_serve(path: str, bench: dict) -> str:
    if "modes" in bench:           # benchmarks/serving.py two-mode payload
        for key in ("arch", "arch_type", "checkpoint", "engine", "workload",
                    "modes", "throughput_ratio", "parity_bitwise"):
            _require(key in bench, path, f"missing top-level key {key!r}")
        _require(bench["checkpoint"]["step"] >= 1, path,
                 "did not serve a real checkpoint")
        for mode in ("continuous", "static"):
            _require(mode in bench["modes"], path, f"missing mode {mode!r}")
            _check_mode_summary(path, mode, bench["modes"][mode])
        _require(bench["parity_bitwise"] is True, path,
                 "continuous/static outputs not bitwise equal")
        _require(bench["throughput_ratio"] >= 1.0, path,
                 f"continuous slower than static "
                 f"(ratio {bench['throughput_ratio']})")
        return (f"serve: parity bitwise, "
                f"ratio {bench['throughput_ratio']}")
    # repro.launch.serve --bench-out single-mode payload
    for key in ("arch", "mode", "workload", "engine", "metrics"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    _check_mode_summary(path, bench["mode"], bench["metrics"])
    return f"serve ({bench['mode']}): schema complete"


def check_round_throughput(path: str, bench: dict) -> str:
    for key in ("arch", "engine", "cohort_shard", "local_steps",
                "params_bytes", "opt_state_bytes", "rows"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    _require(bench["rows"], path, "empty rows")
    mode_keys = {"round_s", "clients_per_s", "step_flops_per_client",
                 "aggregate_upload_bytes", "aggregate_download_bytes",
                 "peak_live_bytes_proxy"}
    for row in bench["rows"]:
        _require("cohort" in row, path, "row missing cohort")
        for mode in ("stacked_vmap", "cohort_scan"):
            _require(mode in row, path,
                     f"cohort {row['cohort']}: missing {mode!r}")
            cell = row[mode]
            if cell is None:       # stacked-vmap unmeasured above crossover
                continue
            missing = mode_keys - set(cell)
            _require(not missing, path,
                     f"cohort {row['cohort']}.{mode} missing "
                     f"{sorted(missing)}")
            _require(cell["round_s"] > 0, path,
                     f"cohort {row['cohort']}.{mode}: round_s must be > 0")
    return f"round_throughput: {len(bench['rows'])} cohort rows"


FULL_GRID_MIN_CASES = 40
KERNEL_NAMES = ("flash_attention", "rwkv6_scan", "mamba2_scan", "moe_gmm")


def check_kernels(path: str, bench: dict) -> str:
    for key in ("grid", "backend", "interpret", "jax_version",
                "tolerance_ladder", "summary", "rows"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    rows = bench["rows"]
    _require(rows, path, "empty rows")
    row_keys = {"name", "kernel", "dtype", "tags", "ok", "fwd_violation",
                "vjp_violation", "chain_violation", "interpret"}
    names = set()
    for row in rows:
        missing = row_keys - set(row)
        _require(not missing, path,
                 f"row {row.get('name')} missing {sorted(missing)}")
        _require(row["kernel"] in KERNEL_NAMES, path,
                 f"row {row['name']}: unknown kernel {row['kernel']!r}")
        _require(row["name"] not in names, path,
                 f"duplicate case name {row['name']!r}")
        names.add(row["name"])
        _require(row["ok"] is True, path,
                 f"case {row['name']} FAILED its tolerance rung "
                 f"(fwd={row['fwd_violation']} vjp={row['vjp_violation']} "
                 f"chain={row['chain_violation']})")
        for d in ("fwd_violation", "vjp_violation", "chain_violation"):
            v = row[d]
            _require(v is None or 0.0 <= v <= 1.0, path,
                     f"case {row['name']}: {d}={v} out of [0, 1]")
    summary = bench["summary"]
    _require(summary.get("n_failed") == 0, path,
             f"summary reports {summary.get('n_failed')} failed cases")
    if bench["grid"] == "full":
        _require(len(rows) >= FULL_GRID_MIN_CASES, path,
                 f"full grid has {len(rows)} cases "
                 f"(< {FULL_GRID_MIN_CASES})")
        for kernel in KERNEL_NAMES:
            krows = [r for r in rows if r["kernel"] == kernel]
            _require(krows, path, f"full grid missing kernel {kernel!r}")
            _require(any(r["vjp_violation"] is not None for r in krows),
                     path, f"full grid: no VJP coverage for {kernel!r}")
        _require(any(r["chain_violation"] is not None for r in rows), path,
                 "full grid: no state-chaining coverage")
    if bench["interpret"] is False:
        med = summary.get("median_fp32_speedup", {})
        _require(bool(med), path,
                 "compiled run must record median_fp32_speedup")
        slow = {k: v for k, v in med.items() if v < 1.0}
        _require(not slow, path, f"compiled kernels slower than ref: {slow}")
    mode = "interpret" if bench["interpret"] else "compiled"
    return (f"kernels ({bench['grid']}, {mode}): {len(rows)} cases, "
            f"worst fwd violation "
            f"{summary['worst_violation']['fwd']:.3f}")


def check_train(path: str, bench: dict) -> str:
    for key in ("arch", "engine", "cohort", "local_steps", "batch", "seq",
                "warm_round_s", "clients_per_s", "step_cost", "drift"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    _require(bench["warm_round_s"] > 0, path, "warm_round_s must be > 0")
    _require(bench["clients_per_s"] > 0, path, "clients_per_s must be > 0")
    cost = bench["step_cost"]
    for key in ("flops", "hbm_bytes", "collective_bytes"):
        _require(key in cost, path, f"step_cost missing {key!r}")
    _require(cost["flops"] > 0, path, "step_cost.flops must be > 0")
    _require(cost["hbm_bytes"] > 0, path, "step_cost.hbm_bytes must be > 0")
    drift = bench["drift"]
    for key in ("phase", "measured_s", "predicted_s", "ratio", "source",
                "warn", "device"):
        _require(key in drift, path, f"drift missing {key!r}")
    _require(drift["predicted_s"] > 0, path,
             "drift.predicted_s must be > 0 (no predictor resolved)")
    _require(drift["ratio"] is not None and drift["ratio"] > 0, path,
             "drift.ratio must be a positive number")
    return (f"train_step: warm round {bench['warm_round_s']}s, drift "
            f"ratio {drift['ratio']:.3g} ({drift['source']})")


FLUCTUATION_MIN_DOCS = 128          # the <1% gate needs a real sample size


def check_downstream(path: str, bench: dict) -> str:
    for key in ("arch", "task", "engine", "rounds", "local_steps",
                "probe_docs", "rows", "fluctuation_pct",
                "lora_upload_reduction_x"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    models = {r.get("model") for r in bench["rows"]}
    for model in ("fdapt", "ffdapt", "lora_fdapt"):
        _require(model in models, path, f"missing variant row {model!r}")
    for row in bench["rows"]:
        _require(0.0 <= row.get("accuracy", -1.0) <= 1.0, path,
                 f"{row.get('model')}: accuracy {row.get('accuracy')} "
                 f"out of [0, 1]")
        _require(row.get("upload_bytes", -1) >= 0, path,
                 f"{row.get('model')}: missing/negative upload_bytes")
    _require(bench["lora_upload_reduction_x"] >= 10.0, path,
             f"LoRA upload reduction {bench['lora_upload_reduction_x']:.1f}x "
             f"< 10x")
    if bench["probe_docs"] >= FLUCTUATION_MIN_DOCS:
        _require(bench["fluctuation_pct"] < 1.0, path,
                 f"FDAPT-vs-FFDAPT fluctuation "
                 f"{bench['fluctuation_pct']:.3f}% >= 1% (paper bound)")
        gate = f"fluctuation {bench['fluctuation_pct']:.3f}%"
    else:                              # tiny smoke: too few docs to gate on
        gate = f"fluctuation ungated ({bench['probe_docs']} docs)"
    return (f"downstream: {gate}, lora upload "
            f"{bench['lora_upload_reduction_x']:.1f}x smaller")


CHECKERS = {"serve": check_serve,
            "round_throughput": check_round_throughput,
            "kernels": check_kernels,
            "train_step": check_train,
            "downstream": check_downstream}


def check_file(path: str) -> str:
    with open(path) as f:
        bench = json.load(f)
    _require(isinstance(bench, dict), path, "payload is not a JSON object")
    _require("benchmark" in bench, path, "missing 'benchmark' key")
    name = bench["benchmark"]
    _require(name in CHECKERS, path,
             f"unknown benchmark {name!r} (known: {sorted(CHECKERS)}) — "
             f"add its schema to scripts/bench_check.py")
    return CHECKERS[name](path, bench)


def main(argv) -> int:
    paths = argv[1:]
    if not paths:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
        if not paths:
            print("bench_check: no BENCH_*.json files found", file=sys.stderr)
            return 1
    for path in paths:
        try:
            detail = check_file(path)
        except AssertionError as e:
            print(f"bench_check FAIL: {e}", file=sys.stderr)
            return 1
        print(f"bench_check OK [{os.path.basename(path)}] {detail}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
