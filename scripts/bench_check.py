#!/usr/bin/env python
"""Validate BENCH_*.json perf-trajectory files against the shared schema.

One schema per benchmark family, held in ONE place (here) instead of
drifting between inline heredocs in each smoke script:

  * ``serve``            — ``benchmarks/serving.py`` (two-mode payload with
    bitwise parity + throughput ratio) and ``repro.launch.serve
    --bench-out`` (single-mode payload);
  * ``round_throughput`` — ``benchmarks/round_throughput.py``.

Usage::

    python scripts/bench_check.py FILE [FILE ...]   # validate these files
    python scripts/bench_check.py                   # committed BENCH_*.json

Exits non-zero naming the first violation.  CI runs this twice: over the
committed trajectory files (schema rot) and over freshly-generated tiny
runs (producer rot) — see scripts/bench_smoke.sh / serve_smoke.sh.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.serve.metrics import BENCH_MODE_KEYS  # noqa: E402

PERCENTILE_KEYS = ("mean", "p50", "p99")


def _require(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise AssertionError(f"{path}: {msg}")


def _check_mode_summary(path: str, mode: str, summary: dict) -> None:
    missing = set(BENCH_MODE_KEYS) - set(summary)
    _require(not missing, path, f"{mode} summary missing {sorted(missing)}")
    _require(summary["generated_tokens"] > 0, path,
             f"{mode}: generated_tokens must be > 0")
    for field in ("ttft_s", "latency_s"):
        got = set(summary[field])
        _require(got == set(PERCENTILE_KEYS), path,
                 f"{mode}.{field} keys {sorted(got)} != "
                 f"{sorted(PERCENTILE_KEYS)}")


def check_serve(path: str, bench: dict) -> str:
    if "modes" in bench:           # benchmarks/serving.py two-mode payload
        for key in ("arch", "arch_type", "checkpoint", "engine", "workload",
                    "modes", "throughput_ratio", "parity_bitwise"):
            _require(key in bench, path, f"missing top-level key {key!r}")
        _require(bench["checkpoint"]["step"] >= 1, path,
                 "did not serve a real checkpoint")
        for mode in ("continuous", "static"):
            _require(mode in bench["modes"], path, f"missing mode {mode!r}")
            _check_mode_summary(path, mode, bench["modes"][mode])
        _require(bench["parity_bitwise"] is True, path,
                 "continuous/static outputs not bitwise equal")
        _require(bench["throughput_ratio"] >= 1.0, path,
                 f"continuous slower than static "
                 f"(ratio {bench['throughput_ratio']})")
        return (f"serve: parity bitwise, "
                f"ratio {bench['throughput_ratio']}")
    # repro.launch.serve --bench-out single-mode payload
    for key in ("arch", "mode", "workload", "engine", "metrics"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    _check_mode_summary(path, bench["mode"], bench["metrics"])
    return f"serve ({bench['mode']}): schema complete"


def check_round_throughput(path: str, bench: dict) -> str:
    for key in ("arch", "engine", "cohort_shard", "local_steps",
                "params_bytes", "opt_state_bytes", "rows"):
        _require(key in bench, path, f"missing top-level key {key!r}")
    _require(bench["rows"], path, "empty rows")
    mode_keys = {"round_s", "clients_per_s", "step_flops_per_client",
                 "aggregate_upload_bytes", "aggregate_download_bytes",
                 "peak_live_bytes_proxy"}
    for row in bench["rows"]:
        _require("cohort" in row, path, "row missing cohort")
        for mode in ("stacked_vmap", "cohort_scan"):
            _require(mode in row, path,
                     f"cohort {row['cohort']}: missing {mode!r}")
            cell = row[mode]
            if cell is None:       # stacked-vmap unmeasured above crossover
                continue
            missing = mode_keys - set(cell)
            _require(not missing, path,
                     f"cohort {row['cohort']}.{mode} missing "
                     f"{sorted(missing)}")
            _require(cell["round_s"] > 0, path,
                     f"cohort {row['cohort']}.{mode}: round_s must be > 0")
    return f"round_throughput: {len(bench['rows'])} cohort rows"


CHECKERS = {"serve": check_serve,
            "round_throughput": check_round_throughput}


def check_file(path: str) -> str:
    with open(path) as f:
        bench = json.load(f)
    _require(isinstance(bench, dict), path, "payload is not a JSON object")
    _require("benchmark" in bench, path, "missing 'benchmark' key")
    name = bench["benchmark"]
    _require(name in CHECKERS, path,
             f"unknown benchmark {name!r} (known: {sorted(CHECKERS)}) — "
             f"add its schema to scripts/bench_check.py")
    return CHECKERS[name](path, bench)


def main(argv) -> int:
    paths = argv[1:]
    if not paths:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
        if not paths:
            print("bench_check: no BENCH_*.json files found", file=sys.stderr)
            return 1
    for path in paths:
        try:
            detail = check_file(path)
        except AssertionError as e:
            print(f"bench_check FAIL: {e}", file=sys.stderr)
            return 1
        print(f"bench_check OK [{os.path.basename(path)}] {detail}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
