#!/usr/bin/env bash
# Training runtime hygiene: exec a command under the allocator and XLA
# settings that matter for federated training drivers — especially the
# cohort-scan engine, whose shard loop churns large stacked host buffers
# and (on a real mesh) leans on pipelined collectives for the per-shard
# aggregation all-reduce.
#
#   scripts/train_env.sh python -m repro.launch.train --clients 100000 ...
#   TRAIN_DEVICES=8 scripts/train_env.sh python benchmarks/round_throughput.py
#
# Everything is opt-out (existing values win) and degrades gracefully on
# machines without the optional pieces.
set -euo pipefail

# tcmalloc: glibc malloc fragments badly under the cohort-scan shard churn
# (every shard stacks/free's client batches and opt state); preload
# tcmalloc when the machine has it, and keep its large-alloc warnings out
# of the logs (stacked shard buffers are big by design).
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -f "$TCMALLOC" ]]; then
  export LD_PRELOAD="$TCMALLOC"
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# quiet TF/XLA init chatter; training logs should be the round ledger
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# float32 by default: the reduced-config CPU path assumes it, and silent
# x64 promotion doubles every stacked client buffer
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# TRAIN_DEVICES=N simulates an N-device host platform (client-axis sharding
# experiments — COHORT_RULES / the 512-device fixtures — on one machine)
XLA_EXTRA=""
if [[ -n "${TRAIN_DEVICES:-}" ]]; then
  XLA_EXTRA="--xla_force_host_platform_device_count=${TRAIN_DEVICES}"
fi

# MaxText-style GPU collective flags (harmless on CPU: only applied when a
# GPU is visible): the latency-hiding scheduler overlaps the per-shard
# aggregation all-reduce with the next shard's compute, pipelined
# collectives + fat combine thresholds keep the model-sized payloads off
# the critical path, and double-buffered while loops serve the scanned
# local epochs.
if command -v nvidia-smi >/dev/null 2>&1 && nvidia-smi >/dev/null 2>&1; then
  XLA_EXTRA="$XLA_EXTRA --xla_gpu_enable_latency_hiding_scheduler=true \
--xla_gpu_enable_highest_priority_async_stream=true \
--xla_gpu_all_reduce_combine_threshold_bytes=134217728 \
--xla_gpu_all_gather_combine_threshold_bytes=1073741824 \
--xla_gpu_reduce_scatter_combine_threshold_bytes=33554432 \
--xla_gpu_enable_pipelined_all_reduce=true \
--xla_gpu_enable_pipelined_all_gather=true \
--xla_gpu_enable_pipelined_reduce_scatter=true \
--xla_gpu_enable_while_loop_double_buffering=true"
fi
if [[ -n "$XLA_EXTRA" ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-}${XLA_FLAGS:+ }${XLA_EXTRA}"
fi

exec "$@"
