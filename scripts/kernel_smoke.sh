#!/usr/bin/env bash
# Kernel conformance smoke: sweep a tiny slice of the conformance grid
# (one fp32 lattice case per kernel + one chain case per scan) through
# benchmarks/kernel_bench.py into a temp dir, then validate the freshly
# produced BENCH_kernels.json / BENCH_train.json against the shared
# schemas in scripts/bench_check.py (producer rot), alongside the
# committed repo-root baselines (schema rot, checked by bench_check's
# no-args mode in bench_smoke.sh).  Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

OUT=$(mktemp -d)
trap 'rm -rf "$OUT"' EXIT

echo "== kernel conformance (tiny grid -> $OUT) =="
python benchmarks/kernel_bench.py --tiny \
    --out "$OUT/BENCH_kernels.json" --train-out "$OUT/BENCH_train.json"

echo "== fresh BENCH_kernels/BENCH_train schemas =="
python scripts/bench_check.py "$OUT/BENCH_kernels.json" \
    "$OUT/BENCH_train.json"

echo "kernel smoke OK"
