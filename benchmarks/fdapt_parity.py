"""Table 2 analogue: downstream-performance parity grid at smoke scale.

The paper's Table 2 measures downstream F1 on 9 biomedical tasks after
4,640 GPU-hours of pre-training; offline we measure the *pre-training proxy*
— held-out masked-LM loss — for the same grid:
  original / centralized / FDAPT / FFDAPT x {IID, quantity, length, vocab}
  x {2, 8 clients}.
The paper's claims map to: (i) every federated cell beats `original`,
(ii) every federated cell lands within a few percent of `centralized`,
(iii) FFDAPT tracks FDAPT within ~1%.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P


def run(quick: bool = True, seed: int = 0):
    cfg = get_config("distilbert-mlm").reduced()
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    from repro.data.corpus import split_holdout
    n = 160 if quick else 480
    docs, held_docs = split_holdout(generate_corpus(n, seed=seed))
    # frequent averaging bounds client drift under the vocabulary skew
    rounds = 5 if quick else 8
    steps = 4 if quick else 8
    clients = (2,) if quick else (2, 8)

    eval_step = jax.jit(make_eval_step(cfg))
    held = make_client_datasets(held_docs, cfg, k=1,
                                batch=4, seq=64)["batches"][0][:8]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in held]))

    lr = 1e-3
    rows = [("original", 0, "-", eval_loss(params0))]
    cen = make_client_datasets(docs, cfg, k=1, batch=2, seq=32)
    p, _ = FedSession(cfg, optim.adam(lr), n_rounds=rounds).run(
        params0, [cen["batches"][0][:steps * 2]])
    rows.append(("centralized", 1, "-", eval_loss(p)))

    for k in clients:
        for skew in ("iid", "quantity", "length", "vocab"):
            ds = make_client_datasets(docs, cfg, k=k, skew=skew,
                                      batch=2, seq=32, seed=seed)
            bs = [b[:steps] for b in ds["batches"]]
            for ffd, tag in ((None, "fdapt"), (FFDAPTConfig(), "ffdapt")):
                p, _ = FedSession(cfg, optim.adam(lr), n_rounds=rounds,
                                  client_sizes=ds["sizes"],
                                  ffdapt=ffd).run(params0, bs)
                rows.append((tag, k, skew, eval_loss(p)))
    return rows


def main(quick: bool = True):
    rows = run(quick=quick)
    print("setting,clients,skew,eval_loss")
    for tag, k, skew, loss in rows:
        print(f"{tag},{k},{skew},{loss:.4f}")
    # claim checks
    orig = rows[0][3]
    cen = rows[1][3]
    fed = [r for r in rows if r[0] in ("fdapt", "ffdapt")]
    beats = sum(l < orig for *_, l in fed)
    near = all(l < cen * 1.2 for *_, l in fed)
    fd = {(k, s): l for t, k, s, l in fed if t == "fdapt"}
    ffd = {(k, s): l for t, k, s, l in fed if t == "ffdapt"}
    track = max(abs(ffd[k] - fd[k]) / fd[k] for k in fd)
    worst = max(l / orig - 1 for *_, l in fed)
    print(f"claim_beat_original_cells,{beats}/{len(fed)}")
    print(f"claim_worst_cell_vs_original_pct,{worst * 100:.2f}")
    print(f"claim_all_near_centralized,{near}")
    print(f"claim_ffdapt_max_delta_pct,{track * 100:.2f}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
