"""Communication-efficient FDAPT (paper §5 future work, made concrete).

Per-round client->server upload bytes vs held-out quality for:
  dense FedAvg | int8-quantized deltas | top-10% sparsified deltas
plus FedAvgM (server momentum) as the "other strategies" axis.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core import strategies as S
from repro.core.noniid import make_client_datasets
from repro.data.corpus import generate_corpus, split_holdout
from repro.models.model import init_model
from repro.models.steps import make_eval_step, make_train_step
from repro.nn import param as P


def run(rounds: int = 3, steps: int = 4, seed: int = 0):
    cfg = get_config("distilbert-mlm").reduced()
    docs, held_docs = split_holdout(generate_corpus(160, seed=seed))
    ds = make_client_datasets(docs, cfg, k=2, skew="iid", batch=2, seq=32,
                              seed=seed)
    batches = [b[:steps] for b in ds["batches"]]
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    opt = optim.adam(1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    eval_step = jax.jit(make_eval_step(cfg))
    held = make_client_datasets(held_docs, cfg, k=1, batch=4,
                                seq=64)["batches"][0][:8]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in held]))

    def local_epoch(gparams):
        outs = []
        for bs in batches:
            p, o = gparams, P.unbox(opt.init(gparams))
            for b in bs:
                p, o, _ = step(p, o, b)
            outs.append(p)
        return outs

    def fed_run(compressor=None, server="avg"):
        g = params0
        st = S.ServerState()
        total_bytes = 0
        for _ in range(rounds):
            clients = local_epoch(g)
            if server == "avgm":
                g, st = S.fedavgm_update(g, clients, ds["sizes"], st, beta=0.9)
                total_bytes += sum(S.dense_bytes(S.tree_delta(c, g))
                                   for c in clients)
            else:
                g, nbytes = S.compressed_fedavg(g, clients, ds["sizes"],
                                                compressor=compressor)
                total_bytes += nbytes
        return eval_loss(g), total_bytes

    rows = [("fedavg_dense", *fed_run())]
    rows.append(("fedavg_int8", *fed_run(compressor=S.quantize8)))
    rows.append(("fedavg_top10pct",
                 *fed_run(compressor=lambda d: S.topk_sparsify(d, 0.10))))
    rows.append(("fedavgm_dense", *fed_run(server="avgm")))
    rows.append(("no_training", eval_loss(params0), 0))
    return rows


def main():
    rows = run()
    base_bytes = rows[0][2]
    print("strategy,eval_loss,upload_MB,compression_x")
    for name, loss, nbytes in rows:
        ratio = base_bytes / nbytes if nbytes else 0.0
        print(f"{name},{loss:.4f},{nbytes / 2**20:.1f},{ratio:.1f}")


if __name__ == "__main__":
    main()
