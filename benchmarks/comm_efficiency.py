"""Communication-efficient FDAPT (paper §5 future work, made concrete).

Per-round client->server upload bytes vs held-out quality for:
  dense FedAvg | int8-quantized deltas | top-10% sparsified deltas
plus FedAvgM (server momentum) as the "other strategies" axis, and the
parameter-efficient family (LoRA / adapter banks via
``RoundPlan.param_space`` — clients train and ship only the low-rank
factors, which also composes with int8) — every row is one ``FedSession``,
and the byte column comes straight from ``RoundResult.upload_bytes``
(exact, dtype- and tie-aware accounting; bank-sized for low-rank rows).

    PYTHONPATH=src python benchmarks/comm_efficiency.py [--engine parallel]
"""

from __future__ import annotations

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan
from repro.core.strategy import Compressed, FedAvg, FedAvgM
from repro.data.corpus import generate_corpus, split_holdout
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P


def run(rounds: int = 3, steps: int = 4, seed: int = 0,
        engine: str = "sequential"):
    cfg = get_config("distilbert-mlm").reduced()
    docs, held_docs = split_holdout(generate_corpus(160, seed=seed))
    ds = make_client_datasets(docs, cfg, k=2, skew="iid", batch=2, seq=32,
                              seed=seed)
    batches = [b[:steps] for b in ds["batches"]]
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    eval_step = jax.jit(make_eval_step(cfg))
    held = make_client_datasets(held_docs, cfg, k=1, batch=4,
                                seq=64)["batches"][0][:8]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in held]))

    def fed_run(strategy, space=None):
        plan = RoundPlan(n_rounds=rounds, engine=engine, strategy=strategy,
                         client_sizes=ds["sizes"], param_space=space)
        p, hist = FedSession(cfg, optim.adam(1e-3), plan).run(params0, batches)
        return (eval_loss(p), sum(h.upload_bytes for h in hist),
                sum(h.comm_bytes for h in hist),
                sum(h.flops_estimate for h in hist))

    from repro.peft import adapter, lora
    rows = [("fedavg_dense", *fed_run(FedAvg()))]
    rows.append(("fedavg_int8", *fed_run(Compressed(kind="int8"))))
    rows.append(("fedavg_top10pct", *fed_run(Compressed(kind="topk",
                                                        frac=0.10))))
    rows.append(("fedavgm_dense", *fed_run(FedAvgM(beta=0.9))))
    rows.append(("lora_r4", *fed_run(FedAvg(), space=lora(4))))
    rows.append(("adapter_d8", *fed_run(FedAvg(), space=adapter(8))))
    rows.append(("lora_r4_int8", *fed_run(Compressed(kind="int8"),
                                          space=lora(4))))
    rows.append(("no_training", eval_loss(params0), 0, 0, 0.0))
    return rows


def main(engine: str = "sequential", rounds: int = 3, steps: int = 4):
    rows = run(rounds=rounds, steps=steps, engine=engine)
    base_bytes = rows[0][2]
    print("strategy,eval_loss,upload_MB,comm_MB,compute_GFLOP,compression_x")
    for name, loss, nbytes, comm, flops in rows:
        ratio = base_bytes / nbytes if nbytes else 0.0
        print(f"{name},{loss:.4f},{nbytes / 2**20:.1f},{comm / 2**20:.1f},"
              f"{flops / 1e9:.2f},{ratio:.1f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "parallel"))
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke mode: 1 round, 2 local steps")
    a = ap.parse_args()
    if a.tiny:
        a.rounds, a.steps = 1, 2
    main(engine=a.engine, rounds=a.rounds, steps=a.steps)
