"""§Roofline assembler: read the dry-run JSON artifacts and emit the per
(arch x shape x mesh) roofline table — the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-device memory."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(pattern: str = "*", *, baseline_only: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern + ".json"))):
        name = os.path.basename(path)[:-5]
        # baseline artifacts are arch__shape__podN; hillclimb runs carry an
        # extra __tag suffix and fed_round__* is a separate program
        if baseline_only and (name.count("__") != 2
                              or not name.split("__")[-1].startswith("pod")):
            continue
        with open(path) as f:
            rec = json.load(f)
        rec["_file"] = name
        rows.append(rec)
    return rows


def table(rows=None, *, pods=None, baseline_only=True):
    rows = rows if rows is not None else load()
    out = []
    for r in rows:
        if r.get("status") != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "ERROR", "error": r.get("error", "")[:80]})
            continue
        if pods is not None and len(r["mesh"]) != (3 if pods == 2 else 2):
            continue
        if baseline_only and r.get("knobs", {}).get("opt_rules"):
            continue
        if baseline_only and "__opt" in r.get("_file", ""):
            continue
        rl = r["roofline_s"]
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "pods": 2 if len(r["mesh"]) == 3 else 1,
            "compute_s": rl["compute"], "memory_s": rl["memory"],
            "collective_s": rl["collective"], "bottleneck": r["bottleneck"],
            "model_vs_hlo": r.get("model_vs_hlo_flops", 0.0),
            "mem_gib": r["memory"]["peak_estimate_bytes"] / 2**30
            if isinstance(r.get("memory"), dict) else 0.0,
            "compile_s": r.get("compile_s", 0.0),
        })
    return out


def main():
    rows = table(pods=1)
    print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
          "model_vs_hlo,mem_gib")
    for r in rows:
        if r.get("status") == "ERROR":
            print(f"{r['arch']},{r['shape']},ERROR,,,,{r['error']}")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.3e},"
              f"{r['memory_s']:.3e},{r['collective_s']:.3e},"
              f"{r['bottleneck']},{r['model_vs_hlo']:.3f},{r['mem_gib']:.2f}")
    n_ok = sum(1 for r in rows if r.get("status") != "ERROR")
    print(f"pairs_ok,{n_ok}")


if __name__ == "__main__":
    main()
