"""§Roofline assembler: read the dry-run JSON artifacts and emit the per
(arch x shape x mesh) roofline table — the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-device memory.

``--session`` additionally runs a small live ``FedSession`` and merges its
per-round ``RoundResult`` ledger into the same table through the
``repro.sim`` clock (``--device`` picks the fleet preset the rounds are
timed on), so dry-run programs and real federated rounds are comparable
rows."""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(pattern: str = "*", *, baseline_only: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern + ".json"))):
        name = os.path.basename(path)[:-5]
        # baseline artifacts are arch__shape__podN; hillclimb runs carry an
        # extra __tag suffix and fed_round__* is a separate program
        if baseline_only and (name.count("__") != 2
                              or not name.split("__")[-1].startswith("pod")):
            continue
        with open(path) as f:
            rec = json.load(f)
        rec["_file"] = name
        rows.append(rec)
    return rows


def table(rows=None, *, pods=None, baseline_only=True):
    rows = rows if rows is not None else load()
    out = []
    for r in rows:
        if r.get("status") != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "ERROR", "error": r.get("error", "")[:80]})
            continue
        if pods is not None and len(r["mesh"]) != (3 if pods == 2 else 2):
            continue
        if baseline_only and r.get("knobs", {}).get("opt_rules"):
            continue
        if baseline_only and "__opt" in r.get("_file", ""):
            continue
        rl = r["roofline_s"]
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "pods": 2 if len(r["mesh"]) == 3 else 1,
            "compute_s": rl["compute"], "memory_s": rl["memory"],
            "collective_s": rl["collective"], "bottleneck": r["bottleneck"],
            "model_vs_hlo": r.get("model_vs_hlo_flops", 0.0),
            "mem_gib": r["memory"]["peak_estimate_bytes"] / 2**30
            if isinstance(r.get("memory"), dict) else 0.0,
            "compile_s": r.get("compile_s", 0.0),
        })
    return out


def session_rows(history, arch: str = "session", device: str = "tpu-v4"):
    """RoundResult ledger -> roofline rows on one device preset: the same
    three terms in seconds the dry-run reports, derived from the round's
    flops/hbm/comm estimates via the ``repro.sim`` clock."""
    from repro.sim import PRESETS, device_roofline_s
    dev = PRESETS[device]
    out = []
    for h in history:
        rl = device_roofline_s(h.flops_estimate, h.hbm_bytes_estimate,
                               h.comm_bytes, dev)
        out.append({
            "arch": arch, "shape": f"round{h.round}@{device}",
            "pods": 0,
            "compute_s": rl["compute"], "memory_s": rl["memory"],
            "collective_s": rl["collective"],
            "bottleneck": max(rl, key=rl.get),
            "model_vs_hlo": 0.0, "mem_gib": 0.0, "compile_s": 0.0,
        })
    return out


def run_session(arch: str = "distilbert-mlm", *, clients: int = 2,
                rounds: int = 2, steps: int = 2, device: str = "tpu-v4"):
    """Run a small real FedSession and ledger it (the live counterpart of
    the dry-run artifacts)."""
    import jax
    from repro import optim
    from repro.configs import get_config
    from repro.core.noniid import make_client_datasets
    from repro.core.rounds import FedSession
    from repro.data.corpus import generate_corpus
    from repro.models.model import init_model
    from repro.nn import param as P

    cfg = get_config(arch).reduced()
    ds = make_client_datasets(generate_corpus(120, seed=0), cfg, k=clients,
                              batch=2, seq=32)
    batches = [b[:steps] for b in ds["batches"]]
    params = P.unbox(init_model(jax.random.PRNGKey(0), cfg))
    _, hist = FedSession(cfg, optim.adam(5e-5), n_rounds=rounds,
                         client_sizes=ds["sizes"]).run(params, batches)
    return session_rows(hist, arch=arch, device=device)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--session", action="store_true",
                    help="also run a small live FedSession and merge its "
                         "per-round ledger into the table")
    ap.add_argument("--arch", default="distilbert-mlm")
    ap.add_argument("--device", default="tpu-v4",
                    help="repro.sim device preset the session rounds are "
                         "timed on")
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    rows = table(pods=1)
    if args.session:
        rows += run_session(args.arch, rounds=args.rounds,
                            device=args.device)
    print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
          "model_vs_hlo,mem_gib")
    for r in rows:
        if r.get("status") == "ERROR":
            print(f"{r['arch']},{r['shape']},ERROR,,,,{r['error']}")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.3e},"
              f"{r['memory_s']:.3e},{r['collective_s']:.3e},"
              f"{r['bottleneck']},{r['model_vs_hlo']:.3f},{r['mem_gib']:.2f}")
    n_ok = sum(1 for r in rows if r.get("status") != "ERROR")
    print(f"pairs_ok,{n_ok}")


if __name__ == "__main__":
    main()
