"""Simulated wall-clock FFDAPT-vs-FDAPT saving across the model zoo.

The paper states FFDAPT's efficiency in FLOPs (12.1% mean saving); a
deployer cares about round time on a real fleet, where communication,
stragglers and memory-bound devices dilute a pure-compute saving.  This
benchmark converts the telemetry ledger into *time*:

  for each of the 11 zoo configs (reduced shapes — relative savings are
  shape-stable):
    1. per-step cost of the plain client step and of every window in the
       FFDAPT schedule (``repro.telemetry``, cached per distinct window);
    2. synthetic FDAPT and FFDAPT round histories (same steps, same wire
       bytes — only the compute term differs);
    3. ``repro.sim.simulate_sync`` on a homogeneous datacenter fleet and a
       heterogeneous edge fleet, under BOTH clock modes (sequential and
       overlap — the pipelined clock must never be slower, checked on
       every config);
  reporting simulated sync round seconds per fleet/clock and the FFDAPT
  wall-clock saving next to the analytic FLOP saving.

``--calibrated`` adds a paper-2080ti column timed on the measurement-
calibrated device registry (``repro.sim.calibrate``, anchored to the
committed 2x RTX 2080 Ti datapoint) and prints an ``anchor_check`` row:
the calibrated fleet must reproduce the anchor's measured round seconds
to within 5% (asserted).

Expected shape of the result: on the homogeneous compute-bound fleet the
wall-clock saving tracks the FLOP saving; on the heterogeneous fleet the
slowest (often uplink-bound) client gates the round, so the saving
compresses toward 0 — the quantified version of the survey's system-
heterogeneity warning.

    PYTHONPATH=src python benchmarks/wallclock.py [--tiny] [--calibrated]
        [--archs distilbert-mlm,qwen2-7b] [--clients 2] [--rounds 15]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import optim, telemetry
from repro.configs import all_configs, get_config
from repro.core import ffdapt
from repro.core.rounds import RoundResult
from repro.models.model import n_freeze_units
from repro.sim import (PAPER_2080TI_ROUND, make_fleet, simulate_sync,
                       sync_round_s)

HOMOGENEOUS = "uniform-a100"
HETEROGENEOUS = "edge-mixed"
CALIBRATED = "paper-2080ti"


def _dense_bytes(cfg, opt) -> int:
    from repro.models.steps import abstract_train_state
    params_sds, _ = abstract_train_state(cfg, opt)
    import jax
    import jax.numpy as jnp
    return int(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(params_sds)))


def synthetic_history(step_costs_per_round, steps: int, up_bytes: int,
                      down_bytes: int):
    """Round t with per-client (flops, hbm) pairs -> a replayable history
    (every client runs ``steps`` local steps and uploads a dense model)."""
    hist = []
    for t, per_client in enumerate(step_costs_per_round):
        k = len(per_client)
        hist.append(RoundResult(
            t, 0.0, 0.0, clients=list(range(k)),
            client_steps=[steps] * k,
            client_step_flops=[c[0] for c in per_client],
            client_step_hbm=[c[1] for c in per_client],
            client_upload_bytes=[up_bytes] * k,
            download_bytes=down_bytes * k,
            upload_bytes=up_bytes * k))
    return hist


def anchor_check(clients: int, seed: int) -> dict:
    """Replay the committed anchor workload on the calibrated paper-2080ti
    fleet: the ideal sync round must land within 5% of the measured
    seconds, or the calibrated column cannot be quoted next to the paper."""
    p = PAPER_2080TI_ROUND
    fleet = make_fleet(CALIBRATED, clients, seed=seed, calibrated=True)
    rr = RoundResult(
        0, 0.0, 0.0, clients=list(range(clients)),
        client_steps=[p.steps] * clients,
        client_step_flops=[p.step_flops] * clients,
        client_step_hbm=[p.step_hbm_bytes] * clients,
        client_upload_bytes=[int(p.upload_bytes)] * clients,
        upload_bytes=int(p.upload_bytes) * clients,
        download_bytes=int(p.download_bytes) * clients)
    pred = sync_round_s(rr, fleet)
    rel = abs(pred - p.measured_round_s) / p.measured_round_s
    assert rel <= 0.05, (f"calibrated paper-2080ti round {pred:.1f}s is "
                         f"{rel:.1%} off the measured anchor "
                         f"{p.measured_round_s:.1f}s")
    return {"pred_round_s": pred, "measured_round_s": p.measured_round_s,
            "rel_err": rel}


def arch_row(arch: str, *, clients: int, rounds: int, steps: int,
             batch: int, seq: int, seed: int, calibrated: bool = False):
    cfg = get_config(arch).reduced()
    opt = optim.adam(5e-5)
    from repro.core.strategy import FedAvg
    strat = FedAvg()
    batch_sds = telemetry.train_batch_struct(cfg, batch, seq)
    base = telemetry.client_step_cost(cfg, opt, strat, batch_sds)
    n_units = n_freeze_units(cfg)
    sched = ffdapt.schedule(n_units, [1] * clients, rounds, gamma=1.0)
    # per-round per-client FFDAPT window costs (cache: <= n_units analyses)
    ffd_costs = []
    for rnd in sched:
        masks = [ffdapt.window_mask(n_units, win) for win in rnd]
        costs = telemetry.client_step_costs(
            cfg, opt, strat, [batch_sds] * len(rnd), frozen_list=masks)
        ffd_costs.append([(c.flops, c.hbm_bytes) for c in costs])
    fd_costs = [[(base.flops, base.hbm_bytes)] * clients
                for _ in range(rounds)]

    dense = _dense_bytes(cfg, opt)
    h_fd = synthetic_history(fd_costs, steps, dense, dense)
    h_ffd = synthetic_history(ffd_costs, steps, dense, dense)

    flops_fd = sum(sum(f for f, _ in r) for r in fd_costs)
    flops_ffd = sum(sum(f for f, _ in r) for r in ffd_costs)
    flop_saving = (flops_fd - flops_ffd) / flops_fd * 100.0

    row = {"arch": arch, "flop_saving_pct": flop_saving,
           "params_mb": dense / 2**20}
    presets = [HOMOGENEOUS, HETEROGENEOUS] + ([CALIBRATED] if calibrated
                                              else [])
    for preset in presets:
        fleet = make_fleet(preset, clients, seed=seed,
                           calibrated=(calibrated and preset == CALIBRATED))
        t_fd = simulate_sync(h_fd, fleet, seed=seed).total_s
        t_ffd = simulate_sync(h_ffd, fleet, seed=seed).total_s
        t_fd_ov = simulate_sync(h_fd, fleet, seed=seed, overlap=True).total_s
        # the pipelined clock can only hide time, never add it — asserted
        # on every config x fleet (the acceptance bound of the overlap mode)
        assert t_fd_ov <= t_fd * (1 + 1e-9), (
            f"{arch}/{preset}: overlap {t_fd_ov:.3f}s > sequential "
            f"{t_fd:.3f}s")
        row[preset] = {
            "fdapt_round_s": t_fd / rounds,
            "ffdapt_round_s": t_ffd / rounds,
            "fdapt_overlap_round_s": t_fd_ov / rounds,
            "wallclock_saving_pct": (t_fd - t_ffd) / t_fd * 100.0,
        }
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke mode: 1 arch, 2 rounds, seq 32")
    ap.add_argument("--archs", default="",
                    help="comma-separated arch subset (default: full zoo)")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--steps", type=int, default=32,
                    help="local steps per client per round")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calibrated", action="store_true",
                    help="add a paper-2080ti column on the measurement-"
                         "calibrated registry and assert the anchor check")
    args = ap.parse_args()

    archs = [a for a in args.archs.split(",") if a]
    if not archs:
        archs = ["distilbert-mlm"] if args.tiny else sorted(all_configs())
    rounds = 2 if args.tiny else args.rounds
    seq = 32 if args.tiny else args.seq
    presets = [HOMOGENEOUS, HETEROGENEOUS] + ([CALIBRATED] if args.calibrated
                                              else [])

    if args.calibrated:
        chk = anchor_check(args.clients, args.seed)
        print(f"anchor_check,{CALIBRATED},pred={chk['pred_round_s']:.1f}s,"
              f"measured={chk['measured_round_s']:.1f}s,"
              f"rel_err={chk['rel_err']:.3f}")

    print("arch,fleet,fdapt_round_s,ffdapt_round_s,fdapt_overlap_round_s,"
          "wallclock_saving_pct,flop_saving_pct")
    rows = []
    for arch in archs:
        row = arch_row(arch, clients=args.clients, rounds=rounds,
                       steps=args.steps, batch=args.batch, seq=seq,
                       seed=args.seed, calibrated=args.calibrated)
        rows.append(row)
        for preset in presets:
            r = row[preset]
            print(f"{arch},{preset},{r['fdapt_round_s']:.4f},"
                  f"{r['ffdapt_round_s']:.4f},"
                  f"{r['fdapt_overlap_round_s']:.4f},"
                  f"{r['wallclock_saving_pct']:.1f},"
                  f"{row['flop_saving_pct']:.1f}")
    print(f"overlap_le_sequential,all,{len(rows)}_configs_ok")
    for preset in presets:
        mean_w = float(np.mean([r[preset]["wallclock_saving_pct"]
                                for r in rows]))
        print(f"mean_wallclock_saving_pct[{preset}],{mean_w:.1f}")
    print(f"mean_flop_saving_pct,"
          f"{float(np.mean([r['flop_saving_pct'] for r in rows])):.1f}")
    # the paper's 12.1% is its measured COMPUTE-efficiency improvement
    # (2x RTX 2080 Ti) — the reference for the flop row, not the fleet rows
    print("paper_reported_flop_saving_pct,12.1")


if __name__ == "__main__":
    main()
