"""Kernel + train-step entries of the perf trajectory.

Drives the SAME case registry the conformance pytest suite sweeps
(``repro.conformance.CASES``) — correctness always, timing per case — and
prices the train hot path through the existing round engine + analytic
telemetry.  Two trajectory files:

  * ``BENCH_kernels.json`` — one row per conformance case: forward / VJP /
    chain violation ratios against the ``kernels/ref.py`` oracles, plus
    jit'd kernel-vs-ref wall-clock.  **Interpret-mode-aware**: on a
    non-TPU backend the Pallas kernels run interpreted (Python-stepped),
    so speed ratios are recorded for the record but only *asserted* when
    ``interpret`` is false; correctness is asserted unconditionally.
  * ``BENCH_train.json`` — warm-round wall-clock of a tiny ``FedSession``
    (parallel engine), the analytic per-client step cost
    (``telemetry.client_step_cost``), and a measured-vs-predicted drift
    row (``obs.DriftMonitor`` against a device-roofline prediction).

Both validate under ``scripts/bench_check.py`` (schemas ``kernels`` /
``train_step``).

    PYTHONPATH=src python benchmarks/kernel_bench.py                # full
    PYTHONPATH=src python benchmarks/kernel_bench.py --tiny \
        --out /tmp/k.json --train-out /tmp/t.json                   # CI smoke

``--tiny`` runs one fp32 lattice case per kernel plus one chain case per
scan (correctness + timing, 1 rep) and a 2-round train session — the
producer-rot leg of ``scripts/kernel_smoke.sh``.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro import conformance as cf          # noqa: E402
from repro import obs, optim, telemetry      # noqa: E402
from repro.configs import get_config         # noqa: E402
from repro.core.noniid import make_client_pool        # noqa: E402
from repro.core.rounds import FedSession, RoundPlan   # noqa: E402
from repro.core.strategy import FedAvg       # noqa: E402
from repro.data.corpus import generate_corpus         # noqa: E402
from repro.models.model import init_model    # noqa: E402
from repro.nn import param as P              # noqa: E402
from repro.serve import write_bench          # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# speed floor asserted per kernel (median fp32 speedup) — ONLY off-interpret
COMPILED_SPEEDUP_FLOOR = 1.0


def tiny_cases():
    """One fp32 lattice case per kernel + one chain case per scan."""
    picked = []
    for kernel in cf.KERNEL_NAMES:
        for c in cf.iter_cases(kernel=kernel, tags=("lattice",)):
            if c.dtype == "float32":
                picked.append(c)
                break
    for kernel in ("rwkv6_scan", "mamba2_scan"):
        picked.append(cf.iter_cases(kernel=kernel, tags=("chain",))[0])
    return picked


def kernels_payload(cases, *, reps: int, grid: str) -> dict:
    results = cf.run_grid(cases, timed=True, reps=reps,
                          progress=lambda r: print(
                              f"  {r.name}: ok={r.ok} "
                              f"fwd={r.fwd_violation:.3f} "
                              f"kernel={r.kernel_ms:.2f}ms "
                              f"ref={r.ref_ms:.2f}ms", flush=True))
    bad = [r.name for r in results if not r.ok]
    assert not bad, f"conformance failures: {bad}"

    summary = cf.summarize(results)
    med = {}
    for kernel in cf.KERNEL_NAMES:
        sp = sorted(r.speedup for r in results
                    if r.kernel == kernel and r.dtype == "float32"
                    and r.speedup)
        if sp:
            med[kernel] = round(sp[len(sp) // 2], 4)
    summary["median_fp32_speedup"] = med

    interp = cf.interpret_mode()
    if not interp:       # compiled backend: the wins are load-bearing
        slow = {k: v for k, v in med.items() if v < COMPILED_SPEEDUP_FLOOR}
        assert not slow, f"compiled kernels slower than ref: {slow}"

    return {
        "benchmark": "kernels",
        "grid": grid,
        "backend": jax.default_backend(),
        "interpret": interp,
        "jax_version": jax.__version__,
        "tolerance_ladder": cf.ladder(),
        "summary": summary,
        "rows": [r.to_row() for r in results],
        "note": "violations are max |got-want|/(atol+rtol*|want|) vs the "
                "kernels/ref.py oracle (<=1 passes); speed ratios under "
                "interpret=true are Python-stepped Pallas and NOT asserted "
                "— see docs/kernels.md",
    }


def train_payload(*, arch: str, cohort: int, rounds: int, batch: int,
                  seq: int, steps: int, seed: int, device: str) -> dict:
    cfg = get_config(arch).reduced()
    optimizer = optim.adam(1e-3)
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    corpus = generate_corpus(40, seed=seed)
    pool = make_client_pool(corpus, cfg, n_clients=cohort, pool=2,
                            batch=batch, seq=seq, seed=seed, limit=steps)
    plan = RoundPlan(n_rounds=rounds, engine="parallel", seed=seed,
                     telemetry=True)
    _, hist = FedSession(cfg, optimizer, plan).run(params0, pool)
    warm = min(h.round_time_s for h in hist[1:])
    rr = hist[-1]

    cost = telemetry.client_step_cost(
        cfg, optimizer, FedAvg(), telemetry.train_batch_struct(cfg, batch,
                                                               seq))
    mon = obs.DriftMonitor()
    rec = mon.observe_round(rr, device=device)

    return {
        "benchmark": "train_step",
        "arch": cfg.name,
        "engine": "parallel",
        "cohort": cohort,
        "local_steps": steps,
        "batch": batch,
        "seq": seq,
        "rounds_timed": rounds,
        "warm_round_s": round(warm, 6),
        "clients_per_s": round(cohort / warm, 2),
        "step_cost": {"flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
                      "collective_bytes": cost.collective_bytes},
        "drift": {"phase": rec.phase, "measured_s": rec.measured_s,
                  "predicted_s": rec.predicted_s, "ratio": rec.ratio,
                  "source": rec.source, "warn": rec.warn,
                  "device": device},
        "note": "warm-round host wall-clock vs the %s roofline prediction; "
                "the drift row is recorded, not asserted (host is not the "
                "modeled device)" % device,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one lattice case per kernel + scan "
                         "chain cases, 1 timing rep, 2-round train session")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--arch", default="distilbert-mlm")
    ap.add_argument("--cohort", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--device", default="rtx2080ti",
                    help="sim.fleet preset used for the drift prediction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-train", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"))
    ap.add_argument("--train-out",
                    default=os.path.join(ROOT, "BENCH_train.json"))
    ap.add_argument("--trace-out", default="",
                    help="enable the span tracer; per-case "
                         "conformance.case spans land in this Chrome trace")
    args = ap.parse_args()

    if args.trace_out:
        obs.enable()

    if not args.skip_kernels:
        if args.tiny:
            cases, reps, grid = tiny_cases(), 1, "tiny"
        else:
            cases, reps, grid = cf.CASES, args.reps, "full"
        print(f"kernel grid: {len(cases)} cases "
              f"(interpret={cf.interpret_mode()})")
        payload = kernels_payload(cases, reps=reps, grid=grid)
        print(f"wrote {write_bench(args.out, payload)}")

    if not args.skip_train:
        rounds = 2 if args.tiny else args.rounds
        payload = train_payload(arch=args.arch, cohort=args.cohort,
                                rounds=rounds, batch=2, seq=32, steps=1,
                                seed=args.seed, device=args.device)
        print(f"warm round {payload['warm_round_s']}s "
              f"({payload['clients_per_s']} clients/s)")
        print(f"wrote {write_bench(args.train_out, payload)}")

    if args.trace_out:
        print(f"chrome trace: {obs.get_tracer().export(args.trace_out)}")


if __name__ == "__main__":
    main()
