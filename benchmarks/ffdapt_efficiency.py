"""Eq. 1 / the 12.1% claim: FFDAPT computational-efficiency benchmark.

Two measurements, matching §4.2:
  * WALL  — measured round time for FDAPT vs FFDAPT (static freeze windows)
    on the reduced DistilBERT, I = (T - T_F) / T_F * 100%.
  * LEDGER — analytic backward-FLOP saving from the Algorithm-1 schedule at
    the PAPER'S OWN scale (full DistilBERT, 2 clients, equal data,
    gamma=1): frozen layers skip their dW (~half the backward, which is
    ~2/3 of a step), embeddings/head stay trainable.

The paper reports 12.1% average wall-time improvement on 2x RTX 2080 Ti; the
ledger bound is what the schedule makes *possible*, the wall number is what
this host realizes.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core import ffdapt
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P


def ledger(arch: str = "distilbert-mlm", clients: int = 2, rounds: int = 15,
           gamma: float = 1.0):
    cfg = get_config(arch)
    sizes = [1] * clients
    sched = ffdapt.schedule(cfg.n_layers, sizes, rounds, gamma=gamma)
    # share of step FLOPs inside the freezable stack (vs embeddings/head):
    # per-layer params vs total params
    from repro.launch.dryrun import count_params_split
    total, _ = count_params_split(cfg)
    layer_params = 12 * cfg.d_model ** 2 * cfg.n_layers   # attn+mlp approx
    layer_share = min(1.0, layer_params / total)
    savings = [ffdapt.backward_flop_saving(cfg.n_layers, rnd,
                                           layer_share=layer_share)
               for rnd in sched]
    return float(np.mean(savings)), layer_share


def wall(reps: int = 3, rounds: int = 2, steps: int = 6, seed: int = 0):
    """Interleaved A/B/A/B round-time measurement (cancels host drift).
    Warm-up pass first so every distinct freeze-window program is compiled
    before any timed round (rotation reuses at most N programs)."""
    cfg = get_config("distilbert-mlm").reduced().replace(n_layers=6)
    docs = generate_corpus(120, seed=seed)
    ds = make_client_datasets(docs, cfg, k=2, batch=2, seq=128)
    batches = [b[:steps] for b in ds["batches"]]
    params = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    opt = optim.adam(5e-5)            # single instance -> step-cache hits

    def one(ffd):
        _, hist = FedSession(cfg, opt, n_rounds=rounds,
                             client_sizes=ds["sizes"],
                             ffdapt=ffd).run(params, batches)
        return [h.round_time_s for h in hist]

    one(None), one(ffdapt.FFDAPTConfig(gamma=1.0))       # compile warmup
    plain, frozen = [], []
    for _ in range(reps):
        plain += one(None)
        frozen += one(ffdapt.FFDAPTConfig(gamma=1.0))
    t_plain, t_frozen = float(np.median(plain)), float(np.median(frozen))
    return t_plain, t_frozen, (t_plain - t_frozen) / t_frozen * 100.0


def main():
    mean_saving, share = ledger()
    print("metric,value")
    print(f"ledger_backward_dw_saving_frac,{mean_saving:.4f}")
    print(f"ledger_layer_flop_share,{share:.4f}")
    # dW saving as a share of the whole step (fwd+bwd = 3 fwd-units):
    print(f"ledger_step_saving_pct,{mean_saving * 100:.1f}")
    t_plain, t_frozen, imp = wall()
    print(f"wall_fdapt_round_s,{t_plain:.3f}")
    print(f"wall_ffdapt_round_s,{t_frozen:.3f}")
    print(f"wall_efficiency_improvement_pct,{imp:.1f}")
    print(f"paper_reported_pct,12.1")


if __name__ == "__main__":
    main()
