"""Eq. 1 / the 12.1% claim: FFDAPT computational-efficiency benchmark.

Three measurements, matching §4.2:
  * WALL    — measured round time for FDAPT vs FFDAPT (static freeze
    windows) on the reduced DistilBERT, I = (T - T_F) / T_F * 100%.
  * LEDGER  — analytic backward-FLOP saving from the Algorithm-1 schedule at
    the PAPER'S OWN scale (full DistilBERT, 2 clients, equal data,
    gamma=1): frozen layers skip their dW (~half the backward, which is
    ~2/3 of a step), embeddings/head stay trainable.
  * HLO     — the cost-model figure: per-arch compiled-step dot FLOPs
    (``repro.telemetry``, scan-aware) for the plain step vs the mean over
    the FFDAPT schedule's frozen-window steps — the compute saving XLA
    actually realizes, reported for EVERY config in the zoo without
    compiling anything unrolled.
  * PEFT    — LoRA/adapter columns for the same table: per-client comm
    (bank vs dense tree) and the analytic step-FLOP saving of freezing the
    base, so the paper's 12.1% sits next to what the low-rank family buys.

The paper reports 12.1% average wall-time improvement on 2x RTX 2080 Ti; the
ledger bound is what the schedule makes *possible*, the HLO figure is what
the compiled programs realize, the wall number is what this host measures.

    PYTHONPATH=src python benchmarks/ffdapt_efficiency.py [--tiny]
        [--archs distilbert-mlm,qwen2-7b] [--skip-wall]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import optim, telemetry
from repro.configs import all_configs, get_config
from repro.core import ffdapt
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.core.strategy import FedAvg
from repro.data.corpus import generate_corpus
from repro.models.model import init_model, n_freeze_units
from repro.nn import param as P


def ledger(arch: str = "distilbert-mlm", clients: int = 2, rounds: int = 15,
           gamma: float = 1.0):
    cfg = get_config(arch)
    sizes = [1] * clients
    sched = ffdapt.schedule(cfg.n_layers, sizes, rounds, gamma=gamma)
    # share of step FLOPs inside the freezable stack (vs embeddings/head):
    # per-layer params vs total params
    from repro.launch.dryrun import count_params_split
    total, _ = count_params_split(cfg)
    layer_params = 12 * cfg.d_model ** 2 * cfg.n_layers   # attn+mlp approx
    layer_share = min(1.0, layer_params / total)
    savings = [ffdapt.backward_flop_saving(cfg.n_layers, rnd,
                                           layer_share=layer_share)
               for rnd in sched]
    return float(np.mean(savings)), layer_share


def hlo_ledger(archs=None, clients: int = 2, rounds: int = 15,
               gamma: float = 1.0, epsilon: int = 0, batch: int = 2,
               seq: int = 64):
    """Per-arch compiled-step compute saving from the telemetry cost model:
    dot FLOPs of the plain client step vs the mean over the FFDAPT
    schedule's (round x client) frozen-window steps.  Reduced configs — the
    RELATIVE saving is shape-stable, and every distinct window compiles once
    (cached), so the whole zoo runs on a CPU host in minutes."""
    opt = optim.adam(5e-5)
    strat = FedAvg()
    rows = []
    for arch in archs or sorted(all_configs()):
        cfg = get_config(arch).reduced()
        batch_sds = telemetry.train_batch_struct(cfg, batch, seq)
        base = telemetry.client_step_cost(cfg, opt, strat, batch_sds).flops
        n_units = n_freeze_units(cfg)
        sched = ffdapt.schedule(n_units, [1] * clients, rounds,
                                epsilon=epsilon, gamma=gamma)
        tot, cnt = 0.0, 0
        for rnd in sched:
            for win in rnd:
                frozen = ffdapt.window_mask(n_units, win)
                tot += telemetry.client_step_cost(cfg, opt, strat, batch_sds,
                                                  frozen=frozen).flops
                cnt += 1
        saving = (base * cnt - tot) / (base * cnt) * 100.0
        rows.append((arch, base, saving))
    return rows


def peft_ledger(archs=None, rank: int = 4, bottleneck: int = 8):
    """LoRA/adapter columns next to FFDAPT's: per-client upload vs the dense
    tree (the bank IS the wire format under a low-rank
    ``RoundPlan.param_space``) and the analytic share of step FLOPs the
    frozen base removes — backward dW work scales with the trainable
    fraction, dW ~ half of backward ~ 2/3 of a step, the same accounting
    behind the FFDAPT ledger's bound.  Allocation-free (eval_shape)."""
    from repro.core.strategy import tree_bytes
    from repro.peft import adapter, lora
    rows = []
    for arch in archs or ["distilbert-mlm"]:
        cfg = get_config(arch).reduced()
        params = jax.eval_shape(
            lambda k: P.unbox(init_model(k, cfg)), jax.random.PRNGKey(0))
        dense = tree_bytes(params)
        for sp in (lora(rank), adapter(bottleneck)):
            bank = jax.eval_shape(
                lambda p: sp.inject(p, jax.random.PRNGKey(0)), params)
            frac = sp.trainable_fraction(params, bank=bank)
            saving = (1.0 - frac) * (2.0 / 3.0) * 0.5 * 100.0
            rows.append((arch, f"{sp.kind}_r{sp.rank}", dense / 2**20,
                         tree_bytes(bank) / 2**20, dense / tree_bytes(bank),
                         saving))
    return rows


def wall(reps: int = 3, rounds: int = 2, steps: int = 6, seed: int = 0):
    """Interleaved A/B/A/B round-time measurement (cancels host drift).
    Warm-up pass first so every distinct freeze-window program is compiled
    before any timed round (rotation reuses at most N programs)."""
    cfg = get_config("distilbert-mlm").reduced().replace(n_layers=6)
    docs = generate_corpus(120, seed=seed)
    ds = make_client_datasets(docs, cfg, k=2, batch=2, seq=128)
    batches = [b[:steps] for b in ds["batches"]]
    params = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    opt = optim.adam(5e-5)            # single instance -> step-cache hits

    def one(ffd):
        _, hist = FedSession(cfg, opt, n_rounds=rounds,
                             client_sizes=ds["sizes"],
                             ffdapt=ffd).run(params, batches)
        return [h.round_time_s for h in hist]

    one(None), one(ffdapt.FFDAPTConfig(gamma=1.0))       # compile warmup
    plain, frozen = [], []
    for _ in range(reps):
        plain += one(None)
        frozen += one(ffdapt.FFDAPTConfig(gamma=1.0))
    t_plain, t_frozen = float(np.median(plain)), float(np.median(frozen))
    return t_plain, t_frozen, (t_plain - t_frozen) / t_frozen * 100.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke mode: 1 arch, short schedule, no wall timing")
    ap.add_argument("--archs", default="",
                    help="comma-separated arch subset for the HLO ledger")
    ap.add_argument("--skip-wall", action="store_true")
    args = ap.parse_args()

    mean_saving, share = ledger()
    print("metric,value")
    print(f"ledger_backward_dw_saving_frac,{mean_saving:.4f}")
    print(f"ledger_layer_flop_share,{share:.4f}")
    # dW saving as a share of the whole step (fwd+bwd = 3 fwd-units):
    print(f"ledger_step_saving_pct,{mean_saving * 100:.1f}")

    archs = [a for a in args.archs.split(",") if a] or None
    if args.tiny and archs is None:
        archs = ["distilbert-mlm"]
    rows = hlo_ledger(archs=archs, rounds=3 if args.tiny else 15,
                      seq=32 if args.tiny else 64)
    print("arch,step_gflops_hlo,ffdapt_compute_saving_pct")
    for arch, flops, saving in rows:
        print(f"{arch},{flops / 1e9:.3f},{saving:.1f}")
    print(f"hlo_mean_compute_saving_pct,"
          f"{float(np.mean([r[2] for r in rows])):.1f}")
    print("paper_reported_pct,12.1")

    print("arch,space,dense_MB,bank_MB,comm_reduction_x,"
          "analytic_step_saving_pct")
    for arch, space, dense, bank, ratio, saving in peft_ledger(archs=archs):
        print(f"{arch},{space},{dense:.1f},{bank:.3f},{ratio:.1f},"
              f"{saving:.1f}")

    if not (args.tiny or args.skip_wall):
        t_plain, t_frozen, imp = wall()
        print(f"wall_fdapt_round_s,{t_plain:.3f}")
        print(f"wall_ffdapt_round_s,{t_frozen:.3f}")
        print(f"wall_efficiency_improvement_pct,{imp:.1f}")


if __name__ == "__main__":
    main()
