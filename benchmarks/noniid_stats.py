"""Table 3 analogue: data-distribution statistics under IID + three skews.

Reproduces the paper's Appendix D table on the synthetic corpus: for 2 and 8
clients, the mean and cross-client sigma of (quantity, mean sentence length,
vocabulary) per skew — each skew maximizing its own sigma, pinning others.
"""

from __future__ import annotations

from repro.data.corpus import generate_corpus
from repro.data.partition import SKEWS, client_stats_table, partition


def run(n_docs: int = 480, seed: int = 0):
    docs = generate_corpus(n_docs, seed=seed)
    rows = []
    for k in (2, 8):
        for skew in SKEWS:
            t = client_stats_table(partition(docs, k, skew, seed=seed))
            rows.append({
                "clients": k, "skew": skew,
                "q_mean": t["quantity"]["mean"],
                "q_sigma": t["quantity"]["sigma"],
                "len_mean": t["mean_sentence_length"]["mean"],
                "len_sigma": t["mean_sentence_length"]["sigma"],
                "vocab_mean": t["unique_words"]["mean"],
                "vocab_sigma": t["unique_words"]["sigma"],
                "docvocab_sigma": t["doc_vocab"]["sigma"],
            })
    return rows


def main():
    print("clients,skew,Q_mean,Q_sigma,L_mean,L_sigma,V_mean,V_sigma,Vdoc_sigma")
    for r in run():
        print(f"{r['clients']},{r['skew']},{r['q_mean']:.0f},{r['q_sigma']:.1f},"
              f"{r['len_mean']:.1f},{r['len_sigma']:.2f},"
              f"{r['vocab_mean']:.0f},{r['vocab_sigma']:.0f},"
              f"{r['docvocab_sigma']:.1f}")


if __name__ == "__main__":
    main()
