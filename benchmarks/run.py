"""Benchmark harness — one entry per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run            # quick grid
    PYTHONPATH=src python -m benchmarks.run --full     # full Table-2 grid

Sections:
  [noniid_stats]      Table 3  — partitioner sigma table
  [ffdapt_efficiency] Eq. 1    — 12.1%-claim: wall + analytic ledger
  [fdapt_parity]      Table 2  — parity grid (proxy: held-out MLM loss)
  [roofline]          §Roofline — from the dry-run artifacts (run
                      `python -m repro.launch.dryrun --all` first)
"""

from __future__ import annotations

import sys
import time


def _section(name):
    print(f"\n[{name}]")


def main() -> None:
    full = "--full" in sys.argv
    t0 = time.perf_counter()

    _section("noniid_stats")
    from benchmarks import noniid_stats
    noniid_stats.main()

    _section("ffdapt_efficiency")
    from benchmarks import ffdapt_efficiency
    ffdapt_efficiency.main()

    _section("fdapt_parity")
    from benchmarks import fdapt_parity
    fdapt_parity.main(quick=not full)

    _section("ffdapt_ablation")
    from benchmarks import ffdapt_ablation
    ffdapt_ablation.main()

    _section("comm_efficiency")
    from benchmarks import comm_efficiency
    comm_efficiency.main()

    _section("roofline")
    from benchmarks import roofline
    try:
        roofline.main()
    except Exception as e:  # artifacts absent until the dry-run has been run
        print(f"skipped,{type(e).__name__}: {e}")

    print(f"\ntotal_seconds,{time.perf_counter() - t0:.1f}")


if __name__ == "__main__":
    main()
