"""Serving benchmark: continuous batching vs the static-batch baseline at
equal offered load, served from a REAL federated checkpoint.

The point of FDAPT is the model you serve afterwards, so the benchmark
closes the loop: it runs (or reuses) a ``FedSession`` training run, loads
the aggregated global params through ``repro.serve.loader``, and drives
both decode paths with the SAME seeded open-loop Poisson arrival trace and
per-request stop lengths.  Three numbers matter:

  * ``throughput_ratio`` — continuous over static tokens/s.  Requests stop
    at heterogeneous lengths; the engine refills freed slots mid-flight
    while the static batch decodes to its longest member and waits for
    batches to form, so the ratio should be >= 1.
  * ``parity_bitwise`` — per-request outputs of the two paths compared
    token-for-token.  Same sampler, same (seed, position) keys => must be
    True; the benchmark fails loudly if not.
  * the per-mode latency breakdown (TTFT / p50 / p99, occupancies).

    PYTHONPATH=src python benchmarks/serving.py --tiny
    PYTHONPATH=src python benchmarks/serving.py --tiny --rates 5,20,80
    PYTHONPATH=src python benchmarks/serving.py --ckpt-dir runs/fed/ckpts

``--tiny`` is the CI smoke: a 2-round qwen2-7b run at shrunken width into a
temp dir, ~200 decode steps total, asserts ratio >= 1 and parity, writes
``BENCH_serve.json`` (the committed perf-trajectory file).
"""

from __future__ import annotations

import argparse
import os
import tempfile

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus
from repro.core.noniid import make_client_datasets
from repro.models.model import init_model
from repro.nn import param as P
from repro.serve import (DecodeEngine, EngineConfig, PoissonArrivals,
                         load_serving_params, run_static, synthetic_requests,
                         write_bench)


def shrink(cfg):
    """Sub-reduced() width for the smoke: decode steps in milliseconds."""
    return cfg.reduced().replace(d_model=128, n_heads=2, n_kv_heads=1,
                                 head_dim=64, d_ff=256, vocab_size=512)


def train_checkpoint(cfg, ckpt_dir: str, *, n_rounds: int, seed: int) -> None:
    """A real (tiny) FDAPT run whose round checkpoints land in ckpt_dir."""
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    docs = generate_corpus(24, seed=seed)
    ds = make_client_datasets(docs, cfg, k=2, batch=2, seq=16, seed=seed)
    batches = [b[:2] for b in ds["batches"]]
    session = FedSession(
        cfg, optim.adam(1e-3), n_rounds=n_rounds, telemetry=False,
        checkpoint_dir=ckpt_dir,
        fingerprint_extra={"arch": cfg.name, "bench": "serving"})
    session.run(params0, batches)


def measure(cfg, params, requests, *, n_slots, cache_len, impl):
    """Both decode paths over (copies of) the same request trace."""
    engine = DecodeEngine(cfg, params,
                          EngineConfig(n_slots=n_slots, cache_len=cache_len,
                                       impl=impl))
    out_c, sum_c = engine.run([r.replace() for r in requests])
    assert engine.decode_cache_size() == 1, "decode program recompiled"
    out_s, sum_s = run_static(cfg, params, [r.replace() for r in requests],
                              n_slots=n_slots, cache_len=cache_len, impl=impl)
    parity = all(np.array_equal(out_c[r.rid], out_s[r.rid])
                 for r in requests)
    ratio = sum_c["tokens_per_s"] / max(sum_s["tokens_per_s"], 1e-9)
    return {"continuous": sum_c, "static": sum_s,
            "throughput_ratio": round(ratio, 4), "parity_bitwise": parity}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: shrunken width, 2 training rounds, "
                         "asserts ratio >= 1 and bitwise parity")
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve an existing checkpoint instead of training")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--min-tokens", type=int, default=8)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="offered load, requests/s (both modes see the "
                         "same arrival trace)")
    ap.add_argument("--rates", default=None,
                    help="comma-separated rate sweep; summary rows land "
                         "under 'sweep'")
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serve.json"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cfg = shrink(cfg) if args.tiny else cfg.reduced()

    tmp = None
    ckpt_dir = args.ckpt_dir
    if ckpt_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve_bench_")
        ckpt_dir = os.path.join(tmp.name, "ckpts")
        print(f"training {args.rounds}-round FedSession ({cfg.name}) ...")
        train_checkpoint(cfg, ckpt_dir, n_rounds=args.rounds, seed=args.seed)
    params, step, fed = load_serving_params(ckpt_dir, cfg)
    n_hist = len(fed.history) if fed else 0
    print(f"serving checkpoint step {step} ({n_hist} recorded rounds)")

    cache_len = args.prompt_len + args.tokens
    rng = np.random.default_rng(args.seed)
    requests = synthetic_requests(
        cfg, args.requests, prompt_len=args.prompt_len, rng=rng,
        max_new_tokens=args.tokens, min_new_tokens=args.min_tokens,
        temperature=args.temperature, seed=args.seed)

    rates = ([float(r) for r in args.rates.split(",")] if args.rates
             else [args.rate])
    sweep = []
    for rate in rates:
        reqs = PoissonArrivals(rate, seed=args.seed).assign(requests)
        res = measure(cfg, params, reqs, n_slots=args.slots,
                      cache_len=cache_len, impl=args.impl)
        print(f"rate {rate:g} rps: continuous "
              f"{res['continuous']['tokens_per_s']:.1f} tok/s, static "
              f"{res['static']['tokens_per_s']:.1f} tok/s, ratio "
              f"{res['throughput_ratio']:.2f}, parity "
              f"{res['parity_bitwise']}")
        sweep.append({"rate_rps": rate, **res})

    head = sweep[0]
    payload = {
        "benchmark": "serve",
        "arch": cfg.name,
        "arch_type": cfg.arch_type,
        "checkpoint": {"dir": "<temp>" if tmp else ckpt_dir, "step": step,
                       "rounds_recorded": n_hist},
        "engine": {"n_slots": args.slots, "cache_len": cache_len,
                   "impl": args.impl},
        "workload": {"requests": args.requests,
                     "prompt_len": args.prompt_len,
                     "max_new_tokens": args.tokens,
                     "min_new_tokens": args.min_tokens,
                     "rate_rps": rates[0],
                     "temperature": args.temperature, "seed": args.seed},
        "modes": {"continuous": head["continuous"],
                  "static": head["static"]},
        "throughput_ratio": head["throughput_ratio"],
        "parity_bitwise": head["parity_bitwise"],
    }
    if len(sweep) > 1:
        payload["sweep"] = sweep
    write_bench(args.out, payload)
    print(f"wrote {args.out}")

    if args.tiny:
        assert head["parity_bitwise"], \
            "continuous/static outputs diverged (bitwise)"
        assert head["throughput_ratio"] >= 1.0, \
            f"continuous slower than static: {head['throughput_ratio']}"
        print("OK (parity bitwise, ratio >= 1)")
    if tmp:
        tmp.cleanup()


if __name__ == "__main__":
    main()
