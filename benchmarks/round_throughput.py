"""Round engine throughput: cohort-scan vs stacked-vmap.

One sampled federated round over cohort sizes {8, 64, 512} (tiny config,
one local step per client, lazy 4-shard data pool), timed on the warm
(already-compiled) round for both parallel-engine modes:

  * stacked-vmap  — ``cohort_shard=None``: the whole cohort's params, opt
    state and batches live at once (peak memory grows with the cohort);
    measured only up to cohort 64 — the point of the scan engine is that
    the stacked mode stops scaling.
  * cohort-scan   — ``--shard``-wide shards streamed through ONE compiled
    shard program with an O(params) fold carry; peak live buffers are
    O(shard) regardless of cohort size.

Both modes produce bitwise-identical params (tests/test_cohort.py pins
that); this benchmark records the throughput/memory side of the trade:
clients/s, per-client step FLOPs (compiled-program analysis), aggregate
wire bytes, and an analytic peak-live-bytes proxy (live clients x
(params + opt state + batches) + the fold carry).  Results land in
``BENCH_round.json`` — the second entry in the ``BENCH_<area>.json``
perf trajectory (after ``BENCH_serve.json``).

    PYTHONPATH=src python benchmarks/round_throughput.py           # full
    PYTHONPATH=src python benchmarks/round_throughput.py --tiny    # CI smoke

``--tiny`` trims the sweep to cohorts {8, 64} and asserts the scan
engine's warm-round throughput is no worse than stacked-vmap at
cohort 64 (the crossover the ISSUE pins).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.noniid import make_client_pool
from repro.core.rounds import FedSession, RoundPlan, _shard_widths
from repro.core.strategy import tree_bytes
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P
from repro.serve import write_bench

COHORTS = (8, 64, 512)
STACKED_MAX = 64          # stacked-vmap measured only up to this cohort


def _batch_bytes(batch) -> int:
    return sum(np.asarray(v).nbytes for v in jax.tree.leaves(batch))


def peak_live_bytes(width: int, params_bytes: int, opt_bytes: int,
                    batch_block_bytes: int) -> int:
    """Analytic peak proxy for one shard program invocation: ``width``
    stacked replicas of (params + opt state + one epoch of batches), plus
    the global params broadcast source and the fp32 fold carry."""
    f32_params = params_bytes  # reduced configs train in fp32 already
    return width * (params_bytes + opt_bytes + batch_block_bytes) \
        + params_bytes + f32_params


def run_round(cfg, params0, pool, *, cohort_shard, rounds, seed):
    """Time a short FedSession; returns (warm_round_s, last RoundResult)."""
    plan = RoundPlan(n_rounds=rounds, engine="parallel",
                     cohort_shard=cohort_shard, seed=seed, telemetry=True)
    _, hist = FedSession(cfg, optim.adam(1e-3), plan).run(params0, pool)
    # round 1 pays the compile; the steady-state rounds are the number
    warm = min(h.round_time_s for h in hist[1:])
    return warm, hist[-1]


def sweep(cfg, *, cohorts, shard, pool_shards, docs, steps, rounds, seed):
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    params_bytes = tree_bytes(params0)
    opt_bytes = tree_bytes(optim.adam(1e-3).init(params0))
    corpus = generate_corpus(docs, seed=seed)

    rows = []
    for cohort in cohorts:
        pool = make_client_pool(corpus, cfg, n_clients=cohort,
                                pool=pool_shards, batch=2, seq=32,
                                seed=seed, limit=steps)
        batch_block = _batch_bytes(pool.batches_for(0)[0]) * steps
        row = {"cohort": cohort}

        for mode, cs in (("stacked_vmap", None), ("cohort_scan", shard)):
            if cs is None and cohort > STACKED_MAX:
                row[mode] = None      # O(cohort) live buffers: not measured
                continue
            warm_s, rr = run_round(cfg, params0, pool, cohort_shard=cs,
                                   rounds=rounds, seed=seed)
            width = max(_shard_widths(cohort, cs))
            row[mode] = {
                "round_s": round(warm_s, 6),
                "clients_per_s": round(cohort / warm_s, 2),
                "step_flops_per_client": rr.client_step_flops[0],
                "aggregate_upload_bytes": rr.upload_bytes,
                "aggregate_download_bytes": rr.download_bytes,
                "peak_live_bytes_proxy": peak_live_bytes(
                    width, params_bytes, opt_bytes, batch_block),
            }
        s, c = row.get("stacked_vmap"), row.get("cohort_scan")
        if s and c:
            row["scan_over_stacked_throughput"] = round(
                c["clients_per_s"] / s["clients_per_s"], 4)
            row["scan_over_stacked_peak_mem"] = round(
                c["peak_live_bytes_proxy"] / s["peak_live_bytes_proxy"], 4)
        rows.append(row)
        print(f"cohort {cohort:4d}: " + "  ".join(
            f"{m}={row[m]['clients_per_s']:.1f} cl/s" if row[m] else f"{m}=–"
            for m in ("stacked_vmap", "cohort_scan")))
    return rows, params_bytes, opt_bytes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="distilbert-mlm")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: cohorts {8, 64} only, asserts "
                         "cohort-scan >= stacked-vmap clients/s at 64")
    ap.add_argument("--shard", type=int, default=8,
                    help="cohort-scan shard width")
    ap.add_argument("--pool", type=int, default=4,
                    help="lazy data-pool shards backing the population")
    ap.add_argument("--docs", type=int, default=60)
    ap.add_argument("--steps", type=int, default=1,
                    help="local steps per client per round")
    ap.add_argument("--rounds", type=int, default=3,
                    help="rounds per timed session (first pays compile)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_round.json"))
    ap.add_argument("--trace-out", default="",
                    help="enable the span tracer and write the sweep's "
                         "Chrome trace-event JSON here (per-shard dispatch "
                         "and compile spans; load in Perfetto)")
    args = ap.parse_args()

    if args.trace_out:
        from repro import obs
        obs.enable()
        obs.capture_compiles()

    cfg = get_config(args.arch).reduced()
    cohorts = tuple(c for c in COHORTS if c <= 64) if args.tiny else COHORTS
    rows, params_bytes, opt_bytes = sweep(
        cfg, cohorts=cohorts, shard=args.shard, pool_shards=args.pool,
        docs=args.docs, steps=args.steps, rounds=args.rounds, seed=args.seed)

    payload = {
        "benchmark": "round_throughput",
        "arch": cfg.name,
        "engine": "parallel",
        "cohort_shard": args.shard,
        "local_steps": args.steps,
        "params_bytes": params_bytes,
        "opt_state_bytes": opt_bytes,
        "rows": rows,
        "note": "warm-round timings (compile excluded); stacked_vmap null "
                "above cohort %d — its live buffers grow O(cohort) while "
                "cohort_scan stays O(shard)" % STACKED_MAX,
    }
    if not args.tiny:
        path = write_bench(args.out, payload)
        print(f"wrote {path}")

    crossover = [r for r in rows
                 if r["cohort"] >= 64 and r.get("stacked_vmap")]
    if args.tiny:
        assert crossover, "tiny sweep must include the cohort-64 crossover"
        for r in crossover:
            ratio = r["scan_over_stacked_throughput"]
            assert ratio >= 0.9, (
                f"cohort-scan fell behind stacked-vmap at cohort "
                f"{r['cohort']}: ratio {ratio}")
            print(f"tiny OK: cohort {r['cohort']} scan/stacked "
                  f"throughput ratio {ratio}")

    if args.trace_out:
        from repro import obs
        print(f"chrome trace: {obs.get_tracer().export(args.trace_out)}")


if __name__ == "__main__":
    main()
