"""Downstream probe: does FFDAPT / LoRA-FDAPT hurt the adapted model?

The paper's central efficiency claim is only interesting if the cheaper
variants keep downstream quality: Table 2 reports <1% task fluctuation
between FDAPT and FFDAPT.  This benchmark reproduces that comparison with
the repo's synthetic domain and extends it to the ParamSpace family:

  1. Run three federated adaptations of the same init on the same clients —
     FDAPT (dense FedAvg), FFDAPT (rotating freeze windows) and LoRA-FDAPT
     (``RoundPlan.param_space = lora(4)``, clients ship only the bank).
  2. Freeze each result and train a linear probe on top: documents are
     drawn from two disjoint lexicon BANDS (a crude domain-ID task — the
     kind of single-sentence classification GLUE-style suites use), the
     feature is the mean-pooled output logits, the probe is a seeded
     float64 logistic regression (fixed iterations, no early stopping) so
     the accuracy column is bit-reproducible.
  3. Emit ``BENCH_downstream.json``: per-variant accuracy + upload bytes,
     the FDAPT-vs-FFDAPT fluctuation (must stay <1%, the paper's bound)
     and the LoRA upload reduction (must stay >=10x).

    PYTHONPATH=src python benchmarks/downstream.py [--tiny] [--engine ...]
        [--out BENCH_downstream.json]
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core import ffdapt
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan
from repro.core.strategy import FedAvg
from repro.data.corpus import Document, build_lexicon, generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import apply_model, init_model
from repro.nn import param as P
from repro.peft import lora


def probe_documents(n_per_class: int, seq: int, vocab: int, *,
                    seed: int = 0, lexicon_size: int = 12_000):
    """Two-domain classification set.  The bands are defined in TOKEN-ID
    space — class c draws only words whose hashed id lands in its half of
    the vocabulary — because ``HashWordTokenizer`` scatters any
    lexicon-order band uniformly over the ids: a class signal defined on
    raw words would not survive tokenization, one defined on ids does,
    which is exactly the vocabulary-skew axis the paper's D_V partitioner
    manipulates.  Returns (tokens (N, seq) int32, labels (N,) int64)."""
    rng = np.random.default_rng(seed)
    lex = np.asarray(build_lexicon(lexicon_size))
    tok = HashWordTokenizer(vocab)
    word_ids = np.asarray([tok.token(w) for w in lex])
    toks, labels = [], []
    for c in (0, 1):
        lo, hi = (0, vocab // 2) if c == 0 else (vocab // 2, vocab)
        band = lex[(word_ids >= lo) & (word_ids < hi)]
        half = len(band)
        for _ in range(n_per_class):
            # zipfian draw inside the band, like the training corpus —
            # WIDE pools (vs the corpus's 120-2400) so every document
            # covers enough of its band for the class means to be stable
            pool_n = int(rng.integers(2_400, min(6_000, half)))
            off = int(rng.integers(0, half - pool_n))
            pool = band[off:off + pool_n]
            ranks = np.arange(1, pool_n + 1)
            pz = (1.0 / ranks) / np.sum(1.0 / ranks)
            i = int(rng.choice(pool_n, p=pz))
            idx = []
            for _ in range(2 * seq):
                idx.append(i)
                i = int((i + rng.integers(-2, 3)) % pool_n)
            doc = Document([[str(pool[j]) for j in idx]])
            ids = np.asarray(tok.encode_document(doc.sentences), np.int32)
            ids = np.tile(ids, (seq // max(len(ids), 1)) + 1)[:seq]
            toks.append(ids)
            labels.append(c)
    return np.stack(toks), np.asarray(labels, np.int64)


def features(params, cfg, tokens: np.ndarray, batch: int = 8) -> np.ndarray:
    """Mean-pooled output logits per document, under the frozen model."""

    @jax.jit
    def feats(p, t):
        logits, _, _ = apply_model(p, cfg, {"tokens": t})
        return logits.mean(axis=1)

    out = []
    for i in range(0, len(tokens), batch):
        chunk = tokens[i:i + batch]
        n = len(chunk)
        if n < batch:                    # pad the tail to one batch shape
            chunk = np.concatenate([chunk, np.tile(chunk[-1:],
                                                   (batch - n, 1))])
        out.append(np.asarray(feats(params, chunk))[:n])
    return np.concatenate(out).astype(np.float64)


def probe_accuracy(x: np.ndarray, y: np.ndarray, *, seed: int = 0,
                   iters: int = 200, lr: float = 0.5,
                   n_splits: int = 3) -> float:
    """Seeded logistic probe, float64, fixed iteration budget, accuracy
    averaged over ``n_splits`` deterministic train/test splits — the same
    features always produce the same number (no solver nondeterminism) and
    a single document flipping sides moves it by 1/(n_splits * n_test)."""
    accs = []
    for split in range(n_splits):
        rng = np.random.default_rng(seed + split)
        order = rng.permutation(len(x))
        xs, ys = x[order], y[order]
        xs = (xs - xs.mean(0)) / (xs.std(0) + 1e-8)
        n_tr = len(xs) // 2
        xtr, ytr, xte, yte = xs[:n_tr], ys[:n_tr], xs[n_tr:], ys[n_tr:]
        w, b = np.zeros(xs.shape[1]), 0.0
        for _ in range(iters):
            p = 1.0 / (1.0 + np.exp(-(xtr @ w + b)))
            g = p - ytr
            w -= lr * (xtr.T @ g / n_tr + 1e-4 * w)
            b -= lr * float(g.mean())
        pred = (xte @ w + b) > 0.0
        accs.append(float((pred == yte).mean()))
    return float(np.mean(accs))


def run(rounds: int = 3, steps: int = 4, probe_n: int = 96, seq: int = 64,
        seed: int = 0, engine: str = "sequential"):
    cfg = get_config("distilbert-mlm").reduced()
    docs = generate_corpus(160, seed=seed)
    ds = make_client_datasets(docs, cfg, k=2, skew="vocab", batch=2,
                              seq=32, seed=seed)
    batches = [b[:steps] for b in ds["batches"]]
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    opt = optim.adam(1e-3)

    def adapt(name, **plan_kw):
        plan = RoundPlan(n_rounds=rounds, engine=engine,
                         client_sizes=ds["sizes"], strategy=FedAvg(),
                         seed=seed, **plan_kw)
        p, hist = FedSession(cfg, opt, plan).run(params0, batches)
        return name, p, sum(h.upload_bytes for h in hist)

    variants = [
        adapt("fdapt"),
        adapt("ffdapt", ffdapt=ffdapt.FFDAPTConfig(gamma=1.0)),
        adapt("lora_fdapt", param_space=lora(4)),
    ]

    toks, labels = probe_documents(probe_n, seq, cfg.vocab_size, seed=seed)
    rows = []
    for name, p, up in variants:
        acc = probe_accuracy(features(p, cfg, toks), labels, seed=seed)
        rows.append({"model": name, "accuracy": acc,
                     "upload_bytes": int(up)})
    acc_of = {r["model"]: r["accuracy"] for r in rows}
    up_of = {r["model"]: r["upload_bytes"] for r in rows}
    return {
        "benchmark": "downstream",
        "arch": cfg.name,
        "task": "vocab_band_probe",
        "engine": engine,
        "rounds": rounds,
        "local_steps": steps,
        "probe_docs": 2 * probe_n,
        "rows": rows,
        "fluctuation_pct": abs(acc_of["fdapt"] - acc_of["ffdapt"])
        / max(acc_of["fdapt"], 1e-9) * 100.0,
        "lora_upload_reduction_x": up_of["fdapt"] / max(
            up_of["lora_fdapt"], 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke mode: 1 round, 2 local steps, 16 probe docs")
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "parallel"))
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.tiny:
        args.rounds, args.steps = 1, 2
    bench = run(rounds=args.rounds, steps=args.steps,
                probe_n=8 if args.tiny else 64, engine=args.engine)
    print("model,accuracy,upload_MB")
    for r in bench["rows"]:
        print(f"{r['model']},{r['accuracy']:.4f},"
              f"{r['upload_bytes'] / 2**20:.1f}")
    print(f"fdapt_vs_ffdapt_fluctuation_pct,{bench['fluctuation_pct']:.3f}")
    print(f"lora_upload_reduction_x,{bench['lora_upload_reduction_x']:.1f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
