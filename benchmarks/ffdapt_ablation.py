"""FFDAPT gamma / epsilon ablation (the algorithm's two hyper-parameters).

For each (gamma, epsilon): the analytic backward-dW saving from the schedule
(at the paper's full-DistilBERT scale) and the held-out-loss delta vs vanilla
FDAPT at smoke scale — the efficiency/quality frontier Algorithm 1 trades on.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core import ffdapt
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus, split_holdout
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P


def run(rounds: int = 3, steps: int = 4, seed: int = 0):
    cfg = get_config("distilbert-mlm").reduced()
    full = get_config("distilbert-mlm")
    docs, held_docs = split_holdout(generate_corpus(160, seed=seed))
    ds = make_client_datasets(docs, cfg, k=2, skew="iid", batch=2, seq=32,
                              seed=seed)
    batches = [b[:steps] for b in ds["batches"]]
    params0 = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    opt = optim.adam(1e-3)
    eval_step = jax.jit(make_eval_step(cfg))
    held = make_client_datasets(held_docs, cfg, k=1, batch=4,
                                seq=64)["batches"][0][:8]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in held]))

    p_fd, _ = FedSession(cfg, opt, n_rounds=rounds,
                         client_sizes=ds["sizes"]).run(params0, batches)
    base = eval_loss(p_fd)

    rows = [("fdapt", "-", "-", 0.0, base, 0.0)]
    for gamma in (0.5, 1.0, 2.0):
        for eps in (0, 3):                      # 0 -> default N-1
            cfg_f = ffdapt.FFDAPTConfig(gamma=gamma, epsilon=eps)
            # analytic saving at the paper's scale (6 layers, 2 equal clients)
            sched = ffdapt.schedule(full.n_layers, [1, 1], 15,
                                    epsilon=eps, gamma=gamma)
            saving = float(np.mean([
                ffdapt.backward_flop_saving(full.n_layers, rnd)
                for rnd in sched]))
            p, _ = FedSession(cfg, opt, n_rounds=rounds,
                              client_sizes=ds["sizes"],
                              ffdapt=cfg_f).run(params0, batches)
            l = eval_loss(p)
            rows.append(("ffdapt", gamma, eps or "N-1", saving, l,
                         (l - base) / base * 100))
    return rows


def main():
    print("setting,gamma,epsilon,ledger_dw_saving,eval_loss,delta_vs_fdapt_pct")
    for r in run():
        name, g, e, sv, l, d = r
        print(f"{name},{g},{e},{sv:.3f},{l:.4f},{d:+.2f}")


if __name__ == "__main__":
    main()
