"""Training-step mechanics: microbatch equivalence, clipping, optimizers,
loss masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import optim
from repro.configs import get_config
from repro.models.model import init_model
from repro.models.steps import lm_loss, make_eval_step, make_train_step
from repro.nn import param as P

KEY = jax.random.PRNGKey(0)


def test_lm_loss_matches_naive():
    rng = np.random.default_rng(0)
    B, S, V = 2, 6, 11
    logits = jnp.asarray(rng.normal(0, 2, (B, S, V)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
    loss, n = lm_loss(logits, tgt, mask)
    lp = jax.nn.log_softmax(logits, -1)
    want = -np.sum(np.take_along_axis(np.asarray(lp), np.asarray(tgt)[..., None],
                                      -1)[..., 0] * np.asarray(mask))
    want /= max(float(mask.sum()), 1.0)
    assert float(loss) == pytest.approx(want, rel=1e-5)
    assert float(n) == float(mask.sum())


def test_lm_loss_ignores_masked_positions():
    rng = np.random.default_rng(1)
    B, S, V = 1, 4, 7
    logits = jnp.asarray(rng.normal(0, 1, (B, S, V)), jnp.float32)
    t1 = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    t2 = t1.at[0, 0].set((int(t1[0, 0]) + 1) % V)       # differs only at masked
    mask = jnp.asarray([[0, 1, 1, 1]], jnp.float32)
    assert float(lm_loss(logits, t1, mask)[0]) == \
        float(lm_loss(logits, t2, mask)[0])


def _setup():
    cfg = get_config("phi4-mini-3.8b").reduced().replace(n_layers=2)
    params = P.unbox(init_model(KEY, cfg))
    rng = np.random.default_rng(0)
    B, S = 4, 8
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    return cfg, params, batch


def test_microbatch_equivalence():
    cfg, params, batch = _setup()
    opt = optim.sgd(1e-2)                    # linear in grads -> exact check
    o0 = P.unbox(opt.init(params))
    s1 = jax.jit(make_train_step(cfg, opt, microbatches=1, clip_norm=0.0))
    s4 = jax.jit(make_train_step(cfg, opt, microbatches=4, clip_norm=0.0))
    p1, _, m1 = s1(params, o0, batch)
    p4, _, m4 = s4(params, o0, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)


def test_grad_clipping_caps_update():
    cfg, params, batch = _setup()
    opt = optim.sgd(1.0)
    o0 = P.unbox(opt.init(params))
    step = jax.jit(make_train_step(cfg, opt, clip_norm=1e-6))
    p1, _, m = step(params, o0, batch)
    delta = optim.global_norm(jax.tree.map(lambda a, b: a - b, p1, params))
    assert float(delta) <= 1.2e-6
    assert float(m["grad_norm"]) > 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_adam_decreases_quadratic(seed):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(0, 1, (8,)), jnp.float32)
    params = {"w": jnp.zeros((8,))}
    opt = optim.adam(0.1)
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    init = float(loss(params))
    for _ in range(120):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 0.02 * max(init, 1.0)


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones((4,))}
    opt = optim.adamw(1e-2, weight_decay=0.1)
    state = opt.init(params)
    g = {"w": jnp.zeros((4,))}
    upd, state = opt.update(g, state, params)
    p2 = optim.apply_updates(params, upd)
    assert float(jnp.max(p2["w"])) < 1.0


def test_bf16_state_dtype():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = optim.adam(1e-2, state_dtype=jnp.bfloat16)
    st_ = opt.init(params)
    assert st_["m"]["w"].dtype == jnp.bfloat16
    assert st_["v"]["w"].dtype == jnp.bfloat16


def test_eval_step_matches_train_loss():
    cfg, params, batch = _setup()
    ev = jax.jit(make_eval_step(cfg))
    opt = optim.sgd(0.0)
    step = jax.jit(make_train_step(cfg, opt, clip_norm=0.0))
    _, _, m = step(params, P.unbox(opt.init(params)), batch)
    assert float(ev(params, batch)["loss"]) == pytest.approx(
        float(m["loss"]), rel=1e-5)
