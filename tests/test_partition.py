"""Non-IID partitioner properties (Eqs. 8-10) — hypothesis-driven."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.corpus import generate_corpus
from repro.data.partition import (client_stats_table, partition,
                                  quantity_split_sizes)

DOCS = generate_corpus(240, seed=7)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 5000), k=st.integers(1, 16))
def test_quantity_sizes_eq8(n, k):
    sizes = quantity_split_sizes(n, k)
    assert sum(sizes) == n                      # conservation
    assert len(sizes) == k
    denom = k * (k + 1) // 2
    for i, s in enumerate(sizes):               # within 1 of i/sum(j) * Q
        assert abs(s - (i + 1) / denom * n) <= 1
    assert sizes == sorted(sizes)               # monotone in client index


@pytest.mark.parametrize("skew", ["iid", "quantity", "length", "vocab"])
@pytest.mark.parametrize("k", [2, 8])
def test_partition_conservation(skew, k):
    shards = partition(DOCS, k, skew, seed=0)
    assert len(shards) == k
    ids = [id(d) for s in shards for d in s]
    assert len(ids) == len(DOCS)                # every doc exactly once
    assert len(set(ids)) == len(DOCS)


@pytest.mark.parametrize("k", [2, 8])
def test_skews_maximize_their_sigma(k):
    t = {s: client_stats_table(partition(DOCS, k, s, seed=0))
         for s in ("iid", "quantity", "length", "vocab")}
    # each skew's target sigma must dominate iid's by a wide margin
    assert t["quantity"]["quantity"]["sigma"] > 5 * max(
        t["iid"]["quantity"]["sigma"], 1e-9)
    assert t["length"]["mean_sentence_length"]["sigma"] > \
        3 * t["iid"]["mean_sentence_length"]["sigma"]
    assert t["vocab"]["unique_words"]["sigma"] > \
        2.0 * t["iid"]["unique_words"]["sigma"]


@pytest.mark.parametrize("k", [2, 8])
def test_skews_pin_other_metrics(k):
    """The paper's objective: maximise ONE sigma, keep others almost flat."""
    t = {s: client_stats_table(partition(DOCS, k, s, seed=0))
         for s in ("iid", "quantity", "length", "vocab")}
    # length skew keeps quantity exactly flat
    assert t["length"]["quantity"]["sigma"] <= 1.0
    assert t["vocab"]["quantity"]["sigma"] <= 1.0
    # vocab skew keeps sentence length close to iid levels
    assert t["vocab"]["mean_sentence_length"]["sigma"] < \
        0.35 * t["length"]["mean_sentence_length"]["sigma"]
    # quantity skew keeps per-document vocabulary flat (Table 3 analogue)
    assert t["quantity"]["doc_vocab"]["sigma"] < \
        3 * max(t["iid"]["doc_vocab"]["sigma"], 1.0)


def test_partition_deterministic():
    a = partition(DOCS, 4, "vocab", seed=3)
    b = partition(DOCS, 4, "vocab", seed=3)
    assert all([id(x) for x in sa] == [id(y) for y in sb]
               for sa, sb in zip(a, b))
