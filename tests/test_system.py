"""End-to-end behaviour: the paper's pipeline on the paper's own backbone —
centralized vs FDAPT (IID + skews) vs FFDAPT, plus the sharded lowering path
on the host mesh and the quickstart example."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step, make_train_step
from repro.nn import param as P

CFG = get_config("distilbert-mlm").reduced()
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    from repro.data.corpus import split_holdout
    docs, held = split_holdout(generate_corpus(160, seed=0))
    params = P.unbox(init_model(KEY, CFG))
    eval_step = jax.jit(make_eval_step(CFG))
    heldout = make_client_datasets(held, CFG, k=1,
                                   batch=2, seq=32)["batches"][0][:3]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in heldout]))

    return docs, params, eval_loss


@pytest.mark.slow
def test_centralized_vs_fdapt_parity(setup):
    """The paper's headline: FDAPT stays close to centralized, both beat the
    original model — at smoke scale, measured in eval loss."""
    docs, params, eval_loss = setup
    init = eval_loss(params)

    # centralized = 1 client, same total data/steps
    cen = make_client_datasets(docs, CFG, k=1, batch=2, seq=32)
    p_cen, _ = FedSession(CFG, optim.adam(5e-4), n_rounds=2).run(
        params, [cen["batches"][0][:8]])
    l_cen = eval_loss(p_cen)

    results = {}
    for skew in ("iid", "quantity"):
        ds = make_client_datasets(docs, CFG, k=2, skew=skew, batch=2, seq=32)
        bs = [b[:4] for b in ds["batches"]]
        p_fd, _ = FedSession(CFG, optim.adam(5e-4), n_rounds=2,
                             client_sizes=ds["sizes"]).run(params, bs)
        results[skew] = eval_loss(p_fd)

    assert l_cen < init
    for skew, l in results.items():
        assert l < init, f"{skew} did not beat the original model"
        assert l < l_cen * 1.15, f"{skew} too far from centralized"


@pytest.mark.slow
def test_ffdapt_faster_and_close(setup):
    """FFDAPT (static windows) must not diverge from FDAPT; backward-work
    reduction is checked via the analytic ledger (CPU wall time is noisy)."""
    docs, params, eval_loss = setup
    ds = make_client_datasets(docs, CFG, k=2, skew="iid", batch=2, seq=32)
    bs = [b[:4] for b in ds["batches"]]
    p_fd, _ = FedSession(CFG, optim.adam(5e-4), n_rounds=2,
                         client_sizes=ds["sizes"]).run(params, bs)
    p_ffd, hist = FedSession(CFG, optim.adam(5e-4), n_rounds=2,
                             client_sizes=ds["sizes"],
                             ffdapt=FFDAPTConfig()).run(params, bs)
    assert abs(eval_loss(p_ffd) - eval_loss(p_fd)) / eval_loss(p_fd) < 0.05
    from repro.core.ffdapt import backward_flop_saving
    for h in hist:
        assert h.windows is not None
        assert backward_flop_saving(CFG.n_layers, h.windows) > 0


def test_sharded_lowering_on_host_mesh():
    """The launch-layer path (rules -> shardings -> jit -> lower) works on the
    local host mesh too, not only the 512-device dry-run process."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.ctx import activation_sharding
    from repro.sharding.rules import DEFAULT_RULES, tree_shardings

    cfg = get_config("phi4-mini-3.8b").reduced()
    mesh = make_host_mesh()
    opt = optim.adam(1e-4)

    def full(key):
        p = init_model(key, cfg)
        return p, opt.init(p)

    pb, ob = jax.eval_shape(full, KEY)
    psh = tree_shardings(pb, mesh, DEFAULT_RULES)
    osh = tree_shardings(ob, mesh, DEFAULT_RULES)
    B, S = 2, 8
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    step = make_train_step(cfg, opt)
    with activation_sharding(mesh, DEFAULT_RULES):
        lowered = jax.jit(step, in_shardings=(psh, osh, None)).lower(
            P.unbox(pb), P.unbox(ob), batch)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_quickstart_example_runs():
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run([sys.executable, "examples/quickstart.py", "--fast"],
                       capture_output=True, text=True, env=env, cwd=root)
    assert r.returncode == 0, r.stderr[-2000:]


def test_fed_round_program_lowers_on_host_mesh():
    """The production federated-round program (clients x local-steps x FedAvg
    in ONE jit) lowers on the host mesh; the dry-run exercises it at 512."""
    from repro.core.rounds import make_fed_round_program
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import n_freeze_units

    cfg = get_config("distilbert-mlm").reduced()
    opt = optim.adam(1e-4)
    prog = make_fed_round_program(cfg, opt)
    K, steps, B, S = 2, 2, 2, 16

    def full(key):
        p = init_model(key, cfg)
        return p, opt.init(p)

    pb, ob = jax.eval_shape(full, KEY)

    def stack(t):
        return jax.tree.map(lambda l: jax.ShapeDtypeStruct(
            (K,) + l.shape, l.dtype), P.unbox(t))

    batch = {k: jax.ShapeDtypeStruct((K, steps, B, S),
                                     jnp.float32 if k == "loss_mask"
                                     else jnp.int32)
             for k in ("tokens", "targets", "loss_mask")}
    fm = jax.ShapeDtypeStruct((K, n_freeze_units(cfg)), jnp.float32)
    sz = jax.ShapeDtypeStruct((K,), jnp.float32)
    compiled = jax.jit(prog).lower(stack(pb), stack(ob), batch, fm, sz).compile()
    assert compiled.cost_analysis() is not None


def test_fed_round_program_executes():
    """Execute the fed-round program concretely: equals broadcast+average of
    per-client masked steps."""
    from repro.core.rounds import make_fed_round_program
    from repro.core.fedavg import broadcast_clients
    from repro.models.model import n_freeze_units

    cfg = get_config("distilbert-mlm").reduced()
    opt = optim.adam(1e-3)
    prog = jax.jit(make_fed_round_program(cfg, opt))
    K, steps, B, S = 2, 2, 2, 16
    rng = np.random.default_rng(0)
    params = P.unbox(init_model(KEY, cfg))
    sp = broadcast_clients(params, K)
    so = broadcast_clients(P.unbox(opt.init(params)), K)
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size,
                                           (K, steps, B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size,
                                            (K, steps, B, S)), jnp.int32),
        "loss_mask": jnp.ones((K, steps, B, S), jnp.float32),
    }
    fm = jnp.zeros((K, n_freeze_units(cfg)), jnp.float32)
    sizes = jnp.asarray([1.0, 3.0], jnp.float32)
    new_sp, losses = prog(sp, so, batch, fm, sizes)
    assert losses.shape == (K,)
    assert all(np.isfinite(float(l)) for l in losses)
    # all clients hold the same aggregated model afterwards
    for leaf in jax.tree.leaves(new_sp):
        np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(leaf[1]))


def test_roofline_report_example_runs():
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    artifacts = os.path.join(root, "benchmarks", "results", "dryrun")
    if not os.path.isdir(artifacts) or not os.listdir(artifacts):
        pytest.skip("no dry-run artifacts (run repro.launch.dryrun --all)")
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run([sys.executable, "examples/roofline_report.py"],
                       capture_output=True, text=True, env=env, cwd=root)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "pairs lowered+compiled" in r.stdout
