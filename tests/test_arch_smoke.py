"""Per-architecture smoke tests: REDUCED variant of each assigned family runs
one forward + one train step on CPU; output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import all_configs, get_config
from repro.models.model import apply_model, init_cache, init_model
from repro.models.steps import make_train_step
from repro.nn import param as P

ARCHS = list(all_configs())
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, train=True, rng_seed=1):
    rng = np.random.default_rng(rng_seed)
    b = {"tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if train:
        b["targets"] = jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)),
                                   jnp.int32)
        b["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.arch_type == "vlm":
        b["image_embeds"] = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.arch_type == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = P.unbox(init_model(KEY, cfg))
    B, S = 2, 16
    logits, cache, aux = apply_model(params, cfg, _batch(cfg, B, S, False),
                                     mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert cache is None
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = P.unbox(init_model(KEY, cfg))
    opt = optim.adam(1e-4)
    opt_state = P.unbox(opt.init(params))
    step = jax.jit(make_train_step(cfg, opt))
    b = _batch(cfg)
    p1, o1, m1 = step(params, opt_state, b)
    p2, o2, m2 = step(p1, o1, b)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])        # same batch: must improve
    for l in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(l)))


DECODE_ARCHS = [a for a in ARCHS if get_config(a).arch_type != "mlm"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_train(arch):
    """prefill(S-1) + decode(1) logits == full train-mode forward at pos S-1."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))  # no drops
    params = P.unbox(init_model(KEY, cfg))
    B, S = 2, 12
    batch = _batch(cfg, B, S, train=False)
    full, _, _ = apply_model(params, cfg, batch, mode="train")
    cache = init_cache(cfg, B, S)
    pre = dict(batch, tokens=batch["tokens"][:, :S - 1])
    _, cache, _ = apply_model(params, cfg, pre, mode="prefill", cache=cache)
    dec = {k: v for k, v in batch.items() if k != "tokens"}
    dec["tokens"] = batch["tokens"][:, S - 1:]
    lg, cache, _ = apply_model(params, cfg, dec, mode="decode", cache=cache)
    assert int(cache["index"]) == S
    ref = np.asarray(full[:, S - 1], np.float32)
    got = np.asarray(lg[:, 0], np.float32)
    np.testing.assert_allclose(got, ref, rtol=2e-3,
                               atol=2e-3 * np.abs(ref).max())


def test_sliding_window_ring_decode():
    """Window variant: decoding past the window with the ring cache matches
    train-mode sliding-window attention."""
    cfg = get_config("phi4-mini-3.8b").reduced().replace(sliding_window=8)
    params = P.unbox(init_model(KEY, cfg))
    B, S = 1, 14
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32)
    full, _, _ = apply_model(params, cfg, {"tokens": toks}, mode="train")
    cache = init_cache(cfg, B, cfg.sliding_window)       # ring-sized cache
    _, cache, _ = apply_model(params, cfg, {"tokens": toks[:, :8]},
                              mode="prefill", cache=cache)
    outs = []
    for t in range(8, S):
        lg, cache, _ = apply_model(params, cfg, {"tokens": toks[:, t:t + 1]},
                                   mode="decode", cache=cache)
        outs.append(lg[:, 0])
    got = np.asarray(jnp.stack(outs, 1), np.float32)
    ref = np.asarray(full[:, 8:], np.float32)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3 * np.abs(ref).max())


def test_full_configs_validate_and_count():
    """Full configs build abstract params with the published scale."""
    expected_min = {"qwen2-7b": 7e9, "qwen3-14b": 13e9, "nemotron-4-340b": 3e11,
                    "phi4-mini-3.8b": 3.5e9, "llama-3.2-vision-90b": 8e10}
    for arch, lo in expected_min.items():
        cfg = get_config(arch)
        cfg.validate()
        boxed = jax.eval_shape(lambda k: init_model(k, cfg),
                               jax.random.PRNGKey(0))
        n = P.count_params(boxed)
        assert n >= lo, f"{arch}: {n:.3e} < {lo:.1e}"
        assert n < lo * 2.2, f"{arch}: {n:.3e} implausibly large"
