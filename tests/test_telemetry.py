"""Telemetry package: golden-HLO parser fixtures, property tests for the
byte/FLOP rules, and the RoundResult ledger on both round engines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import optim, telemetry as T


# ---------------------------------------------------------------------------
# Golden HLO fixtures (hand-written module text with known totals)
# ---------------------------------------------------------------------------

_WHILE_HLO = """\
HloModule golden_while

%body (p.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p.1 = (s32[], f32[8,8]) parameter(0)
  %i.1 = s32[] get-tuple-element((s32[], f32[8,8]) %p.1), index=0
  %x.1 = f32[8,8] get-tuple-element((s32[], f32[8,8]) %p.1), index=1
  %d.1 = f32[8,8] dot(f32[8,8] %x.1, f32[8,8] %x.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i.2 = s32[] add(s32[] %i.1, s32[] %one)
  ROOT %t.1 = (s32[], f32[8,8]) tuple(s32[] %i.2, f32[8,8] %d.1)
}

%cond (p.2: (s32[], f32[8,8])) -> pred[] {
  %p.2 = (s32[], f32[8,8]) parameter(0)
  %i.3 = s32[] get-tuple-element((s32[], f32[8,8]) %p.2), index=0
  %n.1 = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %i.3, s32[] %n.1), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t.0 = (s32[], f32[8,8]) tuple(s32[] %zero, f32[8,8] %a)
  %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %t.0), condition=%cond, body=%body{TRIP}
  ROOT %out = f32[8,8] get-tuple-element((s32[], f32[8,8]) %w), index=1
}
"""


@pytest.mark.parametrize("trip_attr", [
    ', backend_config={"known_trip_count":{"n":"7"}}',   # compiler-recorded
    "",                                                  # condition fallback
])
def test_golden_while_trip_propagation(trip_attr):
    stats = T.analyze(_WHILE_HLO.replace("{TRIP}", trip_attr))
    # dot: 2 * 8*8 * 8 per iteration, body runs 7x
    assert stats.dot_flops == pytest.approx(7 * 2 * 8 * 8 * 8)


def test_golden_while_body_bytes_scale_with_trips():
    hlo7 = _WHILE_HLO.replace(
        "{TRIP}", ', backend_config={"known_trip_count":{"n":"7"}}')
    hlo1 = _WHILE_HLO.replace(
        "{TRIP}", ', backend_config={"known_trip_count":{"n":"1"}}')
    b7 = T.analyze(hlo7).hbm_bytes
    b1 = T.analyze(hlo1).hbm_bytes
    # per extra iteration: dot (3 x 8*8*4) + s32 add (4+4+4); per extra cond
    # evaluation: compare (pred 1 + 4+4); everything outside the loop equal
    assert b7 - b1 == pytest.approx(6 * (3 * 8 * 8 * 4 + 12) + 6 * 9)


def test_golden_tuple_shaped_results():
    comps = T.parse_computations(_WHILE_HLO.replace("{TRIP}", ""))
    w = [op for op in comps["main"].ops if op.opcode == "while"][0]
    assert T.shape_bytes(w.result) == 4 + 8 * 8 * 4
    assert w.operand_names == ["t.0"]
    assert T.shape_bytes(w.operand_types[0]) == 4 + 8 * 8 * 4


_FUSION_HLO = """\
HloModule golden_fusion

%fc (fp0: f32[16,16], fp1: f32[16,16]) -> f32[16,16] {
  %fp0 = f32[16,16] parameter(0)
  %fp1 = f32[16,16] parameter(1)
  %big = f32[16,16] multiply(f32[16,16] %fp0, f32[16,16] %fp1)
  ROOT %fd = f32[16,16] dot(f32[16,16] %big, f32[16,16] %fp1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[16,16], b: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16] parameter(0)
  %b = f32[16,16] parameter(1)
  ROOT %f = f32[16,16] fusion(f32[16,16] %a, f32[16,16] %b), kind=kLoop, calls=%fc
}
"""


def test_golden_fusion_hides_internal_bytes_counts_internal_flops():
    stats = T.analyze(_FUSION_HLO)
    # the dot INSIDE the fusion still executes
    assert stats.dot_flops == pytest.approx(2 * 16 * 16 * 16)
    # but HBM traffic is only the fusion op's operands + result — the
    # internal %big buffer never leaves VMEM
    assert stats.hbm_bytes == pytest.approx(3 * 16 * 16 * 4)


_COLLECTIVE_HLO = """\
HloModule golden_collective

%sum (sa: f32[], sb: f32[]) -> f32[] {
  %sa = f32[] parameter(0)
  %sb = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %sa, f32[] %sb)
}

ENTRY %main (a: f32[64,4]) -> f32[64,4] {
  %a = f32[64,4] parameter(0)
  ROOT %ar = f32[64,4] all-reduce(f32[64,4] %a), replica_groups={}, to_apply=%sum
}
"""


def test_golden_collective_bytes():
    stats = T.analyze(_COLLECTIVE_HLO)
    assert stats.collective_bytes["all-reduce"] == pytest.approx(64 * 4 * 4)
    assert stats.collective_total == pytest.approx(64 * 4 * 4)


# ---------------------------------------------------------------------------
# Property tests for the byte / FLOP rules
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(dims=st.lists(st.integers(min_value=1, max_value=16), min_size=0,
                     max_size=4),
       dt=st.sampled_from(sorted(T.DTYPE_BYTES)))
def test_shape_bytes_property(dims, dt):
    text = f"{dt}[{','.join(str(d) for d in dims)}]{{1,0}}"
    want = T.DTYPE_BYTES[dt]
    for d in dims:
        want *= d
    assert T.shape_bytes(text) == want


@settings(max_examples=40)
@given(shapes=st.lists(st.lists(st.integers(min_value=1, max_value=9),
                                min_size=1, max_size=3),
                       min_size=1, max_size=3))
def test_shape_bytes_tuple_property(shapes):
    text = "(" + ", ".join(
        f"f32[{','.join(str(d) for d in s)}]" for s in shapes) + ")"
    want = sum(4 * int(np.prod(s)) for s in shapes)
    assert T.shape_bytes(text) == want


@settings(max_examples=40)
@given(m=st.integers(min_value=1, max_value=64),
       k=st.integers(min_value=1, max_value=64),
       n=st.integers(min_value=1, max_value=64))
def test_dot_flops_rule_property(m, k, n):
    line = (f"  %d = f32[{m},{n}] dot(f32[{m},{k}] %a, f32[{k},{n}] %b), "
            "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    op = T.parse_op(line)
    comp = T.Computation("c", [op], {})
    assert T.dot_flops(op, comp) == pytest.approx(2.0 * m * k * n)


@settings(max_examples=40)
@given(b=st.integers(min_value=1, max_value=8),
       m=st.integers(min_value=1, max_value=32),
       k=st.integers(min_value=1, max_value=32),
       n=st.integers(min_value=1, max_value=32))
def test_dot_flops_batched_rule_property(b, m, k, n):
    """Batch dims count once via the result; contracting dims via the lhs."""
    line = (f"  %d = f32[{b},{m},{n}] dot(f32[{b},{m},{k}] %a, "
            f"f32[{b},{k},{n}] %b), lhs_batch_dims={{0}}, "
            "lhs_contracting_dims={2}, rhs_batch_dims={0}, "
            "rhs_contracting_dims={1}")
    op = T.parse_op(line)
    comp = T.Computation("c", [op], {})
    assert T.dot_flops(op, comp) == pytest.approx(2.0 * b * m * k * n)


def test_parse_op_operand_types_from_symtab():
    """Operands printed without inline types resolve through the symtab."""
    op = T.parse_op("  %d = f32[4,4] dot(%a, %b), lhs_contracting_dims={0}")
    comp = T.Computation("c", [op], {"a": "f32[9,4]", "b": "f32[9,4]"})
    assert op.operand_names == ["a", "b"]
    assert comp.operand_type(op, 0) == "f32[9,4]"
    assert T.dot_flops(op, comp) == pytest.approx(2.0 * 4 * 4 * 9)


# ---------------------------------------------------------------------------
# RoundResult ledger: both engines, and agreement with XLA cost_analysis
# ---------------------------------------------------------------------------

def _session_inputs(steps=2, seed=0, batch=2, seq=32):
    from repro.configs import get_config
    from repro.core.noniid import make_client_datasets
    from repro.data.corpus import generate_corpus
    from repro.models.model import init_model
    from repro.nn import param as P

    cfg = get_config("distilbert-mlm").reduced()
    docs = generate_corpus(80, seed=seed)
    ds = make_client_datasets(docs, cfg, k=2, batch=batch, seq=seq, seed=seed)
    batches = [b[:steps] for b in ds["batches"]]
    params = P.unbox(init_model(jax.random.PRNGKey(seed), cfg))
    return cfg, params, batches, ds["sizes"]


def test_round_result_telemetry_parity_across_engines():
    from repro.core.rounds import FedSession
    from repro.core.strategy import FedAvg, tree_bytes

    cfg, params, batches, sizes = _session_inputs()
    opt = optim.adam(1e-4)
    _, hs = FedSession(cfg, opt, n_rounds=1, client_sizes=sizes,
                       engine="sequential").run(params, batches)
    _, hp = FedSession(cfg, opt, n_rounds=1, client_sizes=sizes,
                       engine="parallel").run(params, batches)
    total_steps = sum(len(b) for b in batches)
    for h in (hs[0], hp[0]):
        assert h.flops_estimate > 0
        assert h.hbm_bytes_estimate > 0
        # single device: no in-step collectives -> comm = down + up
        assert h.comm_bytes == 2 * tree_bytes(params) + h.upload_bytes
    # same client-step program, same step counts -> identical ledgers
    assert hs[0].flops_estimate == pytest.approx(hp[0].flops_estimate)
    assert hs[0].hbm_bytes_estimate == pytest.approx(hp[0].hbm_bytes_estimate)
    assert hs[0].comm_bytes == hp[0].comm_bytes
    # and the per-step cost seen by the engines matches the cached analysis
    cost = T.client_step_cost(cfg, opt, FedAvg(),
                              T.batch_struct(batches[0][0]))
    assert hs[0].flops_estimate == pytest.approx(cost.flops * total_steps)


def test_round_result_telemetry_off():
    from repro.core.rounds import FedSession

    cfg, params, batches, sizes = _session_inputs()
    _, h = FedSession(cfg, optim.adam(1e-4), n_rounds=1, client_sizes=sizes,
                      telemetry=False).run(params, batches)
    # no compiled-step analysis -> no compute ledger; the wire accounting
    # (down broadcast + upload) is shape-derived and stays populated
    assert h[0].flops_estimate == 0.0
    assert h[0].hbm_bytes_estimate == 0.0
    from repro.core.strategy import tree_bytes
    assert h[0].comm_bytes == 2 * tree_bytes(params) + h[0].upload_bytes


def test_ledger_matches_cost_analysis_on_unrolled_config():
    """Acceptance: flops/hbm estimates within 5% of XLA cost_analysis on a
    small config compiled WITHOUT loops (scan unrolled, no remat) — the
    regime where cost_analysis itself is trustworthy.  cost_analysis counts
    EVERY flop (optimizer elementwise, softmax) while the analyzer counts
    dots, so the comparison uses a dot-dominated batch shape — per-param
    elementwise work is fixed while dot work scales with tokens."""
    from repro.core.rounds import FedSession
    from repro.models.steps import abstract_train_state, make_train_step

    cfg, params, batches, sizes = _session_inputs(batch=4, seq=128)
    cfg = cfg.replace(scan_unroll=True, remat=False)
    opt = optim.adam(1e-4)
    _, hist = FedSession(cfg, opt, n_rounds=1, client_sizes=sizes).run(
        params, batches)
    total_steps = sum(len(b) for b in batches)

    p_sds, o_sds = abstract_train_state(cfg, opt)
    compiled = jax.jit(make_train_step(cfg, opt)).lower(
        p_sds, o_sds, T.batch_struct(batches[0][0])).compile()
    # the layer stack is unrolled (the FLOP-carrying loops); only dot-free
    # bookkeeping loops like the embedding scatter-add may remain
    want_flops = T.xla_flops(compiled) * total_steps
    got = hist[0].flops_estimate
    assert abs(got - want_flops) / want_flops < 0.05
    # bytes: same order as cost_analysis' "bytes accessed" (fusion-hiding
    # conventions differ; the magnitude must agree within 2x either way)
    want_bytes = float(T.xla_cost(compiled).get("bytes accessed", 0.0))
    if want_bytes:
        ratio = hist[0].hbm_bytes_estimate / (want_bytes * total_steps)
        assert 0.5 < ratio < 2.0
