"""The conformance subsystem itself: registry invariants, tolerance-ladder
lookups, harness execution on a representative slice, and the
BENCH_kernels/BENCH_train schemas in scripts/bench_check.py.

The FULL grid is swept by ``scripts/kernel_smoke.sh`` /
``benchmarks/kernel_bench.py`` (CI runs the tiny leg; the committed
``BENCH_kernels.json`` pins a full interpret-mode run) — running all ~50
interpret-mode cases inside tier-1 would double the suite's wall-clock,
so here we pin the *shape* of the registry and execute one adversarial
case per kernel plus the chain properties."""

import importlib.util
import json
import os

import jax.numpy as jnp
import pytest

from repro import conformance as cf

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_bench_check():
    spec = importlib.util.spec_from_file_location(
        "bench_check", os.path.join(ROOT, "scripts", "bench_check.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# registry invariants
# ---------------------------------------------------------------------------

def test_grid_meets_coverage_floor():
    """The acceptance floor the BENCH baseline pins: >= 40 cases, all four
    kernels, forward + VJP per kernel, chain properties for both scans,
    adversarial numerics represented."""
    assert len(cf.CASES) >= 40
    for kernel in cf.KERNEL_NAMES:
        cases = cf.iter_cases(kernel=kernel)
        assert cases, f"no cases for {kernel}"
        assert any(c.vjp for c in cases), f"no VJP coverage for {kernel}"
        assert any("adversarial" in c.tags for c in cases), \
            f"no adversarial coverage for {kernel}"
        assert any(c.dtype == "bfloat16" for c in cases), \
            f"no bf16 coverage for {kernel}"
    for scan in ("rwkv6_scan", "mamba2_scan"):
        assert any(c.chain for c in cf.iter_cases(kernel=scan)), \
            f"no chain property for {scan}"


def test_case_names_unique_and_prefixed():
    names = [c.name for c in cf.CASES]
    assert len(set(names)) == len(names)
    for c in cf.CASES:
        assert c.name.startswith(c.kernel + "/")
        assert c.kernel in cf.KERNELS           # spec registered
        assert c.tol_scale >= 1.0               # loosen-only, never tighten


def test_chain_cases_have_chain_fn():
    for c in cf.CASES:
        if c.chain:
            assert cf.KERNELS[c.kernel].chain_fn is not None


def test_case_keys_deterministic():
    c = cf.CASES[0]
    assert (c.key() == c.key()).all()
    # distinct cases draw distinct inputs
    assert not (cf.CASES[0].key() == cf.CASES[1].key()).all()


def test_register_kernel_rejects_duplicates():
    spec = cf.KERNELS["moe_gmm"]
    with pytest.raises(ValueError):
        cf.register_kernel(spec)


# ---------------------------------------------------------------------------
# tolerance ladder
# ---------------------------------------------------------------------------

def test_ladder_lookup_precedence():
    # per-kernel override beats the dtype default
    assert cf.forward_tol("mamba2_scan", jnp.float32).atol == pytest.approx(
        1e-4)
    assert cf.forward_tol("flash_attention", jnp.float32).atol == \
        pytest.approx(2e-5)
    # dtype string and jnp dtype resolve identically
    assert cf.forward_tol("moe_gmm", "bfloat16") == \
        cf.forward_tol("moe_gmm", jnp.bfloat16)
    # vjp rungs are looser than forward rungs
    for kernel in cf.KERNEL_NAMES:
        for dtype in ("float32", "bfloat16"):
            assert cf.vjp_tol(kernel, dtype).atol > \
                cf.forward_tol(kernel, dtype).atol


def test_ladder_unknown_dtype_raises():
    with pytest.raises(KeyError):
        cf.forward_tol("moe_gmm", jnp.float16)


def test_violation_metric():
    tol = cf.Tol(rtol=0.0, atol=1.0)
    assert tol.violation([0.0, 0.5], [0.0, 0.0]) == pytest.approx(0.5)
    assert tol.violation([2.0], [0.0]) == pytest.approx(2.0)
    assert cf.Tol(rtol=0.1, atol=0.0).violation([11.0], [10.0]) == \
        pytest.approx(1.0)


def test_ladder_export_is_jsonable():
    table = cf.ladder()
    json.dumps(table)
    assert "mamba2_scan/float32/fwd" in table
    assert "default/bfloat16/vjp" in table


# ---------------------------------------------------------------------------
# harness execution: one adversarial case per kernel + the chain cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [
    "flash_attention/softcap-saturated",
    "rwkv6_scan/denormal",
    "mamba2_scan/decay-la60",
    "moe_gmm/denormal",
])
def test_adversarial_case_passes(name):
    res = cf.run_case(cf.get_case(name))
    assert res.ok, (res.fwd_violation, res.vjp_violation)
    assert res.fwd_violation is not None
    if cf.get_case(name).vjp:
        assert res.vjp_violation is not None


@pytest.mark.parametrize("name", [
    "rwkv6_scan/chain-split10",
    "mamba2_scan/chain-split7",
])
def test_chain_property_passes(name):
    res = cf.run_case(cf.get_case(name))
    assert res.ok
    assert res.chain_violation is not None and res.chain_violation <= 1.0


def test_summarize_counts():
    rs = [cf.run_case(cf.get_case(n)) for n in
          ("moe_gmm/denormal", "rwkv6_scan/chain-split10")]
    s = cf.summarize(rs)
    assert s["n_cases"] == 2 and s["n_failed"] == 0
    assert s["by_kernel"]["rwkv6_scan"]["chain"] == 1
    assert s["interpret"] is True  # this container has no TPU


def test_result_row_is_jsonable():
    res = cf.run_case(cf.get_case("moe_gmm/single-expert"))
    row = res.to_row()
    json.dumps(row)
    assert row["ok"] is True and row["kernel"] == "moe_gmm"


# ---------------------------------------------------------------------------
# bench_check schemas
# ---------------------------------------------------------------------------

def _kernels_payload(rows, grid="tiny", interpret=True):
    summary = {"n_cases": len(rows), "n_ok": len(rows), "n_failed": 0,
               "by_kernel": {}, "worst_violation": {"fwd": 0.1, "vjp": 0.2,
                                                    "chain": 0.0},
               "median_fp32_speedup": {"moe_gmm": 1.2}}
    return {"benchmark": "kernels", "grid": grid, "backend": "cpu",
            "interpret": interpret, "jax_version": "0", "tolerance_ladder":
            cf.ladder(), "summary": summary, "rows": rows}


def _row(name="moe_gmm/x", kernel="moe_gmm", ok=True, vjp=0.1):
    return {"name": name, "kernel": kernel, "dtype": "float32", "tags": [],
            "ok": ok, "fwd_violation": 0.1, "vjp_violation": vjp,
            "chain_violation": None, "interpret": True}


def test_bench_check_kernels_schema(tmp_path):
    bc = _load_bench_check()
    p = tmp_path / "BENCH_kernels.json"
    p.write_text(json.dumps(_kernels_payload([_row()])))
    assert "kernels" in bc.check_file(str(p))

    # a failed case must be rejected
    p.write_text(json.dumps(_kernels_payload([_row(ok=False)])))
    with pytest.raises(AssertionError, match="FAILED its tolerance"):
        bc.check_file(str(p))

    # a full grid must meet the coverage floor
    p.write_text(json.dumps(_kernels_payload([_row()], grid="full")))
    with pytest.raises(AssertionError, match="full grid"):
        bc.check_file(str(p))


def test_bench_check_kernels_accepts_real_tiny_run():
    """End-to-end producer check on one real case per kernel (the smoke
    script does the same through benchmarks/kernel_bench.py)."""
    bc = _load_bench_check()
    rows = []
    for kernel in cf.KERNEL_NAMES:
        case = next(c for c in cf.iter_cases(kernel=kernel, tags=("lattice",))
                    if c.dtype == "float32")
        rows.append(cf.run_case(case).to_row())
    payload = _kernels_payload(rows)
    payload["summary"] = cf.summarize(
        [cf.run_case(cf.iter_cases(kernel="moe_gmm")[0])])
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "BENCH_kernels.json")
        with open(path, "w") as f:
            json.dump(payload, f)
        assert "kernels" in bc.check_file(path)


def test_bench_check_train_schema(tmp_path):
    bc = _load_bench_check()
    payload = {
        "benchmark": "train_step", "arch": "distilbert-mlm",
        "engine": "parallel", "cohort": 8, "local_steps": 1, "batch": 2,
        "seq": 32, "warm_round_s": 0.5, "clients_per_s": 16.0,
        "step_cost": {"flops": 1e9, "hbm_bytes": 1e8,
                      "collective_bytes": 1e6},
        "drift": {"phase": "round", "measured_s": 0.5, "predicted_s": 0.1,
                  "ratio": 5.0, "source": "device:rtx2080ti", "warn": True,
                  "device": "rtx2080ti"},
    }
    p = tmp_path / "BENCH_train.json"
    p.write_text(json.dumps(payload))
    assert "train_step" in bc.check_file(str(p))

    bad = dict(payload, drift=dict(payload["drift"], predicted_s=0.0))
    p.write_text(json.dumps(bad))
    with pytest.raises(AssertionError, match="predicted_s"):
        bc.check_file(str(p))


def test_committed_bench_files_pass():
    """The pinned baselines at the repo root stay schema-valid."""
    bc = _load_bench_check()
    for fname in ("BENCH_kernels.json", "BENCH_train.json"):
        path = os.path.join(ROOT, fname)
        assert os.path.exists(path), f"{fname} not committed at repo root"
        bc.check_file(path)
