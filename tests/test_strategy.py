"""FederatedStrategy parity suite.

Pins the strategy API to the legacy math it replaced:
  (a) FedAvg-as-strategy is BITWISE equal to the legacy hand-rolled
      train-then-``fedavg`` loop, on both engines;
  (b) ``aggregate`` (list layout) and ``aggregate_stacked`` (client-dim
      layout) agree for every strategy;
  (c) FedProx with mu=0 collapses to plain FedAvg;
  (d) compressed uploads report fewer bytes than dense.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core.fedavg import (broadcast_clients, fedavg, fedavg_fold,
                               fold_finalize, fold_init)
from repro.core.rounds import FedSession, RoundPlan
from repro.core.strategy import (Compressed, FedAvg, FedAvgM, FedProx,
                                 make_strategy, tree_bytes)
from repro.core.noniid import make_client_datasets
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_train_step
from repro.nn import param as P

CFG = get_config("distilbert-mlm").reduced()
KEY = jax.random.PRNGKey(0)
DOCS = generate_corpus(100, seed=0)


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


@pytest.fixture(scope="module")
def clients():
    ds = make_client_datasets(DOCS, CFG, k=2, skew="iid", batch=2, seq=32)
    return [b[:2] for b in ds["batches"]], ds["sizes"]


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# (a) FedAvg strategy == legacy loop, bitwise, both engines
# ---------------------------------------------------------------------------

def _legacy_sequential(opt, params, batches, sizes, rounds):
    step = jax.jit(make_train_step(CFG, opt))
    for _ in range(rounds):
        locals_ = []
        for bs in batches:
            p, o = params, P.unbox(opt.init(params))
            for b in bs:
                p, o, _ = step(p, o, b)
            locals_.append(p)
        params = fedavg(locals_, sizes)
    return params


def _legacy_parallel(opt, params, batches_list, sizes, rounds):
    """The hand-rolled vmapped round: vmapped epochs + the canonical
    client-index FedAvg fold (``fedavg_fold``) — the reduction both the
    full-width and cohort-scan parallel engines lower to."""
    K = len(batches_list)
    per_client = [jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
                  for bs in batches_list]
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)
    plain_step = make_train_step(CFG, opt)
    w = jnp.asarray(sizes, jnp.float32)

    @jax.jit
    def fed_round(gp, bs_all):
        stacked = broadcast_clients(gp, K)
        opts = jax.vmap(lambda p: P.unbox(opt.init(p)))(stacked)

        def client_epoch(p, o, bs):
            def one(carry, b):
                p_, o_ = carry
                p_, o_, m = plain_step(p_, o_, b)
                return (p_, o_), m["loss"]
            (p, o), losses = jax.lax.scan(one, (p, o), bs)
            return p, jnp.mean(losses)

        p_k, _ = jax.vmap(client_epoch)(stacked, opts, batches)
        return fedavg_fold(fold_init(gp), p_k, w / jnp.sum(w))

    @jax.jit
    def combine(gp, partial):
        return fold_finalize(partial, gp)

    for _ in range(rounds):
        params = combine(params, fed_round(params, batches))
    return params


@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_fedavg_strategy_bitwise_equals_legacy(params0, clients, engine):
    batches, sizes = clients
    p_new, hist = FedSession(CFG, optim.adam(1e-4), RoundPlan(
        n_rounds=2, engine=engine, client_sizes=sizes)).run(params0, batches)
    legacy = (_legacy_sequential if engine == "sequential"
              else _legacy_parallel)
    p_old = legacy(optim.adam(1e-4), params0, batches, sizes, 2)
    assert _maxdiff(p_new, p_old) == 0.0
    assert hist[-1].upload_bytes == len(batches) * tree_bytes(params0)


# ---------------------------------------------------------------------------
# (b) aggregate == aggregate_stacked for every strategy
# ---------------------------------------------------------------------------

def _rand_trees(k, seed=0):
    rng = np.random.default_rng(seed)
    def tree():
        return {"a": jnp.asarray(rng.normal(0, 1, (4, 5)), jnp.float32),
                "b": {"c": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}}
    return tree(), [tree() for _ in range(k)]


@pytest.mark.parametrize("strategy", [
    FedAvg(), FedAvgM(beta=0.7, lr=0.9), FedProx(mu=0.1),
    Compressed(kind="topk", frac=0.25), Compressed(kind="int8"),
    Compressed(inner=FedAvgM(), kind="int8"),
], ids=lambda s: s.name)
def test_aggregate_layouts_agree(strategy):
    g, client_trees = _rand_trees(3, seed=1)
    sizes = [1.0, 2.0, 3.0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *client_trees)
    w = jnp.asarray(sizes, jnp.float32)

    st_a = strategy.init_state(g)
    new_a, st_a, nbytes = strategy.aggregate(g, client_trees, sizes, st_a)
    st_b = strategy.init_state(g)
    new_b, st_b = jax.jit(strategy.aggregate_stacked)(g, stacked, w, st_b)

    assert _maxdiff(new_a, new_b) < 1e-6
    if jax.tree.leaves(st_a):                      # stateful (FedAvgM)
        assert _maxdiff(st_a, st_b) < 1e-6
    assert nbytes > 0


def test_aggregate_layouts_agree_second_round_state():
    """FedAvgM momentum threads identically through both layouts."""
    strategy = FedAvgM(beta=0.9)
    g, client_trees = _rand_trees(2, seed=2)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *client_trees)
    w = jnp.asarray([1.0, 1.0], jnp.float32)

    st_a, st_b = strategy.init_state(g), strategy.init_state(g)
    a, st_a, _ = strategy.aggregate(g, client_trees, [1, 1], st_a)
    b, st_b = strategy.aggregate_stacked(g, stacked, w, st_b)
    a2, st_a, _ = strategy.aggregate(a, client_trees, [1, 1], st_a)
    b2, st_b = strategy.aggregate_stacked(b, stacked, w, st_b)
    assert _maxdiff(a2, b2) < 1e-5


# ---------------------------------------------------------------------------
# (c) FedProx(mu=0) == FedAvg
# ---------------------------------------------------------------------------

def test_fedprox_zero_mu_matches_fedavg(params0, clients):
    batches, sizes = clients
    p_avg, _ = FedSession(CFG, optim.adam(1e-4), n_rounds=1,
                          client_sizes=sizes).run(params0, batches)
    p_prox, _ = FedSession(CFG, optim.adam(1e-4), n_rounds=1,
                           client_sizes=sizes,
                           strategy=FedProx(mu=0.0)).run(params0, batches)
    assert _maxdiff(p_avg, p_prox) == 0.0


def test_fedprox_positive_mu_changes_result_and_reports_anchor(params0,
                                                               clients):
    batches, sizes = clients
    p_avg, _ = FedSession(CFG, optim.adam(1e-3), n_rounds=1,
                          client_sizes=sizes).run(params0, batches)
    p_prox, _ = FedSession(CFG, optim.adam(1e-3), n_rounds=1,
                           client_sizes=sizes,
                           strategy=FedProx(mu=1.0)).run(params0, batches)
    assert _maxdiff(p_avg, p_prox) > 0.0
    # the proximal pull keeps clients nearer the round's anchor
    assert _maxdiff(p_prox, params0) <= _maxdiff(p_avg, params0) * 1.5


# ---------------------------------------------------------------------------
# (d) compressed uploads < dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "parallel"])
@pytest.mark.parametrize("kind,bound", [("topk", 0.5), ("int8", 0.3)])
def test_compressed_upload_bytes_below_dense(params0, clients, engine, kind,
                                             bound):
    batches, sizes = clients
    dense = len(batches) * tree_bytes(params0)
    _, hist = FedSession(CFG, optim.adam(1e-4), RoundPlan(
        n_rounds=1, engine=engine, client_sizes=sizes,
        strategy=Compressed(kind=kind, frac=0.1))).run(params0, batches)
    assert 0 < hist[-1].upload_bytes < dense * bound


def test_make_strategy_registry():
    assert make_strategy("fedavg").name == "fedavg"
    assert make_strategy("fedavgm", beta=0.5) == FedAvgM(beta=0.5)
    assert make_strategy("fedprox", mu=0.3) == FedProx(mu=0.3)
    s = make_strategy("fedprox", compress="topk", frac=0.2)
    assert isinstance(s, Compressed) and s.inner == FedProx() \
        and s.needs_anchor
    with pytest.raises(ValueError):
        make_strategy("fedsgd")
    with pytest.raises(ValueError):
        make_strategy("fedavg", compress="gzip")


def test_participation_samples_clients(params0):
    ds = make_client_datasets(DOCS, CFG, k=4, skew="iid", batch=2, seq=32)
    batches = [b[:1] for b in ds["batches"]]
    _, hist = FedSession(CFG, optim.adam(1e-4), n_rounds=3,
                         participation=0.5, seed=7,
                         client_sizes=ds["sizes"]).run(params0, batches)
    for h in hist:
        assert len(h.clients) == 2
        assert h.upload_bytes == 2 * tree_bytes(params0)
    assert len({tuple(h.clients) for h in hist}) > 1    # rounds vary
