"""Data layer: tokenizer determinism, corpus statistics, batching/MLM."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.data.batching import clm_batches, mlm_batches, shard_batches, tokenize_shard
from repro.data.corpus import Document, corpus_stats, generate_corpus
from repro.data.tokenizer import EOS, MASK, N_SPECIALS, HashWordTokenizer


@settings(max_examples=30, deadline=None)
@given(word=st.text(min_size=1, max_size=20), vocab=st.integers(10, 100000))
def test_tokenizer_range_and_determinism(word, vocab):
    tok = HashWordTokenizer(vocab)
    t = tok.token(word)
    assert N_SPECIALS <= t < vocab
    assert t == HashWordTokenizer(vocab).token(word)


def test_tokenizer_document_bos_eos():
    tok = HashWordTokenizer(1000)
    ids = tok.encode_document([["alpha", "beta"], ["gamma"]])
    assert ids[0] == 3 and ids[-1] == EOS and len(ids) == 5


def test_corpus_controllable_stats():
    docs = generate_corpus(50, seed=0, sent_len_lo=10, sent_len_hi=12)
    s = corpus_stats(docs)
    assert 9 <= s["mean_sentence_length"] <= 13
    docs2 = generate_corpus(50, seed=0, sent_len_lo=40, sent_len_hi=44)
    assert corpus_stats(docs2)["mean_sentence_length"] > \
        s["mean_sentence_length"] * 2


def test_clm_batches_shift():
    stream = np.arange(100, dtype=np.int32)
    bs = clm_batches(stream, batch=2, seq=8)
    b = bs[0]
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    assert b["tokens"].shape == (2, 8)
    assert b["loss_mask"].sum() == 16


def test_mlm_masking_statistics():
    rng = np.random.default_rng(0)
    stream = rng.integers(N_SPECIALS, 1000, 40000).astype(np.int32)
    bs = mlm_batches(stream, batch=4, seq=128, vocab=1000, mask_rate=0.15)
    sel = np.concatenate([b["loss_mask"] for b in bs]).ravel()
    assert 0.12 < sel.mean() < 0.18                 # ~15% positions masked
    b = bs[0]
    masked = b["loss_mask"] > 0
    # 80% of masked positions are [MASK]
    frac_mask_tok = (b["tokens"][masked] == MASK).mean()
    assert 0.65 < frac_mask_tok < 0.95
    # unmasked positions untouched
    np.testing.assert_array_equal(b["tokens"][~masked], b["targets"][~masked])


def test_shard_batches_respects_objective():
    docs = generate_corpus(10, seed=1)
    mlm_cfg = get_config("distilbert-mlm").reduced()
    clm_cfg = get_config("phi4-mini-3.8b").reduced()
    mb = shard_batches(docs, mlm_cfg, batch=2, seq=32)[0]
    cb = shard_batches(docs, clm_cfg, batch=2, seq=32)[0]
    assert mb["loss_mask"].mean() < 0.5             # only masked positions
    assert cb["loss_mask"].mean() == 1.0            # all positions


def test_small_shard_cycles():
    docs = generate_corpus(1, seed=2, sentences_per_doc=2)
    bs = shard_batches(docs, get_config("phi4-mini-3.8b").reduced(),
                       batch=4, seq=64)
    assert len(bs) >= 1                             # tiling fallback
