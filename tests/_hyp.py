"""Hypothesis shim: the property tests use the real library when it is
installed, and fall back to a tiny deterministic random-example runner when it
is not (this container has no ``hypothesis``), so the tier-1 suite always
collects and runs.

The fallback covers exactly the strategy surface the suite uses —
``integers / floats / booleans / lists / text / sampled_from`` — drawing
``max_examples`` examples from a PRNG seeded by the test's qualified name
(stable across runs).  It does not shrink failures; install
``requirements-dev.txt`` for the real engine.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import random as _random
    import zlib as _zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Strategy(lambda r: [
                elements.draw(r)
                for _ in range(r.randint(min_size, max_size))])

        @staticmethod
        def text(min_size=0, max_size=10, **_kw):
            chars = ("abcdefghijklmnopqrstuvwxyz"
                     "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_-., äöμλ汉")
            return _Strategy(lambda r: "".join(
                r.choice(chars) for _ in range(r.randint(min_size, max_size))))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: r.choice(items))

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: __wrapped__ would make pytest read the
            # original signature and treat drawn args as fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                rnd = _random.Random(_zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(**{k: s.draw(rnd) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
