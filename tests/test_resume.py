"""Kill-and-resume: a FedSession interrupted after round r and resumed from
its checkpoint is BITWISE identical to the uninterrupted run — params and
history (losses, ledgers, client selections) — on both engines, including
FedAvgM server momentum, AsyncFedAvg staleness discounting, FFDAPT windows,
and the participation<1 client-sampling RNG position."""

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import latest_step, restore_extra, tree_digest
from repro.checkpoint.npz import FederatedState
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.accounting import split_bytes
from repro.core.rounds import FedSession, RoundPlan, RoundResult
from repro.core.strategies import AsyncFedAvg
from repro.core.strategy import Compressed, FedAvg, FedAvgM, FedProx
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P
from repro.sim import make_fleet, simulate

CFG = get_config("distilbert-mlm").reduced()
KEY = jax.random.PRNGKey(0)
DOCS = generate_corpus(120, seed=0)
OPT = optim.adam(1e-3)          # ONE instance: sessions share the step cache

WALL_FIELDS = ("round_time_s", "tokens_per_s")


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


@pytest.fixture(scope="module")
def clients():
    ds = make_client_datasets(DOCS, CFG, k=3, skew="quantity", batch=2,
                              seq=32)
    return [b[:2] for b in ds["batches"]], ds["sizes"]


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_same_history(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        dx, dy = dataclasses.asdict(x), dataclasses.asdict(y)
        for f in WALL_FIELDS:
            dx.pop(f), dy.pop(f)
        assert dx == dy


def _run(params0, batches, sizes, *, tmp=None, stop=None, resume=False,
         **plan_kw):
    plan = RoundPlan(client_sizes=sizes,
                     checkpoint_dir=str(tmp) if tmp else None,
                     stop_after_round=stop, **plan_kw)
    return FedSession(CFG, OPT, plan).run(params0, batches, resume=resume)


STRATEGIES = [
    FedAvg(),
    FedAvgM(beta=0.9, lr=1.0),                     # stateful server momentum
    FedProx(mu=0.01),                              # anchored client objective
    AsyncFedAvg(alpha=0.5, staleness=(1, 0)),      # staleness discounting
    Compressed(inner=FedAvg(), kind="topk", frac=0.3),  # uneven upload bytes
]


@pytest.mark.parametrize("strategy", STRATEGIES, ids=lambda s: s.name)
def test_resume_bitwise_sequential(params0, clients, tmp_path, strategy):
    """Interrupt after round 1 of 3 with participation<1 (the RNG draws
    every round); the resumed run must match the uninterrupted run bitwise
    for every registered strategy."""
    batches, sizes = clients
    kw = dict(n_rounds=3, engine="sequential", strategy=strategy,
              participation=2 / 3, seed=7, telemetry=False)
    p_full, h_full = _run(params0, batches, sizes, **kw)
    p_a, h_a = _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    assert latest_step(str(tmp_path)) == 1
    assert len(h_a) == 1
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    _assert_bitwise(p_full, p_b)
    _assert_same_history(h_full, h_b)
    # the RNG position survived: resumed rounds sampled the same clients
    assert [h.clients for h in h_b] == [h.clients for h in h_full]
    assert tree_digest(p_full) == tree_digest(p_b)


@pytest.mark.parametrize("strategy", [FedAvgM(), AsyncFedAvg(alpha=0.5,
                                                             staleness=(1,))],
                         ids=lambda s: s.name)
def test_resume_bitwise_parallel(params0, clients, tmp_path, strategy):
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="parallel", strategy=strategy, seed=3,
              telemetry=False)
    p_full, h_full = _run(params0, batches, sizes, **kw)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    _assert_bitwise(p_full, p_b)
    _assert_same_history(h_full, h_b)


@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_resume_ffdapt_windows(params0, clients, tmp_path, engine):
    """FFDAPT runs resume mid-rotation: the restored pointer is verified
    against the re-derived schedule and the window history matches."""
    batches, sizes = clients
    kw = dict(n_rounds=3, engine=engine, ffdapt=FFDAPTConfig(gamma=0.5),
              telemetry=False)
    p_full, h_full = _run(params0, batches, sizes, **kw)
    _run(params0, batches, sizes, tmp=tmp_path, stop=2, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    _assert_bitwise(p_full, p_b)
    _assert_same_history(h_full, h_b)
    assert all(h.windows for h in h_b)


def test_resume_plan_mismatch_raises(params0, clients, tmp_path):
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, seed=0, **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True, seed=1, **kw)


def test_resume_strategy_hyperparam_mismatch_raises(params0, clients,
                                                    tmp_path):
    """The fingerprint carries the strategy's full hyperparameters, not
    just its name — resuming a FedAvgM(beta=0.9) run with beta=0.5 would
    apply the restored momentum under the wrong decay."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1,
         strategy=FedAvgM(beta=0.9), **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             strategy=FedAvgM(beta=0.5), **kw)


def test_resume_client_population_mismatch_raises(params0, clients,
                                                  tmp_path):
    """Resuming over a different client population (count or n_k weights)
    must raise — the restored RNG position and aggregation weights would
    otherwise silently drive a run matching nothing."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches[:2], sizes[:2], tmp=tmp_path, resume=True,
             **kw)


def test_resume_with_fleet_bitwise_and_mismatch(params0, clients, tmp_path):
    """A simulated run resumes bitwise (sim_round_s included via the
    compared history), and a differently-composed fleet — even under the
    same name — refuses to resume."""
    from repro.sim import sample_fleet
    batches, sizes = clients
    fleet_a = sample_fleet({"laptop": 1.0}, len(batches), seed=0)
    fleet_b = sample_fleet({"phone": 1.0}, len(batches), seed=0)
    assert fleet_a.name == fleet_b.name            # both "custom"
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    p_full, h_full = _run(params0, batches, sizes, simulate=fleet_a, **kw)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, simulate=fleet_a,
         **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             simulate=fleet_b, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True,
                    simulate=fleet_a, **kw)
    _assert_bitwise(p_full, p_b)
    _assert_same_history(h_full, h_b)
    assert all(h.sim_round_s > 0 for h in h_b)


def test_fresh_run_refuses_dirty_checkpoint_dir(params0, clients, tmp_path):
    """Without resume=True, a checkpoint_dir that already holds round
    checkpoints is refused — the fresh run's checkpoints would sort oldest
    and rotate away, leaving a later resume to silently pick up the stale
    run's state."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    with pytest.raises(ValueError, match="already holds"):
        _run(params0, batches, sizes, tmp=tmp_path, **kw)


def test_resume_impl_mismatch_raises(params0, clients, tmp_path):
    """A different kernel implementation is only allclose to xla, not
    bitwise — resuming across impls must raise."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, impl="xla", **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             impl="chunked", **kw)


def test_resume_legacy_snapshot_clear_error(params0, clients, tmp_path):
    """A pre-resume final-snapshot checkpoint (bare params + {arch,rounds}
    sidecar) must produce a clear 'not resumable' error, not a KeyError
    from the archive layout."""
    from repro.checkpoint import save_checkpoint
    batches, sizes = clients
    save_checkpoint(str(tmp_path), 15, params0,
                    extra={"arch": "distilbert-mlm", "rounds": 15})
    with pytest.raises(ValueError, match="not a resumable"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             n_rounds=15, engine="sequential", telemetry=False)


def test_resume_fingerprint_extra_mismatch_raises(params0, clients,
                                                  tmp_path):
    """The caller-supplied identity (train.py records lr/arch/batch/...)
    is verified on resume like every other fingerprint key."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1,
         fingerprint_extra={"lr": 1e-3}, **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             fingerprint_extra={"lr": 1e-4}, **kw)


def test_resume_with_same_stop_after_halts_immediately(params0, clients,
                                                       tmp_path):
    """Resuming with the original --stop-after still in force must halt at
    once (the restored rounds already reach the threshold), not run an
    extra round past it."""
    batches, sizes = clients
    kw = dict(n_rounds=3, engine="sequential", telemetry=False)
    p_a, h_a = _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, stop=1,
                    resume=True, **kw)
    assert len(h_b) == 1 and latest_step(str(tmp_path)) == 1
    _assert_bitwise(p_a, p_b)
    _assert_same_history(h_a, h_b)


def test_resume_ffdapt_onoff_mismatch_raises(params0, clients, tmp_path):
    """Resuming an FFDAPT checkpoint without --ffdapt (or with a different
    gamma/epsilon) must raise — the remaining rounds would otherwise train
    fully unfrozen and match neither uninterrupted variant."""
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    _run(params0, batches, sizes, tmp=tmp_path, stop=1,
         ffdapt=FFDAPTConfig(), **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    with pytest.raises(ValueError, match="different plan"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True,
             ffdapt=FFDAPTConfig(gamma=2.0), **kw)


def test_resume_ffdapt_schedule_mismatch_raises(params0, clients, tmp_path):
    """A sidecar whose FFDAPT pointer disagrees with the plan's re-derived
    schedule (e.g. the client sizes or gamma changed) must refuse to
    resume rather than silently train the wrong windows."""
    batches, sizes = clients
    kw = dict(n_rounds=3, engine="sequential", telemetry=False,
              ffdapt=FFDAPTConfig(gamma=0.5))
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, **kw)
    meta = restore_extra(str(tmp_path), 1)
    meta["ffdapt_start"] = meta["ffdapt_start"] + 1    # desync the pointer
    with open(tmp_path / "ckpt_00000001.json", "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="FFDAPT pointer"):
        _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)


def test_resume_without_checkpoint_starts_fresh(params0, clients, tmp_path):
    batches, sizes = clients
    kw = dict(n_rounds=1, engine="sequential", telemetry=False)
    p_a, h_a = _run(params0, batches, sizes, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path / "empty",
                    resume=True, **kw)
    _assert_bitwise(p_a, p_b)
    _assert_same_history(h_a, h_b)


def test_resume_completed_run_is_noop(params0, clients, tmp_path):
    batches, sizes = clients
    kw = dict(n_rounds=2, engine="sequential", telemetry=False)
    p_a, h_a = _run(params0, batches, sizes, tmp=tmp_path, **kw)
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    _assert_bitwise(p_a, p_b)
    _assert_same_history(h_a, h_b)


def test_rotation_keeps_resume_alive(params0, clients, tmp_path):
    """_rotate-safe retention: with keep < rounds the oldest checkpoints
    are gone but the newest still resumes."""
    batches, sizes = clients
    kw = dict(n_rounds=4, engine="sequential", telemetry=False,
              checkpoint_keep=2)
    p_full, h_full = _run(params0, batches, sizes, tmp=tmp_path, stop=3, **kw)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2 and latest_step(str(tmp_path)) == 3
    p_b, h_b = _run(params0, batches, sizes, tmp=tmp_path, resume=True, **kw)
    assert len(h_b) == 4


def test_checkpoint_sidecar_contents(params0, clients, tmp_path):
    """The FederatedState sidecar carries the full resume contract: round
    pointer, RNG bit-state, serialized history, and a plan fingerprint."""
    batches, sizes = clients
    _run(params0, batches, sizes, tmp=tmp_path, stop=1, n_rounds=3,
         engine="sequential", participation=2 / 3, seed=11, telemetry=False)
    fed = FederatedState.from_json(restore_extra(str(tmp_path), 1))
    assert fed.round == 1
    assert fed.rng_state is not None
    assert fed.rng_state["bit_generator"] == "PCG64"
    assert len(fed.history) == 1
    rr = RoundResult.from_json(fed.history[0])
    assert rr.round == 0 and rr.clients is not None
    assert fed.plan["seed"] == 11
    assert fed.plan["strategy"]["name"] == "fedavg"


# ---------------------------------------------------------------------------
# sim replays survive restarts (serialized history == live history)
# ---------------------------------------------------------------------------

def _synthetic_history(rounds=3, k=4):
    out = []
    for t in range(rounds):
        steps = [2 + (i + t) % 3 for i in range(k)]
        out.append(RoundResult(
            t, 0.5, 0.0, clients=list(range(k)), client_steps=steps,
            client_step_flops=[1e12] * k, client_step_hbm=[1e9] * k,
            client_upload_bytes=split_bytes(10_000_001, k),
            upload_bytes=10_000_001, download_bytes=9_999_999))
    return out


@pytest.mark.parametrize("mode,kw", [
    ("sync", {}),
    ("deadline", {"deadline_s": 30.0}),
    ("async", {"buffer_size": 2}),
])
def test_simulate_from_serialized_history(mode, kw):
    """simulate() over the checkpoint's JSON history dicts == simulate()
    over the live RoundResults, including async staleness."""
    hist = _synthetic_history()
    fleet = make_fleet("edge-mixed", 4, seed=0)
    live = simulate(hist, fleet, mode=mode, seed=5, **kw)
    thawed = json.loads(json.dumps([h.to_json() for h in hist]))
    replay = simulate(thawed, fleet, mode=mode, seed=5, **kw)
    assert live == replay
    if mode == "async":
        assert live.staleness_histogram() == replay.staleness_histogram()


def test_ledger_fallback_split_sums_exactly():
    """Records without a per-client upload list fall back to the same
    exact-sum remainder rule the engines use (no dropped bytes)."""
    from repro.sim import ledger_lists
    rr = {"clients": [0, 1, 2], "upload_bytes": 10_000_001,
          "download_bytes": 30}
    _, _, _, _, up, _ = ledger_lists(rr)
    assert sum(up) == 10_000_001 and max(up) - min(up) <= 1


def test_round_result_json_roundtrip():
    rr = _synthetic_history(1)[0]
    rr.windows = [(0, 2), (2, 1)]
    rr.eval_loss = 1.25
    thawed = RoundResult.from_json(json.loads(json.dumps(rr.to_json())))
    assert thawed == rr
