"""repro.serve: decode-vs-teacher-forced parity across archs, engine
invariants (bitwise continuous-vs-static outputs, evict/readmit, no
recompilation), scheduler/clock determinism, checkpoint loading, metrics
schema, and the accumulated finiteness trace."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, tree_digest
from repro.checkpoint.npz import FederatedState
from repro.configs import get_config
from repro.models.model import apply_model, init_model
from repro.models.steps import make_prefill_step, make_serve_step
from repro.nn import param as P
from repro.serve import (BENCH_MODE_KEYS, DecodeEngine, EngineConfig,
                         FIFOScheduler, FiniteTrace, PoissonArrivals,
                         Request, ServeMetrics, VirtualClock,
                         generated_tokens, load_serving_params, run_static,
                         synthetic_requests, tokens_per_s, write_bench)

KEY = jax.random.PRNGKey(0)


def shrunk(name, **kw):
    """Narrower-than-reduced() config: engine tests run many decode steps."""
    cfg = get_config(name).reduced().replace(
        d_model=128, n_heads=2, n_kv_heads=1, head_dim=64, d_ff=256,
        vocab_size=512)
    return cfg.replace(**kw) if kw else cfg


def _params(cfg):
    return P.unbox(init_model(KEY, cfg))


# ---------------------------------------------------------------------------
# Decode parity with the teacher-forced full forward (the serving programs
# themselves: prefill + N serve steps vs one train-mode pass)
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["qwen2-7b", "qwen2-7b-window", "rwkv6-1.6b", "zamba2-1.2b",
                "olmoe-1b-7b"]


def _parity_cfg(arch):
    if arch == "qwen2-7b-window":
        return get_config("qwen2-7b").reduced().replace(sliding_window=8)
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity drops depend on the other rows in the batch; give every
        # token a guaranteed expert seat so decode matches teacher-forcing
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    return cfg


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_serve_steps_match_teacher_forced(arch):
    """prefill(L) + serve steps over the true continuation == train-mode
    logits at every decoded position (incl. ring-cache past the window)."""
    cfg = _parity_cfg(arch)
    params = _params(cfg)
    B, L, S = 2, 6, 14
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32)
    full, _, _ = apply_model(params, cfg, {"tokens": toks}, mode="train")

    cache_len = (cfg.sliding_window if cfg.sliding_window else S)
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    serve = jax.jit(make_serve_step(cfg))
    last, cache = prefill(params, {"tokens": toks[:, :L]})
    got = [last]                      # logits after position L-1
    for t in range(L, S - 1):         # feed the TRUE next tokens
        last, cache = serve(params, {"tokens": toks[:, t:t + 1]}, cache)
        got.append(last)
    got = np.asarray(jnp.stack(got, 1), np.float32)
    ref = np.asarray(full[:, L - 1:S - 1], np.float32)
    np.testing.assert_allclose(got, ref, rtol=2e-3,
                               atol=2e-3 * np.abs(ref).max())


# ---------------------------------------------------------------------------
# Engine invariants
# ---------------------------------------------------------------------------

def _requests(cfg, n, *, prompt_len=8, max_new=10, min_new=3, temp=0.7,
              seed=123, rate=2.0, rng_seed=7):
    rng = np.random.default_rng(rng_seed)
    reqs = synthetic_requests(cfg, n, prompt_len=prompt_len, rng=rng,
                              max_new_tokens=max_new, min_new_tokens=min_new,
                              temperature=temp, seed=seed)
    return PoissonArrivals(rate, seed=1).assign(reqs)


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-1.6b", "zamba2-1.2b"])
def test_engine_matches_static_bitwise(arch):
    """Continuous batching returns the EXACT token streams the static-batch
    path does — slots get reused (7 requests, 3 slots), stop lengths are
    heterogeneous, sampling is temperature>0 — and the decode program
    compiles exactly once."""
    cfg = shrunk(arch)
    params = _params(cfg)
    reqs = _requests(cfg, 7)
    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=3, cache_len=32))
    out_c, sum_c = eng.run([r.replace() for r in reqs],
                           clock=VirtualClock(step_s=0.05))
    out_s, sum_s = run_static(cfg, params, [r.replace() for r in reqs],
                              n_slots=3, cache_len=32,
                              clock=VirtualClock(step_s=0.05))
    assert set(out_c) == {r.rid for r in reqs} == set(out_s)
    for r in reqs:
        np.testing.assert_array_equal(out_c[r.rid], out_s[r.rid])
    assert eng.decode_cache_size() == 1
    assert sum_c["generated_tokens"] == sum_s["generated_tokens"]


def test_engine_run_deterministic_and_seed_sensitive():
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    reqs = _requests(cfg, 5, temp=1.1)
    runs = []
    for _ in range(2):
        eng = DecodeEngine(cfg, params, EngineConfig(n_slots=2, cache_len=32))
        out, _ = eng.run([r.replace() for r in reqs],
                         clock=VirtualClock())
        runs.append(out)
    for r in reqs:
        np.testing.assert_array_equal(runs[0][r.rid], runs[1][r.rid])
    # different per-request seeds must change at least one sampled stream
    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=2, cache_len=32))
    out2, _ = eng.run([r.replace(seed=r.seed + 777) for r in reqs],
                      clock=VirtualClock())
    assert any(not np.array_equal(runs[0][r.rid], out2[r.rid])
               for r in reqs)


def test_evict_readmit_bitwise():
    """A request evicted mid-decode and readmitted (into a different slot)
    continues bitwise identically to the uninterrupted run."""
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    rng = np.random.default_rng(3)
    reqs = synthetic_requests(cfg, 3, prompt_len=8, rng=rng,
                              max_new_tokens=12, min_new_tokens=12,
                              temperature=0.9, seed=9)

    ref_eng = DecodeEngine(cfg, params, EngineConfig(n_slots=3, cache_len=32))
    for r in reqs:
        ref_eng.admit(r.replace())
    while ref_eng.n_active():
        ref_eng.decode_step()

    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=3, cache_len=32))
    for r in reqs:
        eng.admit(r.replace())
    for _ in range(4):
        eng.decode_step()
    snap = eng.evict(0)
    for _ in range(3):
        eng.decode_step()             # the others keep decoding
    new_slot = eng.readmit(snap)      # slot 0 is free again, but any works
    assert eng.slots[new_slot].evictions == 1
    while eng.n_active():
        eng.decode_step()

    for r in reqs:
        np.testing.assert_array_equal(eng.outputs[r.rid],
                                      ref_eng.outputs[r.rid])
    evicted = [rec for rec in eng.metrics.records if rec.evictions][0]
    assert evicted.rid == reqs[0].rid


def test_decode_program_compiles_once_across_prompt_lengths():
    """Mixed prompt lengths retrace PREFILL (one trace per length) but
    never the decode program — the continuous-batching contract."""
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    reqs = []
    for i, L in enumerate([4, 7, 4, 11, 7, 11]):
        toks = rng.integers(5, cfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=5))
    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=2, cache_len=32))
    out, _ = eng.run(reqs, clock=VirtualClock())
    assert eng.decode_cache_size() == 1
    assert eng.prefill_cache_size() == 3          # lengths {4, 7, 11}
    assert all(len(out[r.rid]) == 5 for r in reqs)


def test_engine_stop_conditions_and_capacity():
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(5, cfg.vocab_size, (8,)).astype(np.int32)
    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=1, cache_len=16))
    # max_new_tokens is exact
    out, _ = eng.run([Request(rid=0, tokens=toks, max_new_tokens=6)],
                     clock=VirtualClock())
    assert len(out[0]) == 6
    # prompt + max_new must fit the slot
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.admit(Request(rid=1, tokens=toks, max_new_tokens=100))
    # eos stops early: greedy decode of this model must emit SOME token
    # twice in a row eventually; use the first generated token as eos
    first = int(out[0][0])
    out2, _ = eng.run([Request(rid=2, tokens=toks, max_new_tokens=6,
                               eos_id=first)], clock=VirtualClock())
    assert len(out2[2]) == 1 and int(out2[2][0]) == first


# ---------------------------------------------------------------------------
# Scheduler / traffic / clocks
# ---------------------------------------------------------------------------

def test_poisson_arrivals_seeded_and_monotone():
    gen = PoissonArrivals(rate_rps=4.0, seed=11)
    t1, t2 = gen.times(50), PoissonArrivals(4.0, seed=11).times(50)
    np.testing.assert_array_equal(t1, t2)
    assert np.all(np.diff(t1) >= 0) and t1[0] > 0
    assert not np.array_equal(t1, PoissonArrivals(4.0, seed=12).times(50))
    # empirical mean inter-arrival ~ 1/rate
    assert abs(np.diff(t1).mean() - 0.25) < 0.15
    np.testing.assert_array_equal(PoissonArrivals(0.0).times(5), np.zeros(5))


def test_fifo_scheduler_releases_in_arrival_order():
    reqs = [Request(rid=i, tokens=np.zeros(4, np.int32)) for i in range(4)]
    reqs = PoissonArrivals(5.0, seed=2).assign(reqs)
    sched = FIFOScheduler(list(reversed(reqs)))   # insertion order irrelevant
    assert sched.next_ready(now=0.0) is None      # nothing has arrived at t=0
    assert sched.next_arrival() == min(r.arrival_s for r in reqs)
    got = []
    while sched.waiting:
        r = sched.next_ready(now=1e9)
        got.append(r.rid)
    assert got == [r.rid for r in sorted(reqs, key=lambda r: r.arrival_s)]


def test_virtual_clock():
    clk = VirtualClock(step_s=0.5)
    clk.start()
    clk.tick(); clk.tick()
    assert clk.now() == 1.0
    clk.advance_to(0.2)               # never goes backwards
    assert clk.now() == 1.0
    clk.advance_to(3.0)
    assert clk.now() == 3.0


# ---------------------------------------------------------------------------
# Checkpoint loading
# ---------------------------------------------------------------------------

def test_load_serving_params_roundtrip(tmp_path):
    """Bare and FedSession-style archives both restore bitwise; the arch
    fingerprint guards against serving the wrong config."""
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    want = tree_digest(params)

    bare = os.path.join(tmp_path, "bare")
    save_checkpoint(bare, 3, params)
    got, step, fed = load_serving_params(bare, cfg)
    assert step == 3 and fed is None and tree_digest(got) == want

    wrapped = os.path.join(tmp_path, "wrapped")
    state = FederatedState(round=2, plan={"extra": {"arch": cfg.name}})
    save_checkpoint(wrapped, 2, {"params": params, "server": {}},
                    extra=state.to_json())
    got, step, fed = load_serving_params(wrapped, cfg)
    assert step == 2 and fed.round == 2 and tree_digest(got) == want

    with pytest.raises(ValueError, match="was trained as"):
        load_serving_params(wrapped, cfg.replace(name="other-arch"))
    got, _, _ = load_serving_params(wrapped, cfg.replace(name="other-arch"),
                                    check_arch=False)
    assert tree_digest(got) == want
    with pytest.raises(FileNotFoundError):
        load_serving_params(os.path.join(tmp_path, "empty"), cfg)


# ---------------------------------------------------------------------------
# Metrics / throughput definition / finiteness trace
# ---------------------------------------------------------------------------

def test_metrics_summary_schema(tmp_path):
    m = ServeMetrics(n_slots=2, slot_tokens=16)
    m.on_step(2, 20)
    m.on_step(1, 12)
    from repro.serve.metrics import RequestRecord
    m.finish(RequestRecord(rid=0, arrival_s=0.0, admit_s=0.1,
                           first_token_s=0.2, finish_s=1.0, prompt_len=8,
                           n_generated=10))
    s = m.summary()
    assert set(s) == set(BENCH_MODE_KEYS)
    assert s["n_requests"] == 1 and s["generated_tokens"] == 10
    assert s["tokens_per_s"] == pytest.approx(10.0)
    assert s["ttft_s"]["p50"] == pytest.approx(0.2)
    assert s["slot_occupancy"] == pytest.approx(0.75)
    assert s["cache_occupancy"] == pytest.approx(0.5)
    p = write_bench(os.path.join(tmp_path, "B.json"), s)
    import json
    assert set(json.load(open(p))) == set(BENCH_MODE_KEYS)


def test_throughput_counts_prefill_token():
    # 2 sequences x 5 new tokens each = 10, prefill-produced token included
    assert generated_tokens(2, 5) == 10
    assert tokens_per_s(10, 2.0) == 5.0
    assert tokens_per_s(10, 0.0) > 0          # guarded denominator


def test_finite_trace_reports_first_failing_step():
    tr = FiniteTrace()
    good = jnp.ones((2, 4))
    bad = good.at[1, 2].set(jnp.nan)
    for lg in (good, good, bad, good, bad):
        tr.update(lg)
    assert tr.first_failure() == 2
    with pytest.raises(FloatingPointError, match="step 2 of 5"):
        tr.assert_finite("unit")
    ok = FiniteTrace()
    ok.update(good)
    assert ok.first_failure() is None
    ok.assert_finite()


def test_engine_flags_midstream_nan():
    """A NaN injected into a slot's accumulated flag surfaces as a
    FloatingPointError when that request completes."""
    cfg = shrunk("qwen2-7b")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(5, cfg.vocab_size, (6,)).astype(np.int32)
    eng = DecodeEngine(cfg, params, EngineConfig(n_slots=1, cache_len=16))
    eng.admit(Request(rid=0, tokens=toks, max_new_tokens=4))
    eng._finite[0] = False            # as if some step went non-finite
    with pytest.raises(FloatingPointError, match="request 0"):
        while eng.n_active():
            eng.decode_step()
