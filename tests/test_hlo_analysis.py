"""HLO analyzer validation: its scan-aware totals must reproduce XLA's own
cost_analysis on programs where cost_analysis is trustworthy (no loops)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry as T


def _grad_prog(unroll):
    def g(W, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(jax.checkpoint(body), x, W,
                            unroll=8 if unroll else 1)
        return jnp.sum(y)
    return jax.grad(g)


W = jnp.zeros((8, 256, 256))
X = jnp.zeros((32, 256))


def test_analyzer_matches_cost_analysis_unrolled():
    c = jax.jit(_grad_prog(True)).lower(W, X).compile()
    want = T.xla_flops(c)
    got = T.analyze(c.as_text()).dot_flops
    assert abs(got - want) / want < 0.05


def test_analyzer_scan_counts_trip():
    """Scanned program: analyzer must count ~L x body (cost_analysis doesn't)."""
    cs = jax.jit(_grad_prog(False)).lower(W, X).compile()
    cu = jax.jit(_grad_prog(True)).lower(W, X).compile()
    scanned = T.analyze(cs.as_text()).dot_flops
    unrolled = T.xla_flops(cu)
    # scanned remat keeps the last layer's recompute (no DCE) -> up to 4/3
    assert 0.9 * unrolled < scanned < 1.5 * unrolled
    # and cost_analysis on the scanned program is known to undercount
    assert T.xla_flops(cs) < 0.5 * scanned


def test_trip_count_extraction():
    def f(xs, c):
        return jax.lax.scan(lambda c, x: (c + x, None), c, xs)[0]
    co = jax.jit(f).lower(jnp.zeros((23, 4)), jnp.zeros((4,))).compile()
    comps = T.parse_computations(co.as_text())
    trips = []
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                trips.append(T.trip_count(op, comps))
    assert 23 in trips


def test_trip_count_condition_fallback():
    """Without a recorded known_trip_count the condition-constant heuristic
    must still find the scan length."""
    def f(xs, c):
        return jax.lax.scan(lambda c, x: (c + x, None), c, xs)[0]
    co = jax.jit(f).lower(jnp.zeros((23, 4)), jnp.zeros((4,))).compile()
    comps = T.parse_computations(co.as_text())
    trips = []
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                cond, _ = T.while_parts(op)
                if cond in comps:
                    trips.append(T.cond_trip_count(comps[cond]))
    assert 23 in trips


FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _fixture(name):
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


def test_collective_bytes_on_sharded_program():
    """Collective-byte counting on a real >1-device partitioned program,
    without multi-device hardware: the committed 512-device dry-run-style
    fixture (tests/fixtures/gen_sharded_fixture.py) is a data-parallel
    gradient whose only collective is the dW all-reduce."""
    stats = T.analyze(_fixture("sharded_grad_512dev.hlo.txt"))
    rec = json.loads(_fixture("sharded_grad_512dev.json"))
    # the replicated (256, 256) f32 gradient all-reduce must be counted
    assert stats.collective_bytes["all-reduce"] >= \
        rec["expected_allreduce_bytes_min"]
    # and the totals are pinned to what the generator recorded
    got = {k: int(v) for k, v in stats.collective_bytes.items() if v}
    assert got == rec["collective_bytes_per_device"]
    assert stats.dot_flops == pytest.approx(rec["dot_flops_per_device"])
    assert stats.hbm_bytes == pytest.approx(rec["hbm_bytes_per_device"])


def test_sharded_fixture_flops_vs_cost_analysis():
    """On the loop-free partitioned program the analyzer's dot FLOPs agree
    with XLA's own cost_analysis (recorded at generation time) to ~15% —
    cost_analysis also counts the tanh/transcendental ops the dot rule
    deliberately excludes."""
    stats = T.analyze(_fixture("sharded_grad_512dev.hlo.txt"))
    rec = json.loads(_fixture("sharded_grad_512dev.json"))
    ca = rec["cost_analysis_flops_per_device"]
    assert abs(stats.dot_flops - ca) / ca < 0.15


def test_dot_flops_formula():
    def f(a, b):
        return jnp.einsum("ij,jk->ik", a, b)
    co = jax.jit(f).lower(jnp.zeros((17, 33)), jnp.zeros((33, 9))).compile()
    got = T.analyze(co.as_text()).dot_flops
    assert got == pytest.approx(2 * 17 * 33 * 9, rel=0.01)


def test_dot_flops_batched():
    """Batch dims count once (via the result), contracting dims once (via
    the lhs) — the dot_general rule the seed analyzer miscounted."""
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    co = jax.jit(f).lower(jnp.zeros((5, 17, 33)), jnp.zeros((5, 33, 9))
                          ).compile()
    got = T.analyze(co.as_text()).dot_flops
    assert got == pytest.approx(2 * 5 * 17 * 33 * 9, rel=0.01)
    assert got == pytest.approx(T.xla_flops(co), rel=0.01)


def test_hbm_bytes_order_of_magnitude():
    def f(a, b):
        return a @ b
    co = jax.jit(f).lower(jnp.zeros((512, 512)), jnp.zeros((512, 512))).compile()
    got = T.analyze(co.as_text()).hbm_bytes
    want = 3 * 512 * 512 * 4              # 2 reads + 1 write
    assert 0.5 * want < got < 4 * want
