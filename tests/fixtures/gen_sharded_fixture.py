"""Regenerate the 512-device partitioned-program fixtures.

The dry-run (``repro.launch.dryrun``) proves the production sharding on 512
forced host devices; tier-1 must exercise the SAME property — collective-
byte counting on a >1-device partitioned program — without paying a big
compile in every test run.  This script lowers a minimal data-parallel
gradient program on a 512-device host mesh (the gradient of a replicated
weight under a batch-sharded input is exactly one all-reduce — FedAvg's wire
pattern), then freezes:

  * ``sharded_grad_512dev.hlo.txt``  — the partitioned HLO text the analyzer
    parses in ``tests/test_hlo_analysis.py``;
  * ``sharded_grad_512dev.json``     — a dry-run-style record (analyzer
    collective bytes per kind, dot FLOPs, XLA cost_analysis FLOPs, shapes)
    pinning the expected numbers.

Run from the repo root when jax or the program changes:

    PYTHONPATH=src python tests/fixtures/gen_sharded_fixture.py
"""

import json
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec    # noqa: E402

from repro import telemetry as T                               # noqa: E402

HERE = os.path.dirname(__file__)
N_DEV = 512
D = 256            # weight is (D, D) fp32
B = 1024           # global batch, sharded over all devices


def main():
    assert len(jax.devices()) == N_DEV, len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(N_DEV), ("data",))

    def loss(W, x):
        return jnp.sum(jnp.tanh(x @ W))

    grad = jax.grad(loss)
    w_sh = NamedSharding(mesh, PartitionSpec())             # replicated
    x_sh = NamedSharding(mesh, PartitionSpec("data", None))  # batch-sharded
    W = jax.ShapeDtypeStruct((D, D), jnp.float32)
    X = jax.ShapeDtypeStruct((B, D), jnp.float32)
    compiled = (jax.jit(grad, in_shardings=(w_sh, x_sh), out_shardings=w_sh)
                .lower(W, X).compile())
    hlo = compiled.as_text()
    stats = T.analyze(hlo)

    with open(os.path.join(HERE, "sharded_grad_512dev.hlo.txt"), "w") as f:
        f.write(hlo)
    record = {
        "program": "grad(sum(tanh(x @ W))) wrt W",
        "n_devices": N_DEV,
        "mesh": [N_DEV], "axes": ["data"],
        "weight_shape": [D, D], "batch_shape": [B, D], "dtype": "f32",
        # the dW all-reduce: the full replicated gradient, result bytes
        "expected_allreduce_bytes_min": D * D * 4,
        "collective_bytes_per_device": {k: int(v) for k, v
                                        in stats.collective_bytes.items()
                                        if v},
        "dot_flops_per_device": float(stats.dot_flops),
        "hbm_bytes_per_device": float(stats.hbm_bytes),
        "cost_analysis_flops_per_device": T.xla_flops(compiled),
        "jax_version": jax.__version__,
    }
    with open(os.path.join(HERE, "sharded_grad_512dev.json"), "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))


if __name__ == "__main__":
    main()
