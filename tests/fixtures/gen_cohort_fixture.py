"""Regenerate the 512-device cohort-aggregation fixtures.

The cohort-scan engine's per-shard aggregation is algebraically a weighted
sum over the shard's client axis; at production mesh scale that axis shards
over the whole machine (``repro.sharding.rules.COHORT_RULES``) and the sum
lowers — like ``fedavg_stacked`` in the mesh round program — to exactly ONE
all-reduce whose payload is one model's bytes, regardless of how many
clients the shard holds.  This script resolves the client-sharded layout
through COHORT_RULES on a 512 forced host devices mesh, compiles the
partial-update program, and freezes:

  * ``cohort_agg_512dev.hlo.txt`` — the partitioned HLO text;
  * ``cohort_agg_512dev.json``    — the analyzer's collective bytes per
    kind plus the expected all-reduce payload (weight bytes), pinned by
    ``tests/test_sharding.py``.

Run from the repo root when jax or the program changes:

    PYTHONPATH=src python tests/fixtures/gen_cohort_fixture.py
"""

import json
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec    # noqa: E402

from repro import telemetry as T                               # noqa: E402
from repro.nn import param as P                                # noqa: E402
from repro.sharding.rules import COHORT_RULES, logical_to_spec  # noqa: E402

HERE = os.path.dirname(__file__)
N_DEV = 512
K = 512            # one cohort shard: one client per device
D = 256            # each client's "model" is (D, D) fp32


def main():
    assert len(jax.devices()) == N_DEV, len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(N_DEV), ("data",))

    # the layout COHORT_RULES resolves for a (client, embed, ffn) tensor on
    # this mesh: client axis sharded over every mesh axis, weights replicated
    spec = logical_to_spec((P.CLIENT, P.EMBED, P.FFN), (K, D, D), mesh,
                           COHORT_RULES)
    assert spec == PartitionSpec("data"), spec

    def agg_partial(partial, stacked, w):
        # one shard folded into the carry: algebraically sum_k w_k * W_k
        return partial + jnp.sum(stacked * w[:, None, None], axis=0)

    part_sh = NamedSharding(mesh, PartitionSpec())       # carry: replicated
    stack_sh = NamedSharding(mesh, spec)                 # clients: sharded
    w_sh = NamedSharding(mesh, PartitionSpec("data"))
    Pa = jax.ShapeDtypeStruct((D, D), jnp.float32)
    S = jax.ShapeDtypeStruct((K, D, D), jnp.float32)
    W = jax.ShapeDtypeStruct((K,), jnp.float32)
    compiled = (jax.jit(agg_partial,
                        in_shardings=(part_sh, stack_sh, w_sh),
                        out_shardings=part_sh)
                .lower(Pa, S, W).compile())
    hlo = compiled.as_text()
    stats = T.analyze(hlo)

    with open(os.path.join(HERE, "cohort_agg_512dev.hlo.txt"), "w") as f:
        f.write(hlo)
    record = {
        "program": "partial + sum_k w_k * W_k (client axis mesh-sharded)",
        "n_devices": N_DEV,
        "mesh": [N_DEV], "axes": ["data"],
        "client_spec": ["data"],
        "shard_clients": K, "weight_shape": [D, D], "dtype": "f32",
        # the aggregation all-reduce: one model's bytes, independent of K
        "expected_allreduce_bytes_min": D * D * 4,
        "collective_bytes_per_device": {k: int(v) for k, v
                                        in stats.collective_bytes.items()
                                        if v},
        "jax_version": jax.__version__,
    }
    with open(os.path.join(HERE, "cohort_agg_512dev.json"), "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))


if __name__ == "__main__":
    main()


