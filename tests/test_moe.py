"""MoE routing/dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.nn.moe import (apply_moe, dispatch_indices, expert_capacity,
                          load_balance_loss, route_topk)
from repro.nn.param import ParamCtx, unbox
from repro.nn import moe as moe_mod

KEY = jax.random.PRNGKey(0)


@settings(max_examples=30, deadline=None)
@given(T=st.integers(1, 200), E=st.integers(2, 64), k=st.integers(1, 8),
       cf=st.floats(0.5, 4.0))
def test_capacity_bounds(T, E, k, cf):
    k = min(k, E)
    C = expert_capacity(T, E, k, cf)
    assert C >= 8 and C % 8 == 0
    assert C >= np.ceil(T * k / E * cf)


def test_route_topk_normalized():
    logits = jax.random.normal(KEY, (10, 8))
    gates, idx, probs = route_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8
    # picks are distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == 3


def test_dispatch_each_assignment_at_most_once():
    idx = jax.random.randint(KEY, (40, 2), 0, 4)
    C = expert_capacity(40, 4, 2, 2.0)
    buf, gatep, valid = dispatch_indices(idx, C, 4)
    idxn = np.asarray(idx)
    pairs = set()
    for e in range(4):
        for c in range(C):
            if bool(valid[e, c]):
                t, p = int(buf[e, c]), int(gatep[e, c])
                assert idxn[t, p] == e            # slot really routed here
                assert (t, p) not in pairs        # no duplicates
                pairs.add((t, p))


def test_no_drop_capacity_routes_everything():
    idx = jax.random.randint(KEY, (64, 2), 0, 4)
    C = expert_capacity(64, 4, 2, 4.0)            # cf = E/k * 2 -> no drops
    buf, gatep, valid = dispatch_indices(idx, C, 4)
    assert int(valid.sum()) == 64 * 2


def test_balanced_router_low_aux():
    T, E = 512, 8
    uniform = jnp.ones((T, E)) / E
    idx = jnp.tile(jnp.arange(E), T // E * 2)[:T * 2].reshape(T, 2) % E
    aux_u = load_balance_loss(uniform, idx, E)
    # collapsed router: all mass on expert 0
    collapsed = jnp.zeros((T, E)).at[:, 0].set(1.0)
    idx0 = jnp.zeros((T, 2), jnp.int32)
    aux_c = load_balance_loss(collapsed, idx0, E)
    assert float(aux_c) > 2 * float(aux_u)


def test_apply_moe_zero_router_is_mean_of_topk():
    """With huge capacity and no drops, output is a convex combination of
    expert outputs; sanity: finite, correct shape, aux finite."""
    ctx = ParamCtx(KEY, jnp.float32)
    p = unbox(moe_mod.init_moe(ctx, 16, 32, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y, aux = apply_moe(p, x, 2, capacity_factor=4.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.isfinite(aux))


def test_moe_pallas_path_matches_xla():
    ctx = ParamCtx(KEY, jnp.float32)
    p = unbox(moe_mod.init_moe(ctx, 16, 32, 4))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
    y1, _ = apply_moe(p, x, 2, capacity_factor=4.0, impl="xla")
    y2, _ = apply_moe(p, x, 2, capacity_factor=4.0, impl="pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_grouped_dispatch_matches_global_no_drops():
    """Local dispatch (per-group capacity) == global dispatch when capacity
    admits every assignment."""
    ctx = ParamCtx(KEY, jnp.float32)
    p = unbox(moe_mod.init_moe(ctx, 16, 32, 4))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    y1, _ = apply_moe(p, x, 2, capacity_factor=4.0, groups=0)
    y2, _ = apply_moe(p, x, 2, capacity_factor=4.0, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-6)


def test_grouped_dispatch_falls_back_when_indivisible():
    ctx = ParamCtx(KEY, jnp.float32)
    p = unbox(moe_mod.init_moe(ctx, 16, 32, 4))
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 5, 16))   # 15 tokens
    y, aux = apply_moe(p, x, 2, capacity_factor=4.0, groups=4)  # 15 % 4 != 0
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
