"""Sharding-rule resolution: divisibility fallbacks, axis-reuse protection,
per-arch spec sanity.  Uses a small host mesh (1 device is fine: rules are
pure functions of mesh SHAPE, so we build abstract meshes)."""

import json
import os

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec

from repro.nn import param as P
from repro.sharding.rules import (COHORT_RULES, DECODE_RULES, DEFAULT_RULES,
                                  FED_RULES, LONG_CONTEXT_RULES, OPT_RULES,
                                  logical_to_spec, spec_bytes_per_device)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _ent(spec, i):
    """PartitionSpec trims trailing Nones; index safely."""
    return spec[i] if i < len(spec) else None


def _mesh(shape, axes):
    devs = np.asarray(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = _mesh((16, 16), ("data", "model"))
POD = _mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    spec = logical_to_spec((P.EMBED, P.FFN), (4096, 16384), MESH)
    assert spec == PartitionSpec("data", "model")


def test_indivisible_falls_back_to_replicated():
    # qwen2: 28 heads on a 16-way model axis
    spec = logical_to_spec((P.EMBED, P.HEADS, P.HEAD_DIM), (3584, 28, 128), MESH)
    assert _ent(spec, 1) is None             # heads replicated
    assert _ent(spec, 0) == "data"


def test_no_axis_reuse_within_tensor():
    # batch takes ("pod","data"); embed must not reuse data
    spec = logical_to_spec((P.BATCH, P.SEQ, P.EMBED), (256, 4096, 4096), POD)
    assert spec[0] == ("pod", "data")
    flat = []
    for s in spec:
        if s is None:
            continue
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_batch_takes_pod_and_data_multipod():
    spec = logical_to_spec((P.BATCH, P.SEQ), (256, 4096), POD)
    assert spec[0] == ("pod", "data")


def test_decode_rules_shard_cache_seq():
    spec = logical_to_spec((P.LAYERS, P.BATCH, P.SEQ, P.KV_HEADS, P.HEAD_DIM),
                           (40, 128, 32768, 8, 128), MESH, DECODE_RULES)
    assert _ent(spec, 1) == "data" and _ent(spec, 2) == "model"
    assert _ent(spec, 3) is None             # 8 kv heads can't take model


def test_long_context_rules_shard_seq_both_axes():
    spec = logical_to_spec((P.LAYERS, P.BATCH, P.SEQ, P.KV_HEADS, P.HEAD_DIM),
                           (28, 1, 8192, 4, 128), MESH, LONG_CONTEXT_RULES)
    assert spec[2] == ("data", "model")


def test_fed_rules_pin_client_to_pod():
    spec = logical_to_spec((P.CLIENT, P.EMBED), (2, 4096), POD, FED_RULES)
    assert spec[0] == "pod"


def test_opt_rules_context_parallel_attention():
    spec = logical_to_spec((P.BATCH, P.ATTN_SEQ, P.HEADS, P.HEAD_DIM),
                           (256, 4096, 28, 128), MESH, OPT_RULES)
    assert spec[1] == "model"                # seq takes model when heads can't
    base = logical_to_spec((P.BATCH, P.ATTN_SEQ, P.HEADS, P.HEAD_DIM),
                           (256, 4096, 28, 128), MESH, DEFAULT_RULES)
    assert _ent(base, 1) is None


def test_spec_bytes_per_device():
    spec = PartitionSpec("data", "model")
    b = spec_bytes_per_device((4096, 16384), np.float32, spec, MESH)
    assert b == 4096 * 16384 * 4 // 256


# ---------------------------------------------------------------------------
# COHORT_RULES: the cohort-scan shard layout
# ---------------------------------------------------------------------------

def test_cohort_rules_client_takes_whole_mesh():
    # a shard of 512 clients on the 2x16x16 pod mesh: client axis over all
    # three mesh axes (512 divides 512), within-client dims replicated
    spec = logical_to_spec((P.CLIENT, P.EMBED, P.FFN), (512, 768, 3072),
                           POD, COHORT_RULES)
    assert spec[0] == ("pod", "data", "model")
    assert _ent(spec, 1) is None and _ent(spec, 2) is None


def test_cohort_rules_partial_mesh_fallback():
    # 32 clients can't take the full 512-way product; falls through to the
    # first divisible candidate
    spec = logical_to_spec((P.CLIENT, P.EMBED), (32, 768), POD, COHORT_RULES)
    assert spec[0] == ("pod", "data")
    # indivisible everywhere -> replicated
    spec = logical_to_spec((P.CLIENT, P.EMBED), (7, 768), POD, COHORT_RULES)
    assert _ent(spec, 0) is None


def test_cohort_rules_per_client_shard_bytes():
    # the memory model the engine promises: per-device bytes of a sharded
    # 512-client stack equal ONE client's tensor
    spec = logical_to_spec((P.CLIENT, P.EMBED, P.FFN), (512, 768, 3072),
                           POD, COHORT_RULES)
    b = spec_bytes_per_device((512, 768, 3072), np.float32, spec, POD)
    assert b == 768 * 3072 * 4


def test_cohort_agg_fixture_collective_bytes():
    """The committed 512-device HLO fixture: a COHORT_RULES-sharded shard
    aggregation lowers to exactly one all-reduce of one model's bytes,
    independent of how many clients the shard holds."""
    from repro import telemetry as T
    with open(os.path.join(FIXTURES, "cohort_agg_512dev.json")) as f:
        rec = json.load(f)
    with open(os.path.join(FIXTURES, "cohort_agg_512dev.hlo.txt")) as f:
        stats = T.analyze(f.read())
    want = rec["weight_shape"][0] * rec["weight_shape"][1] * 4
    assert rec["expected_allreduce_bytes_min"] == want
    assert stats.collective_bytes["all-reduce"] >= want
    # ... and not meaningfully more: the payload is O(model), NOT O(clients)
    assert stats.collective_bytes["all-reduce"] < want * rec["shard_clients"]
    assert {k: int(v) for k, v in stats.collective_bytes.items() if v} \
        == rec["collective_bytes_per_device"]
