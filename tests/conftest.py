import jax
import numpy as np
import pytest

# Smoke tests and benches see ONE device (the dry-run sets its own
# XLA_FLAGS=512 in a separate process; never here).
assert len(jax.devices()) >= 1


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
