"""Checkpoint roundtrip, rotation, federated-state resume."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (FederatedState, latest_step, restore_checkpoint,
                              save_checkpoint, tree_digest)
from repro.checkpoint.npz import restore_extra


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layers": {"w": jnp.asarray(rng.normal(0, 1, (3, 4)), jnp.float32),
                       "b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.bfloat16)},
            "head": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"round": 7})
    assert latest_step(str(tmp_path)) == 7
    got = restore_checkpoint(str(tmp_path), 7, jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert restore_extra(str(tmp_path), 7) == {"round": 7}


def test_rotation_keeps_last(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    import os
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(ckpts) == 3
    assert latest_step(str(tmp_path)) == 5


def test_preempted_save_leaves_no_torn_checkpoint(tmp_path):
    """Writes are atomic: a save killed mid-way leaves temp files and/or an
    orphan sidecar, never a visible-but-incomplete ckpt_N.npz — resume
    keys on the archive, so it falls back to the last complete pair.  The
    next successful save sweeps the debris."""
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t, extra={"round": 1})
    # simulate a preemption between the two renames: sidecar landed, the
    # archive is still a temp file
    (tmp_path / "ckpt_00000002.json").write_text("{}")
    (tmp_path / "ckpt_00000002.npz.tmp").write_bytes(b"torn")
    assert latest_step(str(tmp_path)) == 1          # torn step invisible
    got = restore_checkpoint(str(tmp_path), 1, jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t))
    assert tree_digest(got) == tree_digest(t)
    save_checkpoint(str(tmp_path), 3, t, extra={"round": 3})
    import os
    left = sorted(os.listdir(tmp_path))
    assert "ckpt_00000002.json" not in left          # orphan swept
    assert not any(f.endswith(".tmp") for f in left)


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, _tree())
    bad = {"layers": {"w": jax.ShapeDtypeStruct((9, 9), jnp.float32),
                      "b": jax.ShapeDtypeStruct((4,), jnp.bfloat16)},
           "head": jax.ShapeDtypeStruct((2,), jnp.int32)}
    try:
        restore_checkpoint(str(tmp_path), 0, bad)
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_federated_state_json():
    st = FederatedState(round=4, ffdapt_start=3)
    assert FederatedState.from_json(st.to_json()) == st


def test_federated_state_full_roundtrip():
    """The extended resume contract: RNG bit-state, serialized history, and
    the plan fingerprint all survive a json.dumps/loads cycle exactly."""
    rng = np.random.default_rng(123)
    rng.choice(10, size=3, replace=False)          # advance the stream
    hist = [{"round": 0, "loss": 1.5, "clients": [0, 2],
             "client_upload_bytes": [7, 6], "windows": [[0, 2], [2, 1]]}]
    st = FederatedState(round=1, ffdapt_start=3,
                        rng_state=rng.bit_generator.state, history=hist,
                        plan={"strategy": "fedavgm", "seed": 0,
                              "participation": 0.5})
    thawed = FederatedState.from_json(json.loads(json.dumps(st.to_json())))
    assert thawed == st
    # the restored bit-state continues the exact stream
    r2 = np.random.default_rng(0)
    r2.bit_generator.state = thawed.rng_state
    np.testing.assert_array_equal(rng.choice(100, 5), r2.choice(100, 5))


def test_federated_state_ignores_unknown_keys():
    # old sidecars (or future fields) must not break from_json
    st = FederatedState.from_json({"round": 2, "ffdapt_start": 1,
                                   "someday": "maybe"})
    assert st.round == 2 and st.ffdapt_start == 1


def test_tree_digest_bitwise():
    t = _tree()
    assert tree_digest(t) == tree_digest(_tree())
    other = jax.tree.map(lambda l: l, t)
    other["layers"]["w"] = other["layers"]["w"].at[0, 0].add(1e-7)
    assert tree_digest(t) != tree_digest(other)


def test_digest_survives_save_restore(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    got = restore_checkpoint(str(tmp_path), 1, jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t))
    assert tree_digest(got) == tree_digest(t)
