"""Checkpoint roundtrip, rotation, federated-state resume."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (FederatedState, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.npz import restore_extra


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layers": {"w": jnp.asarray(rng.normal(0, 1, (3, 4)), jnp.float32),
                       "b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.bfloat16)},
            "head": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"round": 7})
    assert latest_step(str(tmp_path)) == 7
    got = restore_checkpoint(str(tmp_path), 7, jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert restore_extra(str(tmp_path), 7) == {"round": 7}


def test_rotation_keeps_last(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    import os
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(ckpts) == 3
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, _tree())
    bad = {"layers": {"w": jax.ShapeDtypeStruct((9, 9), jnp.float32),
                      "b": jax.ShapeDtypeStruct((4,), jnp.bfloat16)},
           "head": jax.ShapeDtypeStruct((2,), jnp.int32)}
    try:
        restore_checkpoint(str(tmp_path), 0, bad)
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_federated_state_json():
    st = FederatedState(round=4, ffdapt_start=3)
    assert FederatedState.from_json(st.to_json()) == st
