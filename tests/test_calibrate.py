"""repro.sim.calibrate coverage: the committed paper anchor round-trips
through fit -> apply -> predict to within 1%, a synthetic ground truth is
recovered, and the calibrated registry carries provenance everywhere the
fleet builders re-export it.
"""

import dataclasses

import numpy as np
import pytest

from repro.sim import (CALIBRATED_PRESETS, PAPER_2080TI_ANCHOR,
                       PAPER_2080TI_EPOCH, PAPER_2080TI_ROUND, PRESETS,
                       CalibrationPoint, apply_fit, calibrate_presets,
                       fit_device, make_fleet, predict_round_s, sample_fleet,
                       scale_device)


# ---------------------------------------------------------------------------
# the committed anchor: fit -> predict round-trips within 1%
# ---------------------------------------------------------------------------

def test_anchor_round_trips_within_one_percent():
    fit = fit_device(PAPER_2080TI_ANCHOR)
    dev = apply_fit(PRESETS["rtx2080ti"], fit)
    for p in PAPER_2080TI_ANCHOR:
        pred = predict_round_s(p, dev)
        assert abs(pred - p.measured_round_s) / p.measured_round_s < 0.01
    # the fit reports its own residual honestly
    assert fit.max_rel_err < 0.01
    assert fit.n_points == len(PAPER_2080TI_ANCHOR)
    # physically sensible factors: a real 2080 Ti cannot beat its datasheet
    assert 0.0 < fit.mfu < 1.0
    assert 0.0 < fit.bw_eff <= 1.0


def test_anchor_fixture_is_the_papers_setup():
    # the fixture is load-bearing for benchmarks/wallclock.py --calibrated:
    # pin its identity so a silent edit cannot move the anchor
    assert PAPER_2080TI_ROUND.fleet == "rtx2080ti"
    assert PAPER_2080TI_ROUND.steps == 512
    assert PAPER_2080TI_ROUND.upload_bytes == 278811648.0
    assert PAPER_2080TI_ROUND.step_flops == pytest.approx(2.0208e12,
                                                          rel=1e-3)
    assert PAPER_2080TI_EPOCH.upload_bytes == 0.0
    assert "distilbert" in PAPER_2080TI_EPOCH.config


def test_synthetic_ground_truth_recovered():
    """Generate two exact datapoints from known (mfu, bw_eff) on an a100
    profile; the fit must recover the factors and reproduce both points to
    well under 1%."""
    dev = PRESETS["a100"]
    truth = scale_device(dev, 0.42, 0.55)
    mk = lambda up, name: CalibrationPoint(
        config=name, fleet="a100", steps=100, measured_round_s=0.0,
        step_flops=5e12, step_hbm_bytes=8e9, upload_bytes=up,
        download_bytes=up)
    pts = []
    for up, name in ((0.0, "compute-only"), (5e8, "full-round")):
        p = mk(up, name)
        pts.append(dataclasses.replace(
            p, measured_round_s=predict_round_s(p, truth)))
    fit = fit_device(pts)
    assert fit.mfu == pytest.approx(0.42, rel=0.01)
    assert fit.bw_eff == pytest.approx(0.55, rel=0.01)
    fitted = apply_fit(dev, fit)
    for p in pts:
        assert predict_round_s(p, fitted) == pytest.approx(
            p.measured_round_s, rel=0.005)


def test_fit_caps_mfu_at_datasheet_peak():
    """A measured round FASTER than the datasheet roofline (bad seconds or
    bad ledger) must not fit a super-physical MFU: the mfu axis is capped
    at 1.0 and the residual reports the misfit honestly."""
    impossible = dataclasses.replace(PAPER_2080TI_EPOCH,
                                     measured_round_s=1.0)
    fit = fit_device([impossible])
    assert fit.mfu <= 1.0
    assert fit.max_rel_err > 1.0           # the misfit is visible, not hidden


def test_fit_input_validation():
    with pytest.raises(ValueError):
        fit_device([])
    mixed = [PAPER_2080TI_ROUND,
             dataclasses.replace(PAPER_2080TI_ROUND, fleet="a100")]
    with pytest.raises(ValueError):
        fit_device(mixed)
    unknown = dataclasses.replace(PAPER_2080TI_ROUND, fleet="gtx480")
    with pytest.raises(ValueError):
        fit_device([unknown])


def test_predict_overlap_never_slower():
    dev = CALIBRATED_PRESETS["rtx2080ti"]
    for p in PAPER_2080TI_ANCHOR:
        assert predict_round_s(p, dev, overlap=True) <= \
            predict_round_s(p, dev) * (1 + 1e-12)


# ---------------------------------------------------------------------------
# calibrated registry + provenance, re-exported through the fleet builders
# ---------------------------------------------------------------------------

def test_calibrated_registry_covers_every_preset_with_provenance():
    assert set(CALIBRATED_PRESETS) == set(PRESETS)
    for name, dev in CALIBRATED_PRESETS.items():
        base = PRESETS[name]
        assert dev.calibrated_from != ""           # provenance always set
        # efficiency factors only ever derate datasheet numbers here
        assert dev.peak_flops < base.peak_flops
        assert dev.up_bw < base.up_bw
        # non-efficiency fields pass through untouched
        assert dev.latency_s == base.latency_s
        assert dev.dropout == base.dropout
    # the measured preset carries its own fit, the rest a transfer prior
    assert not CALIBRATED_PRESETS["rtx2080ti"].calibrated_from.startswith(
        "transfer:")
    assert CALIBRATED_PRESETS["a100"].calibrated_from.startswith("transfer:")


def test_make_fleet_calibrated_reexport():
    plain = make_fleet("paper-2080ti", 4, seed=7)
    cal = make_fleet("paper-2080ti", 4, seed=7, calibrated=True)
    assert [d.name for d in plain.devices] == [d.name for d in cal.devices]
    assert all(d.calibrated_from == "" for d in plain.devices)
    assert all(d.calibrated_from != "" for d in cal.devices)
    assert all(c.peak_flops < p.peak_flops
               for p, c in zip(plain.devices, cal.devices))
    mix = {"a100": 0.5, "phone": 0.5}
    cal_mix = sample_fleet(mix, 8, seed=1, calibrated=True)
    assert all(d.calibrated_from for d in cal_mix.devices)


def test_calibrate_presets_custom_points():
    pts = [dataclasses.replace(PAPER_2080TI_ROUND)]
    reg = calibrate_presets(pts)
    assert set(reg) == set(PRESETS)
    with pytest.raises(ValueError):
        calibrate_presets([])
