"""Federated round-engine integration: sequential == parallel, FDAPT learns,
FFDAPT stays close to vanilla."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step, make_train_step
from repro.nn import param as P

CFG = get_config("distilbert-mlm").reduced()
# sentence-level holdout: every synthetic document has its own vocabulary
# pool, so held-out DOCUMENTS are a domain shift; the paper evaluates
# in-domain -> hold out trailing sentences of the same documents.
from repro.data.corpus import split_holdout
DOCS, HELD = split_holdout(generate_corpus(120, seed=0))
KEY = jax.random.PRNGKey(0)


def _clients(k=2, skew="iid", steps=2):
    ds = make_client_datasets(DOCS, CFG, k=k, skew=skew, batch=2, seq=32)
    return [b[:steps] for b in ds["batches"]], ds["sizes"]


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_sequential_equals_parallel(params0):
    batches, sizes = _clients()
    plan = RoundPlan(n_rounds=2, client_sizes=sizes)
    p1, h1 = FedSession(CFG, optim.adam(1e-4), plan,
                        engine="sequential").run(params0, batches)
    p2, h2 = FedSession(CFG, optim.adam(1e-4), plan,
                        engine="parallel").run(params0, batches)
    assert _maxdiff(p1, p2) < 1e-5
    assert abs(h1[-1].loss - h2[-1].loss) < 1e-3


def test_ffdapt_static_vs_masked_engines(params0):
    batches, sizes = _clients()
    plan = RoundPlan(n_rounds=2, client_sizes=sizes, ffdapt=FFDAPTConfig())
    p1, _ = FedSession(CFG, optim.adam(1e-4), plan,
                       engine="sequential").run(params0, batches)
    p2, _ = FedSession(CFG, optim.adam(1e-4), plan,
                       engine="parallel").run(params0, batches)
    assert _maxdiff(p1, p2) < 5e-4


@pytest.mark.slow
def test_fdapt_learns_and_ffdapt_tracks(params0):
    """FDAPT reduces eval loss vs init; FFDAPT lands near vanilla FDAPT —
    the paper's '<1% fluctuation' claim at smoke scale."""
    batches, sizes = _clients(k=2, steps=6)
    eval_step = jax.jit(make_eval_step(CFG))
    heldout = make_client_datasets(HELD, CFG, k=1,
                                   batch=2, seq=32)["batches"][0][:3]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in heldout]))

    init_loss = eval_loss(params0)
    p_fd, _ = FedSession(CFG, optim.adam(1e-3), n_rounds=3,
                         client_sizes=sizes).run(params0, batches)
    p_ffd, _ = FedSession(CFG, optim.adam(1e-3), n_rounds=3,
                          client_sizes=sizes,
                          ffdapt=FFDAPTConfig()).run(params0, batches)
    l_fd, l_ffd = eval_loss(p_fd), eval_loss(p_ffd)
    assert l_fd < init_loss
    assert l_ffd < init_loss
    assert abs(l_ffd - l_fd) / l_fd < 0.05


@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_eval_fn_keeps_train_loss(params0, engine):
    """Regression: eval_fn used to OVERWRITE RoundResult.loss — both values
    must survive, train loss in .loss and the eval figure in .eval_loss."""
    batches, sizes = _clients()
    plan = RoundPlan(n_rounds=1, engine=engine, client_sizes=sizes,
                     telemetry=False, eval_fn=lambda p: 123.5)
    _, hist = FedSession(CFG, optim.adam(1e-4), plan).run(params0, batches)
    assert hist[-1].eval_loss == 123.5
    assert hist[-1].loss != 123.5          # the train loss, not the eval
    assert 0.0 < hist[-1].loss < 50.0


def test_upload_byte_shares_sum_exactly(params0):
    """Regression: the per-client ledger dropped nbytes % len(part) bytes
    (top-k tie-keeps make the round total indivisible), under-counting the
    sim replay's wire traffic."""
    from repro.core.accounting import split_bytes
    from repro.core.strategy import Compressed, FedAvg
    assert split_bytes(7, 2) == [4, 3]
    assert split_bytes(9, 3) == [3, 3, 3]
    for total, k in ((10_000_001, 3), (5, 4), (0, 2)):
        shares = split_bytes(total, k)
        assert sum(shares) == total and max(shares) - min(shares) <= 1
    batches, sizes = _clients(k=3)
    plan = RoundPlan(n_rounds=2, client_sizes=sizes, telemetry=False,
                     strategy=Compressed(inner=FedAvg(), kind="topk",
                                         frac=0.3))
    _, hist = FedSession(CFG, optim.adam(1e-4), plan).run(params0, batches)
    for h in hist:
        assert sum(h.client_upload_bytes) == h.upload_bytes


def test_quantity_skew_weighting():
    """Under quantity skew the big client dominates the average (Eq. n_k/n)."""
    batches, sizes = _clients(k=2, skew="quantity")
    assert sizes[0] < sizes[1]
    p0 = P.unbox(init_model(KEY, CFG))
    opt = optim.adam(1e-3)
    step = jax.jit(make_train_step(CFG, opt))
    # one local step per client from p0
    locals_ = []
    for bs in batches:
        o = P.unbox(opt.init(p0))
        p, _, _ = step(p0, o, bs[0])
        locals_.append(p)
    from repro.core.fedavg import fedavg
    agg = fedavg(locals_, sizes)
    w = sizes[1] / sum(sizes)
    leaf = "final_norm"
    want = (1 - w) * locals_[0][leaf]["scale"] + w * locals_[1][leaf]["scale"]
    np.testing.assert_allclose(np.asarray(agg[leaf]["scale"]),
                               np.asarray(want), rtol=1e-5)
