"""FFDAPT Algorithm-1 properties + freeze execution semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import optim
from repro.configs import get_config
from repro.core import ffdapt
from repro.models.model import init_model, n_freeze_units
from repro.models.steps import make_masked_train_step, make_train_step
from repro.nn import param as P
from repro.nn.stack import freeze_window_mask, mask_segments

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# schedule properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(n_layers=st.integers(2, 64),
       sizes=st.lists(st.integers(1, 500), min_size=1, max_size=8),
       rounds=st.integers(1, 10),
       gamma=st.floats(0.25, 3.0))
def test_schedule_invariants(n_layers, sizes, rounds, gamma):
    sched = ffdapt.schedule(n_layers, sizes, rounds, gamma=gamma)
    assert len(sched) == rounds
    eps = n_layers - 1
    ptr = 0
    for rnd in sched:
        assert len(rnd) == len(sizes)
        for (start, nf) in rnd:
            assert 0 <= nf <= eps          # never freezes everything
            assert 0 <= start < n_layers
            assert start == ptr            # rotation is consecutive
            ptr = (ptr + nf) % n_layers


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), start=st.integers(0, 80), nf=st.integers(0, 80))
def test_window_mask_wrap(n, start, nf):
    mask = freeze_window_mask(n, (start, nf))
    assert len(mask) == n
    assert sum(mask) == min(nf, n)
    # frozen set must equal {(start+i) % n}
    want = {(start + i) % n for i in range(min(nf, n))}
    assert {i for i, f in enumerate(mask) if f} == want


@settings(max_examples=40, deadline=None)
@given(mask=st.lists(st.booleans(), min_size=1, max_size=30))
def test_mask_segments_partition(mask):
    segs = mask_segments(tuple(mask))
    # segments tile [0, n) in order with alternating flags
    assert segs[0][0] == 0 and segs[-1][1] == len(mask)
    for (l1, h1, f1), (l2, h2, f2) in zip(segs, segs[1:]):
        assert h1 == l2 and f1 != f2
    for lo, hi, f in segs:
        assert all(mask[i] == f for i in range(lo, hi))


def test_client_window_size_formula():
    # N_k = min(eps, ceil(n_k/n * N) * gamma)
    assert ffdapt.client_window_size(50, 100, 6, epsilon=5, gamma=1.0) == 3
    assert ffdapt.client_window_size(50, 100, 6, epsilon=2, gamma=1.0) == 2
    assert ffdapt.client_window_size(10, 100, 6, epsilon=5, gamma=2.0) == 2
    assert ffdapt.client_window_size(1, 1000, 6, epsilon=5, gamma=1.0) == 1


def test_client_window_size_gamma_rounds_half_up():
    """Regression: int() truncation froze NOTHING for small clients under
    gamma < 1 — the issue's example (n_k=5, n=100, N=12, gamma=0.5) gave
    int(ceil(0.6) * 0.5) = int(0.5) = 0.  Round-half-up keeps the window."""
    assert ffdapt.client_window_size(5, 100, 12, epsilon=11, gamma=0.5) == 1
    # half-up at the boundary: 1 * 1.5 -> 2, 1 * 1.4 -> 1
    assert ffdapt.client_window_size(5, 100, 12, epsilon=11, gamma=1.5) == 2
    assert ffdapt.client_window_size(5, 100, 12, epsilon=11, gamma=1.4) == 1
    # a gamma=0.5 schedule now actually freezes layers for uniform tiny
    # clients instead of silently disabling FFDAPT
    sched = ffdapt.schedule(12, [5] * 20, 2, gamma=0.5)
    assert any(nf > 0 for rnd in sched for _, nf in rnd)
    # epsilon still caps, and integer gammas are unchanged
    assert ffdapt.client_window_size(5, 100, 12, epsilon=1, gamma=4.0) == 1
    assert ffdapt.client_window_size(50, 100, 6, epsilon=5, gamma=1.0) == 3


def test_backward_flop_saving_range():
    s = ffdapt.backward_flop_saving(6, [(0, 3), (3, 3)])
    assert 0.0 < s < 0.5
    assert ffdapt.backward_flop_saving(6, [(0, 0)]) == 0.0


# ---------------------------------------------------------------------------
# execution semantics
# ---------------------------------------------------------------------------

def _setup(arch="phi4-mini-3.8b", n_layers=4):
    cfg = get_config(arch).reduced().replace(n_layers=n_layers)
    params = P.unbox(init_model(KEY, cfg))
    opt = optim.adam(1e-3)
    opt_state = P.unbox(opt.init(params))
    rng = np.random.default_rng(0)
    B, S = 2, 12
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    return cfg, params, opt, opt_state, batch


@pytest.mark.parametrize("frozen", [
    (True, False, False, False),
    (False, True, True, False),
    (True, False, False, True),       # wrap window
])
def test_static_freeze_untouched(frozen):
    """Frozen layers: params AND Adam moments bit-identical after a step."""
    cfg, params, opt, opt_state, batch = _setup()
    step = jax.jit(make_train_step(cfg, opt, frozen=frozen))
    p1, o1, m = step(params, opt_state, batch)
    for name in ("wq", "wo"):
        d_p = np.asarray(jnp.abs(
            p1["layers"]["attn"][name] - params["layers"]["attn"][name]
        ).sum(axis=tuple(range(1, p1["layers"]["attn"][name].ndim))))
        d_m = np.asarray(jnp.abs(o1["m"]["layers"]["attn"][name]).sum(
            axis=tuple(range(1, p1["layers"]["attn"][name].ndim))))
        for i, f in enumerate(frozen):
            if f:
                assert d_p[i] == 0.0, f"layer {i} param moved"
                assert d_m[i] == 0.0, f"layer {i} moment moved"
            else:
                assert d_p[i] > 0.0, f"layer {i} param frozen unexpectedly"


def test_static_equals_masked():
    """Static (stop_gradient segments) and masked (traced mask) FFDAPT modes
    produce the same params/opt-state up to fp reassociation."""
    cfg, params, opt, opt_state, batch = _setup()
    frozen = (False, True, True, False)
    static = jax.jit(make_train_step(cfg, opt, frozen=frozen))
    masked = jax.jit(make_masked_train_step(cfg, opt))
    p_s, o_s, _ = static(params, opt_state, batch)
    p_m, o_m, _ = masked(params, opt_state, batch,
                         jnp.asarray(frozen, jnp.float32))
    for a, b in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_m)):
        # atol 5e-5: XLA reassociates the two lowerings differently; a
        # handful of elements land ~1e-5 apart after one Adam step
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=5e-5)


def test_freeze_units_per_family():
    assert n_freeze_units(get_config("qwen2-7b")) == 28
    assert n_freeze_units(get_config("llama-3.2-vision-90b")) == 20  # groups
    assert n_freeze_units(get_config("whisper-tiny")) == 8           # enc+dec
    assert n_freeze_units(get_config("zamba2-1.2b")) == 38


def test_audio_freeze_spans_encoder_and_decoder():
    cfg = get_config("whisper-tiny").reduced()      # 2 enc + 2 dec units
    params = P.unbox(init_model(KEY, cfg))
    opt = optim.adam(1e-3)
    opt_state = P.unbox(opt.init(params))
    rng = np.random.default_rng(0)
    B, S = 2, 8
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S)), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "frames": jnp.asarray(rng.normal(0, .1, (B, cfg.n_audio_frames,
                                                 cfg.d_model)), jnp.float32),
    }
    frozen = (False, True, True, False)     # enc layer 1 + dec layer 0
    step = jax.jit(make_train_step(cfg, opt, frozen=frozen))
    p1, _, _ = step(params, opt_state, batch)
    enc_d = np.asarray(jnp.abs(p1["enc_layers"]["attn"]["wq"]
                               - params["enc_layers"]["attn"]["wq"]).sum((1, 2, 3)))
    dec_d = np.asarray(jnp.abs(p1["layers"]["attn"]["wq"]
                               - params["layers"]["attn"]["wq"]).sum((1, 2, 3)))
    assert enc_d[0] > 0 and enc_d[1] == 0
    assert dec_d[0] == 0 and dec_d[1] > 0
