"""FedAvg aggregation properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.fedavg import broadcast_clients, fedavg, fedavg_stacked


def _tree(rng, scale=1.0):
    return {"a": jnp.asarray(rng.normal(0, scale, (3, 4)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(0, scale, (5,)), jnp.float32)}}


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(1, 1000), min_size=1, max_size=6),
       seed=st.integers(0, 100))
def test_fedavg_matches_numpy(sizes, seed):
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in sizes]
    w = np.asarray(sizes, np.float64)
    w = w / w.sum()
    got = fedavg(trees, sizes)
    for path in (("a",), ("b", "c")):
        leaves = [t[path[0]] if len(path) == 1 else t[path[0]][path[1]]
                  for t in trees]
        want = sum(wk * np.asarray(l, np.float64) for wk, l in zip(w, leaves))
        g = got[path[0]] if len(path) == 1 else got[path[0]][path[1]]
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 6), seed=st.integers(0, 100))
def test_stacked_equals_list(k, seed):
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(k)]
    sizes = list(rng.integers(1, 50, k))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    a = fedavg(trees, sizes)
    b = fedavg_stacked(stacked, sizes)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_identity_and_idempotence():
    rng = np.random.default_rng(0)
    t = _tree(rng)
    same = fedavg([t, t, t], [1, 2, 3])      # identical clients -> unchanged
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(same)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_broadcast_clients_shape():
    rng = np.random.default_rng(0)
    t = _tree(rng)
    s = broadcast_clients(t, 4)
    assert s["a"].shape == (4, 3, 4)
    np.testing.assert_array_equal(np.asarray(s["a"][2]), np.asarray(t["a"]))
