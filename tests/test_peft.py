"""ParamSpace contract: bitwise parity of full/frozen_window with the
pre-refactor FedAvg/FFDAPT paths, low-rank bank training (LoRA/adapter),
subspace comm accounting, checkpoint/resume/serve round-trips, and
compile-cache invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core import ffdapt as ffd
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import _STEP_CACHE, FedSession, RoundPlan
from repro.core.strategy import Compressed, FedAvg, FedProx, tree_bytes
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P
from repro.peft import (ParamSpace, adapter, frozen_shippable_template,
                        frozen_window, full, lora, make_param_space)

CFG = get_config("distilbert-mlm").reduced()
DOCS = generate_corpus(120, seed=0)
KEY = jax.random.PRNGKey(0)


def _clients(k=2, steps=2):
    ds = make_client_datasets(DOCS, CFG, k=k, skew="iid", batch=2, seq=32)
    return [b[:steps] for b in ds["batches"]], ds["sizes"]


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


def _bitwise(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Space algebra
# ---------------------------------------------------------------------------

def test_inject_merge_identity_at_init(params0):
    """B/U factors start at zero: merge(base, inject(base)) == base bitwise,
    and injection is deterministic in the key."""
    for sp in (lora(4), adapter(8)):
        bank = sp.inject(params0, jax.random.PRNGKey(7))
        assert _bitwise(sp.merge(params0, bank), params0)
        assert _bitwise(bank, sp.inject(params0, jax.random.PRNGKey(7)))
        d = sp.extract_delta(params0, bank)
        assert max(float(jnp.abs(l).max()) for l in jax.tree.leaves(d)) == 0.0


def test_merge_equals_injected_forward(params0):
    """Merge-then-forward == forward through explicitly injected deltas:
    the merged weights are exactly base + extract_delta (the low-rank
    factors never approximate their own expansion)."""
    from repro.models.model import apply_model
    sp = lora(4, alpha=8.0)
    bank = sp.inject(params0, jax.random.PRNGKey(7))
    # move B off zero so the delta is non-trivial
    bank = jax.tree.map(lambda l: l + 0.01, bank)
    merged = sp.merge(params0, bank)
    delta = sp.extract_delta(params0, bank)
    injected = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) + d.astype(jnp.float32)
                      ).astype(w.dtype), params0, delta)
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % 100}
    lm, _, _ = apply_model(merged, CFG, batch, mode="train")
    li, _, _ = apply_model(injected, CFG, batch, mode="train")
    np.testing.assert_allclose(np.asarray(lm), np.asarray(li),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(jnp.asarray(
        [jnp.abs(l).max() for l in jax.tree.leaves(delta)])).max()) > 0


def test_space_validation(params0):
    with pytest.raises(ValueError):
        ParamSpace("nope")
    with pytest.raises(ValueError):
        lora(0)
    with pytest.raises(ValueError):
        lora(4, targets=("conv",))
    sp = lora(4, targets=("attn",))
    bank = sp.inject(params0, KEY)
    assert all("mlp" not in "/".join(map(str, p))
               for p, _ in jax.tree_util.tree_flatten_with_path(bank)[0])
    rt = ParamSpace.from_json(sp.to_json())
    assert rt == sp


# ---------------------------------------------------------------------------
# Bitwise parity: full == FedAvg, frozen_window == FFDAPT, both engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_full_space_bitwise_equals_fedavg(params0, engine):
    batches, sizes = _clients()
    kw = dict(n_rounds=2, engine=engine, client_sizes=sizes, telemetry=False)
    p_ref, h_ref = FedSession(CFG, optim.adam(1e-4),
                              RoundPlan(**kw)).run(params0, batches)
    p_sp, h_sp = FedSession(CFG, optim.adam(1e-4),
                            RoundPlan(param_space=full(), **kw)
                            ).run(params0, batches)
    assert _bitwise(p_ref, p_sp)
    assert [h.upload_bytes for h in h_ref] == [h.upload_bytes for h in h_sp]
    assert [h.loss for h in h_ref] == [h.loss for h in h_sp]


@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_frozen_window_bitwise_equals_ffdapt(params0, engine):
    batches, sizes = _clients()
    kw = dict(n_rounds=2, engine=engine, client_sizes=sizes, telemetry=False,
              ffdapt=FFDAPTConfig())
    p_ref, h_ref = FedSession(CFG, optim.adam(1e-4),
                              RoundPlan(**kw)).run(params0, batches)
    p_sp, h_sp = FedSession(CFG, optim.adam(1e-4),
                            RoundPlan(param_space=frozen_window(), **kw)
                            ).run(params0, batches)
    assert _bitwise(p_ref, p_sp)
    assert [h.loss for h in h_ref] == [h.loss for h in h_sp]


def test_full_space_shares_step_cache_with_implicit(params0):
    """full/frozen_window key the step cache through the freeze mask
    verbatim — an explicit-space session adds ZERO cache entries (and so
    zero compiles) on top of an implicit one."""
    batches, sizes = _clients()
    kw = dict(n_rounds=1, client_sizes=sizes, telemetry=False)
    opt = optim.adam(1e-4)            # one instance: opt fns are in the key
    FedSession(CFG, opt, RoundPlan(**kw)).run(params0, batches)
    before = set(_STEP_CACHE)
    FedSession(CFG, opt, RoundPlan(param_space=full(), **kw)
               ).run(params0, batches)
    assert set(_STEP_CACHE) == before


# ---------------------------------------------------------------------------
# FFDAPT comm accounting (the ROADMAP full-tree-traffic fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_ffdapt_upload_discounts_frozen_rows(params0, engine):
    batches, sizes = _clients()
    full_bytes = tree_bytes(params0)
    plan = RoundPlan(n_rounds=2, engine=engine, client_sizes=sizes,
                     telemetry=False, ffdapt=FFDAPTConfig())
    _, hist = FedSession(CFG, optim.adam(1e-4), plan).run(params0, batches)
    for h in hist:
        assert sum(h.client_upload_bytes) == h.upload_bytes     # exact-sum
        # some client freezes >= 1 layer each round under the default
        # schedule, so the round must price below the full-tree figure
        assert h.upload_bytes < len(h.clients) * full_bytes
        for (s, nf), b in zip(h.windows, h.client_upload_bytes):
            expect = tree_bytes(frozen_shippable_template(
                CFG, params0, ffd.window_mask(CFG.n_layers, (s, nf))))
            assert b == expect


def test_ffdapt_accounting_composes_with_int8(params0):
    """Compressed wraps the same shippable template: frozen + int8 prices
    below int8 alone, and the ledger still sums exactly."""
    batches, sizes = _clients()
    strat = Compressed(inner=FedAvg(), kind="int8")
    kw = dict(n_rounds=2, client_sizes=sizes, telemetry=False, strategy=strat)
    _, h_plain = FedSession(CFG, optim.adam(1e-4),
                            RoundPlan(**kw)).run(params0, batches)
    _, h_ffd = FedSession(CFG, optim.adam(1e-4),
                          RoundPlan(ffdapt=FFDAPTConfig(), **kw)
                          ).run(params0, batches)
    for hp, hf in zip(h_plain, h_ffd):
        assert hf.upload_bytes < hp.upload_bytes
        assert sum(hf.client_upload_bytes) == hf.upload_bytes


# ---------------------------------------------------------------------------
# Low-rank training: both engines, upload ratio, strategy composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_lora_trains_and_uploads_10x_less(params0, engine):
    batches, sizes = _clients()
    kw = dict(n_rounds=2, engine=engine, client_sizes=sizes, telemetry=False)
    p_full, h_full = FedSession(CFG, optim.adam(1e-3),
                                RoundPlan(**kw)).run(params0, batches)
    p_lora, h_lora = FedSession(CFG, optim.adam(1e-3),
                                RoundPlan(param_space=lora(4), **kw)
                                ).run(params0, batches)
    # the acceptance bar: >= 10x smaller upload at equal model size
    for hf, hl in zip(h_full, h_lora):
        assert hl.upload_bytes * 10 <= hf.upload_bytes
        assert hl.download_bytes * 10 <= hf.download_bytes
        assert sum(hl.client_upload_bytes) == hl.upload_bytes
    # the bank actually moved (the merged model is not the base)
    assert not _bitwise(p_lora, params0)
    # untargeted leaves (embeddings, norms) never move
    assert _bitwise(p_lora["embed"], params0["embed"])
    assert _bitwise(p_lora["final_norm"], params0["final_norm"])
    assert np.isfinite(h_lora[-1].loss)


def test_lora_sequential_close_to_parallel(params0):
    batches, sizes = _clients()
    kw = dict(n_rounds=2, client_sizes=sizes, telemetry=False,
              param_space=lora(4))
    p1, _ = FedSession(CFG, optim.adam(1e-3),
                       RoundPlan(engine="sequential", **kw)
                       ).run(params0, batches)
    p2, _ = FedSession(CFG, optim.adam(1e-3),
                       RoundPlan(engine="parallel", **kw)
                       ).run(params0, batches)
    assert max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p2))) < 1e-5


def test_lora_composes_with_strategies(params0):
    """FedProx anchors the bank; Compressed int8 codes bank deltas — both
    run unmodified in subspace coordinates."""
    batches, sizes = _clients()
    kw = dict(n_rounds=1, client_sizes=sizes, telemetry=False)
    bank_bytes = tree_bytes(lora(4).inject(params0, KEY))
    for strat in (FedProx(mu=0.01), Compressed(inner=FedAvg(), kind="int8")):
        p, hist = FedSession(
            CFG, optim.adam(1e-3),
            RoundPlan(strategy=strat, param_space=lora(4), **kw)
            ).run(params0, batches)
        assert np.isfinite(hist[-1].loss)
        assert hist[-1].upload_bytes <= len(batches) * bank_bytes
    # int8 prices below the dense bank
    assert hist[-1].upload_bytes < len(batches) * bank_bytes


def test_lora_ffdapt_composition_raises(params0):
    batches, sizes = _clients()
    plan = RoundPlan(n_rounds=1, client_sizes=sizes, telemetry=False,
                     param_space=lora(4), ffdapt=FFDAPTConfig())
    with pytest.raises(ValueError, match="does not compose"):
        FedSession(CFG, optim.adam(1e-3), plan).run(params0, batches)


def test_parallel_lora_compile_count(params0):
    """Subspace-keyed step cache: the lora shard program compiles once per
    shard width, independent of rounds — same invariant the cohort engine
    pins for full-space runs."""
    batches, sizes = _clients(k=4)
    plan = RoundPlan(n_rounds=3, engine="parallel", client_sizes=sizes,
                     telemetry=False, cohort_shard=2, param_space=lora(2))
    sess = FedSession(CFG, optim.adam(1e-3), plan)
    sess.run(params0, batches)
    assert sess.shard_compiles == 1


# ---------------------------------------------------------------------------
# Checkpoint / resume / serve
# ---------------------------------------------------------------------------

def _ckpt_kw(sizes, tmp, space):
    return dict(n_rounds=3, client_sizes=sizes, telemetry=False,
                param_space=space, checkpoint_dir=str(tmp),
                fingerprint_extra={"arch": CFG.name})


def test_adapter_kill_and_resume_bitwise(params0, tmp_path):
    batches, sizes = _clients()
    space = adapter(4)
    p_ref, h_ref = FedSession(
        CFG, optim.adam(1e-3),
        RoundPlan(**_ckpt_kw(sizes, tmp_path / "ref", space))
        ).run(params0, batches)
    kw = _ckpt_kw(sizes, tmp_path / "killed", space)
    FedSession(CFG, optim.adam(1e-3),
               RoundPlan(stop_after_round=1, **kw)).run(params0, batches)
    p_res, h_res = FedSession(CFG, optim.adam(1e-3), RoundPlan(**kw)
                              ).run(params0, batches, resume=True)
    assert _bitwise(p_ref, p_res)
    assert [h.loss for h in h_ref] == [h.loss for h in h_res]
    assert [h.upload_bytes for h in h_ref] == [h.upload_bytes for h in h_res]


def test_resume_wrong_rank_raises(params0, tmp_path):
    batches, sizes = _clients()
    kw4 = _ckpt_kw(sizes, tmp_path, lora(4))
    FedSession(CFG, optim.adam(1e-3),
               RoundPlan(stop_after_round=1, **kw4)).run(params0, batches)
    kw8 = dict(kw4, param_space=lora(8))
    with pytest.raises(ValueError, match="param_space"):
        FedSession(CFG, optim.adam(1e-3), RoundPlan(**kw8)
                   ).run(params0, batches, resume=True)


def test_serve_loader_merges_adapter_bank(params0, tmp_path):
    """The decode path serves a low-rank checkpoint as the exact merged
    model training evaluated; wrong base arch and wrong rank raise."""
    from repro.serve.loader import checkpoint_param_space, load_serving_params
    batches, sizes = _clients()
    space = lora(4)
    kw = dict(_ckpt_kw(sizes, tmp_path, space), n_rounds=2)
    p_final, _ = FedSession(CFG, optim.adam(1e-3), RoundPlan(**kw)
                            ).run(params0, batches)
    assert checkpoint_param_space(str(tmp_path)) == space
    served, step, fed = load_serving_params(str(tmp_path), CFG)
    assert step == 2
    assert _bitwise(served, p_final)
    # pinned expectation passes...
    load_serving_params(str(tmp_path), CFG, expect_space=lora(4))
    # ...wrong rank raises
    with pytest.raises(ValueError, match="param space"):
        load_serving_params(str(tmp_path), CFG, expect_space=lora(8))
    # ...wrong base arch raises (the fingerprint_extra guard, extended)
    wrong = CFG.replace(name="other-arch")
    with pytest.raises(ValueError, match="trained as"):
        load_serving_params(str(tmp_path), wrong)


def test_make_param_space_flags():
    assert make_param_space("lora", rank=2) == lora(2)
    assert make_param_space("adapter", adapter_dim=6) == adapter(6)
    assert make_param_space("full") == full()
    assert make_param_space("frozen_window") == frozen_window()
    with pytest.raises(ValueError):
        make_param_space("nope")
