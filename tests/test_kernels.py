"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes.

All rtol/atol pairs come from the shared conformance tolerance ladder
(``repro.conformance.tolerances``) — the same table the harness and
``benchmarks/kernel_bench.py`` judge under, so the pytest suite and the
pinned BENCH baselines cannot drift apart.  The exhaustive grid
(adversarial numerics, chunk lattices, chain properties) lives in
``tests/test_conformance.py``; this file keeps the direct per-kernel
spot checks plus the VJP parity and decay-regression pins.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.conformance import forward_tol, vjp_tol
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _fwd(kernel, dtype=jnp.float32):
    return forward_tol(kernel, dtype).kw()


def _vjp(kernel, dtype=jnp.float32):
    return vjp_tol(kernel, dtype).kw()


def _grads(fn, *inputs):
    """fp32 sum-of-squares loss over all output leaves -> grads wrt all
    inputs (same scalarization the conformance harness uses)."""
    def loss(*a):
        out = fn(*a)
        return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                   for l in jax.tree_util.tree_leaves(out))
    return jax.grad(loss, argnums=tuple(range(len(inputs))))(*inputs)


def _assert_grads_close(got, want, kernel, dtype=jnp.float32):
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_vjp(kernel, dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,Kv,D", [
    (1, 8, 8, 2, 2, 8),          # MHA tiny
    (2, 37, 37, 8, 4, 16),       # GQA, non-aligned seq (padding path)
    (1, 64, 64, 4, 1, 32),       # MQA
    (2, 16, 48, 4, 4, 8),        # cross-length (decode-ish kv longer)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, T, H, Kv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Kv, D), dtype)
    causal = S == T
    want = ref.attention(q, k, v, causal=causal)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_fwd("flash_attention", dtype))


@pytest.mark.parametrize("window", [4, 16, 31])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    B, S, H, Kv, D = 2, 33, 4, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    want = ref.attention(q, k, v, causal=True, window=window)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_fwd("flash_attention"))


def test_flash_attention_softcap():
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 1, 24, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, D)) * 3
    k = jax.random.normal(ks[1], (B, S, H, D)) * 3
    v = jax.random.normal(ks[2], (B, S, H, D))
    want = ref.attention(q, k, v, causal=True, softcap=20.0)
    got = ops.flash_attention(q, k, v, causal=True, softcap=20.0,
                              block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_fwd("flash_attention"))


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,D,chunk", [
    (1, 8, 1, 4, 4),
    (2, 19, 3, 8, 8),            # padding path (19 % 8 != 0)
    (1, 64, 2, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(B, T, H, D, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D))).astype(dtype)
    u = jax.random.normal(ks[4], (H, D), dtype)
    s0 = jax.random.normal(ks[5], (B, H, D, D), jnp.float32)
    y_ref, s_ref = ref.rwkv6_scan(r, k, v, w, u, s0)
    y, s = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               **_fwd("rwkv6_scan", dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               **_fwd("rwkv6_scan"))


def test_rwkv6_state_chaining():
    """Scanning [0:T1] then [T1:T] with carried state == scanning [0:T]."""
    ks = jax.random.split(KEY, 6)
    B, T, H, D = 1, 24, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    y_full, s_full = ref.rwkv6_scan(r, k, v, w, u, s0)
    y1, s1 = ops.rwkv6_scan(r[:, :10], k[:, :10], v[:, :10], w[:, :10], u, s0,
                            chunk=4)
    y2, s2 = ops.rwkv6_scan(r[:, 10:], k[:, 10:], v[:, 10:], w[:, 10:], u, s1,
                            chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), **_fwd("rwkv6_scan"))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               **_fwd("rwkv6_scan"))


# ---------------------------------------------------------------------------
# mamba2 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 8, 1, 4, 4, 4),
    (2, 13, 3, 4, 5, 4),         # padding path
    (1, 32, 4, 8, 16, 16),
])
def test_mamba2_scan(B, T, H, P, N, chunk):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.1
    b = jax.random.normal(ks[3], (B, T, N))
    c = jax.random.normal(ks[4], (B, T, N))
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    y_ref, h_ref = ref.mamba2_scan(x, dt, a_log, b, c, h0)
    y, h = ops.mamba2_scan(x, dt, a_log, b, c, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               **_fwd("mamba2_scan"))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               **_fwd("mamba2_scan"))


def test_mamba2_state_chaining():
    ks = jax.random.split(KEY, 6)
    B, T, H, P, N = 1, 20, 2, 4, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.1
    b = jax.random.normal(ks[3], (B, T, N))
    c = jax.random.normal(ks[4], (B, T, N))
    h0 = jnp.zeros((B, H, P, N))
    y_full, h_full = ref.mamba2_scan(x, dt, a_log, b, c, h0)
    _, h1 = ops.mamba2_scan(x[:, :7], dt[:, :7], a_log, b[:, :7], c[:, :7],
                            h0, chunk=4)
    y2, h2 = ops.mamba2_scan(x[:, 7:], dt[:, 7:], a_log, b[:, 7:], c[:, 7:],
                             h1, chunk=8)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 7:]),
                               **_fwd("mamba2_scan"))
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               **_fwd("mamba2_scan"))


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,d,f", [
    (2, 8, 16, 16),
    (3, 10, 16, 24),             # padding path
    (8, 32, 32, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_ffn(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 4)
    xe = jax.random.normal(ks[0], (E, C, d), dtype)
    wg = (jax.random.normal(ks[1], (E, d, f)) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f)) * 0.1).astype(dtype)
    wo = (jax.random.normal(ks[3], (E, f, d)) * 0.1).astype(dtype)
    want = ref.moe_ffn(xe, wg, wu, wo)
    got = ops.moe_ffn(xe, wg, wu, wo, block_c=8, block_f=8)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_fwd("moe_gmm", dtype))


# ---------------------------------------------------------------------------
# chunked SSD (beyond-paper §Perf path) vs sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 16, 1, 4, 4, 8),
    (2, 50, 3, 4, 5, 16),        # padding path (50 % 16 != 0)
    (1, 128, 4, 8, 16, 64),
    (2, 30, 2, 4, 8, 64),        # chunk > T
])
def test_mamba2_chunked_matches_sequential(B, T, H, P, N, chunk):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.1
    b = jax.random.normal(ks[3], (B, T, N))
    c = jax.random.normal(ks[4], (B, T, N))
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    y_ref, h_ref = ref.mamba2_scan(x, dt, a_log, b, c, h0)
    y, h = ref.mamba2_scan_chunked(x, dt, a_log, b, c, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               **_fwd("mamba2_scan"))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               **_fwd("mamba2_scan"))


def test_mamba2_chunked_bf16_tolerance():
    """The bf16 pairwise path (the §Perf memory fix) stays within ~2%."""
    ks = jax.random.split(KEY, 6)
    B, T, H, P, N = 2, 64, 2, 4, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.1
    b = jax.random.normal(ks[3], (B, T, N))
    c = jax.random.normal(ks[4], (B, T, N))
    h0 = jnp.zeros((B, H, P, N))
    y_ref, _ = ref.mamba2_scan(x, dt, a_log, b, c, h0)
    y, _ = ref.mamba2_scan_chunked(
        x.astype(jnp.bfloat16), dt.astype(jnp.bfloat16), a_log,
        b.astype(jnp.bfloat16), c.astype(jnp.bfloat16), h0, chunk=32)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref))
                / jnp.max(jnp.abs(y_ref)))
    assert rel < 0.02


# ---------------------------------------------------------------------------
# chunked WKV (beyond-paper §Perf path) vs sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,D,chunk", [
    (1, 16, 1, 4, 8),
    (2, 50, 3, 8, 16),           # padding path
    (1, 40, 2, 8, 64),           # chunk > T
])
def test_rwkv6_chunked_matches_sequential(B, T, H, D, chunk):
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jax.random.normal(ks[5], (B, H, D, D))
    y_ref, s_ref = ref.rwkv6_scan(r, k, v, w, u, s0)
    y, s = ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               **_fwd("rwkv6_scan"))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               **_fwd("rwkv6_scan"))


def test_rwkv6_chunked_extreme_decay():
    """Channels with near-total per-step decay (w ~ e^-12) — the regime that
    corrupts a factorized form — must stay oracle-exact."""
    ks = jax.random.split(KEY, 6)
    B, T, H, D = 2, 48, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    wlog = jax.random.normal(ks[3], (B, T, H, D)) + 0.5
    w = jnp.exp(-jnp.exp(wlog))                  # harsh data-dependent decay
    u = jax.random.normal(ks[4], (H, D))
    s0 = jax.random.normal(ks[5], (B, H, D, D))
    y_ref, s_ref = ref.rwkv6_scan(r, k, v, w, u, s0)
    y, s = ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=16)
    rel = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
    assert rel < 1e-4
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               **_fwd("rwkv6_scan"))


# ---------------------------------------------------------------------------
# VJP parity: jax.grad through the Pallas ops' custom_vjp (reference
# backwards in kernels/vjp.py) vs jax.grad through the sequential oracle.
# The backwards are written independently of the oracle's autodiff
# (hand-derived for attention/MoE, chunked-formulation for the scans), so
# these are differential tests of the gradient math.
# ---------------------------------------------------------------------------

def test_flash_attention_vjp():
    ks = jax.random.split(KEY, 3)
    B, S, H, Kv, D = 2, 24, 4, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    got = _grads(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, window=8, block_q=8, block_k=8), q, k, v)
    want = _grads(lambda q, k, v: ref.attention(
        q, k, v, causal=True, window=8), q, k, v)
    _assert_grads_close(got, want, "flash_attention")


def test_flash_attention_vjp_softcap():
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 1, 16, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, D)) * 3
    k = jax.random.normal(ks[1], (B, S, H, D)) * 3
    v = jax.random.normal(ks[2], (B, S, H, D))
    got = _grads(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, softcap=10.0, block_q=8, block_k=8), q, k, v)
    want = _grads(lambda q, k, v: ref.attention(
        q, k, v, causal=True, softcap=10.0), q, k, v)
    _assert_grads_close(got, want, "flash_attention")


def test_rwkv6_scan_vjp():
    ks = jax.random.split(KEY, 6)
    B, T, H, D = 1, 16, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jax.random.normal(ks[5], (B, H, D, D))
    got = _grads(lambda *a: ops.rwkv6_scan(*a, chunk=8), r, k, v, w, u, s0)
    want = _grads(ref.rwkv6_scan, r, k, v, w, u, s0)
    _assert_grads_close(got, want, "rwkv6_scan")


def test_mamba2_scan_vjp():
    ks = jax.random.split(KEY, 6)
    B, T, H, P, N = 1, 16, 2, 4, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.1
    b = jax.random.normal(ks[3], (B, T, N))
    c = jax.random.normal(ks[4], (B, T, N))
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    got = _grads(lambda *a: ops.mamba2_scan(*a, chunk=8),
                 x, dt, a_log, b, c, h0)
    want = _grads(ref.mamba2_scan, x, dt, a_log, b, c, h0)
    _assert_grads_close(got, want, "mamba2_scan")


def test_moe_ffn_vjp():
    ks = jax.random.split(KEY, 4)
    E, C, d, f = 2, 8, 16, 16
    xe = jax.random.normal(ks[0], (E, C, d))
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wo = jax.random.normal(ks[3], (E, f, d)) * 0.1
    got = _grads(lambda *a: ops.moe_ffn(*a, block_c=8, block_f=8),
                 xe, wg, wu, wo)
    want = _grads(ref.moe_ffn, xe, wg, wu, wo)
    _assert_grads_close(got, want, "moe_gmm")


# ---------------------------------------------------------------------------
# PR 2 mantissa-fix regression: the chunked SSD decay must use the direct
# pairwise exp(la_t - la_s) form.  A factorized exp(la_t) * exp(-la_s)
# form overflows/denormalizes past |la| ~ 40 per chunk; |la| = 60 here
# (dt = 1.875, A = -1, chunk = 32) would blow it up visibly.
# ---------------------------------------------------------------------------

def test_mamba2_chunked_extreme_decay_la60():
    ks = jax.random.split(KEY, 5)
    B, T, H, P, N, chunk = 1, 64, 2, 4, 8, 32
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jnp.full((B, T, H), 1.875)      # |la| per chunk = 1.875 * 32 = 60
    a_log = jnp.zeros((H,))              # A = -1 exactly
    b = jax.random.normal(ks[2], (B, T, N))
    c = jax.random.normal(ks[3], (B, T, N))
    h0 = jax.random.normal(ks[4], (B, H, P, N))
    y_ref, h_ref = ref.mamba2_scan(x, dt, a_log, b, c, h0)
    y, h = ref.mamba2_scan_chunked(x, dt, a_log, b, c, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               **_fwd("mamba2_scan"))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               **_fwd("mamba2_scan"))
    # the Pallas kernel shares the formulation — pin it in the same regime
    y2, h2 = ops.mamba2_scan(x, dt, a_log, b, c, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref),
                               **_fwd("mamba2_scan"))
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_ref),
                               **_fwd("mamba2_scan"))
