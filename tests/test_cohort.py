"""Cohort-scan engine: shard-schedule invariants, bitwise parity with the
full-width stacked-vmap round for every registered strategy (+ FFDAPT
masking), compile-count independence from cohort size, resume across a
DIFFERENT shard size, O(m) Floyd sampling, lazy ``ClientPool`` parity, the
vectorized mega-cohort clock, and the shard-program cost multiplicity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets, make_client_pool
from repro.core.rounds import (FedSession, RoundPlan, _participants,
                               _shard_widths)
from repro.core.strategies import AsyncFedAvg
from repro.core.strategy import Compressed, FedAvg, FedAvgM, FedProx
from repro.data.corpus import generate_corpus
from repro.data.partition import ClientPool
from repro.models.model import init_model
from repro.nn import param as P
from repro.sim import clock
from repro.sim.fleet import make_fleet
from repro.telemetry import batch_struct, client_step_cost, shard_epoch_cost

CFG = get_config("distilbert-mlm").reduced()
KEY = jax.random.PRNGKey(0)
DOCS = generate_corpus(120, seed=0)
OPT = optim.adam(1e-3)          # ONE instance: sessions share the step cache


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


@pytest.fixture(scope="module")
def clients():
    ds = make_client_datasets(DOCS, CFG, k=5, skew="quantity", batch=2,
                              seq=32)
    return [b[:2] for b in ds["batches"]], ds["sizes"]


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _run(params0, batches, sizes, *, shard, **plan_kw):
    plan = RoundPlan(client_sizes=sizes, engine="parallel",
                     cohort_shard=shard, telemetry=False, **plan_kw)
    session = FedSession(CFG, OPT, plan)
    p, h = session.run(params0, batches)
    return p, h, session


# ---------------------------------------------------------------- schedule

def test_shard_widths_schedule():
    assert _shard_widths(5, None) == [5]        # full-width stacked vmap
    assert _shard_widths(6, 2) == [2, 2, 2]
    assert _shard_widths(5, 2) == [2, 3]        # lone remainder absorbed
    assert _shard_widths(7, 3) == [3, 4]
    assert _shard_widths(8, 3) == [3, 3, 2]
    assert _shard_widths(5, 1) == [2, 3]        # width-1 clamped to 2
    assert _shard_widths(2, 1) == [2]
    assert _shard_widths(1, 1) == [1]           # single client: no choice
    assert _shard_widths(4, 100) == [4]         # shard >= m: one shard
    for m in range(1, 40):
        for s in (1, 2, 3, 5, 8, None):
            widths = _shard_widths(m, s)
            assert sum(widths) == m
            # never a width-1 shard unless the whole cohort is 1 client
            # (width-1 vmaps lower differently and break bitwise parity)
            assert m == 1 or all(w >= 2 for w in widths)
            # at most two distinct widths -> at most two shard compiles
            assert len(set(widths)) <= 2


# ------------------------------------------------------------------ parity

STRATEGIES = [
    FedAvg(),
    FedAvgM(beta=0.9, lr=1.0),
    FedProx(mu=0.01),
    AsyncFedAvg(alpha=0.5, staleness=(1, 0)),
    Compressed(inner=FedAvg(), kind="topk", frac=0.3),
]


@pytest.mark.parametrize("strategy", STRATEGIES, ids=lambda s: s.name)
def test_cohort_scan_bitwise_parity(params0, clients, strategy):
    """shard=3 over a 5-client cohort (widths [3, 2] — both shard program
    variants) must reproduce the full-width vmapped round bit for bit:
    the streaming fold is the SAME left fold the stacked path runs."""
    batches, sizes = clients
    kw = dict(n_rounds=2, strategy=strategy, seed=3)
    p_full, h_full, _ = _run(params0, batches, sizes, shard=None, **kw)
    p_scan, h_scan, _ = _run(params0, batches, sizes, shard=3, **kw)
    _assert_bitwise(p_full, p_scan)
    assert [h.loss for h in h_full] == [h.loss for h in h_scan]
    assert [h.tokens for h in h_full] == [h.tokens for h in h_scan]


def test_cohort_scan_ffdapt_masked_parity(params0, clients):
    """Per-client freeze masks ride the shard slices: masked FFDAPT rounds
    stay bitwise shard-invariant too."""
    batches, sizes = clients
    kw = dict(n_rounds=2, ffdapt=FFDAPTConfig(), seed=5)
    p_full, _, _ = _run(params0, batches, sizes, shard=None, **kw)
    p_scan, _, _ = _run(params0, batches, sizes, shard=2, **kw)
    _assert_bitwise(p_full, p_scan)


def test_cohort_scan_participation_parity(params0, clients):
    """Sampled cohorts (participation < 1) pick the same clients under any
    shard size (Floyd draw happens before sharding) and fold to the same
    bits."""
    batches, sizes = clients
    kw = dict(n_rounds=3, participation=0.8, seed=11)
    p_full, h_full, _ = _run(params0, batches, sizes, shard=None, **kw)
    p_scan, h_scan, _ = _run(params0, batches, sizes, shard=2, **kw)
    assert [h.clients for h in h_full] == [h.clients for h in h_scan]
    _assert_bitwise(p_full, p_scan)


# ----------------------------------------------------------- compile count

def test_compile_count_independent_of_cohort(params0, clients):
    """One uniform shard width -> ONE compiled shard program, reused across
    shards AND rounds; a remainder adds at most one more.  Cohort size
    never shows up in the compile count."""
    batches, sizes = clients
    _, _, s_uniform = _run(params0, batches[:4], sizes[:4], shard=2,
                           n_rounds=2, seed=0)
    assert s_uniform.shard_compiles == 1          # widths [2, 2]
    _, _, s_remainder = _run(params0, batches, sizes, shard=2,
                             n_rounds=2, seed=0)
    assert s_remainder.shard_compiles == 2        # widths [2, 3]
    _, _, s_full = _run(params0, batches, sizes, shard=None,
                        n_rounds=2, seed=0)
    assert s_full.shard_compiles == 1             # widths [5]


# ------------------------------------------------------------------ resume

def test_resume_across_different_shard_size(params0, clients, tmp_path):
    """cohort_shard is a memory knob, not part of the run's identity: a
    checkpoint written under shard=2 resumes under shard=3 (and under the
    full-width engine) bitwise identical to the uninterrupted run."""
    batches, sizes = clients
    kw = dict(n_rounds=3, participation=0.8, seed=7)
    p_full, h_full, _ = _run(params0, batches, sizes, shard=2, **kw)

    plan = RoundPlan(client_sizes=sizes, engine="parallel", cohort_shard=2,
                     telemetry=False, checkpoint_dir=str(tmp_path),
                     stop_after_round=1, **kw)
    FedSession(CFG, OPT, plan).run(params0, batches)

    plan_b = dataclasses.replace(plan, cohort_shard=3, stop_after_round=None)
    p_b, h_b = FedSession(CFG, OPT, plan_b).run(params0, batches,
                                                resume=True)
    _assert_bitwise(p_full, p_b)
    assert [h.clients for h in h_b] == [h.clients for h in h_full]
    assert [h.loss for h in h_b] == [h.loss for h in h_full]


# ------------------------------------------------------- Floyd sampling

def test_participants_floyd_uniform_subset():
    rng = np.random.default_rng(0)
    got = _participants(rng, 100, 0.2)
    assert len(got) == 20 and got == sorted(set(got))
    assert all(0 <= c < 100 for c in got)


def test_participants_deterministic_same_bitstate():
    a = _participants(np.random.default_rng(42), 1000, 0.016)
    b = _participants(np.random.default_rng(42), 1000, 0.016)
    assert a == b and len(a) == 16


def test_participants_consumes_one_vectorized_draw():
    """The draw is ONE ``integers`` call over the Floyd ranges — the exact
    generator advance the resume contract checkpoints.  A reference
    generator making the same call lands in the same bit-state."""
    k, m = 1000, 16
    rng_a, rng_b = np.random.default_rng(9), np.random.default_rng(9)
    _participants(rng_a, k, m / k)
    rng_b.integers(0, np.arange(k - m + 1, k + 1))
    assert rng_a.integers(0, 2**63) == rng_b.integers(0, 2**63)


def test_participants_billion_clients_o_of_m():
    """k = 10^9 must not materialize a k-length permutation (rng.choice
    would); Floyd touches O(m) memory and returns instantly."""
    got = _participants(np.random.default_rng(1), 10**9, 100 / 10**9)
    assert len(got) == 100
    assert all(0 <= c < 10**9 for c in got)


def test_participants_edges():
    assert _participants(np.random.default_rng(0), 5, 1.0) == [0, 1, 2, 3, 4]
    got = _participants(np.random.default_rng(0), 5, 0.8)   # m = k - 1
    assert len(got) == 4 and len(set(got)) == 4
    assert len(_participants(np.random.default_rng(0), 7, 1e-9)) == 1


# ------------------------------------------------------------- ClientPool

def test_client_pool_lazy_materialization():
    pool = ClientPool(6, [lambda: ["a", "b"], lambda: ["c"]], sizes=[2, 1])
    assert pool.materialized == []               # nothing built yet
    assert pool.batches_for(3) == ["c"]          # virtual 3 -> shard 1
    assert pool.materialized == [1]
    assert len(pool) == 6
    assert pool.sizes == [2, 1, 2, 1, 2, 1]


def test_client_pool_session_parity(params0):
    """A FedSession fed the lazy pool matches the same session fed the
    pre-materialized batch lists bitwise, and builds only the sampled
    cohort's data shards."""
    pool = make_client_pool(DOCS, CFG, n_clients=4, pool=2, batch=2,
                            seq=32, seed=0, limit=2)
    batches = [pool.batches_for(k) for k in range(4)]
    kw = dict(n_rounds=2, seed=3)
    p_list, _, _ = _run(params0, batches, list(pool.sizes), shard=2, **kw)
    fresh = make_client_pool(DOCS, CFG, n_clients=4, pool=2, batch=2,
                             seq=32, seed=0, limit=2)
    plan = RoundPlan(engine="parallel", cohort_shard=2, telemetry=False,
                     **kw)
    p_pool, _ = FedSession(CFG, OPT, plan).run(params0, fresh)
    _assert_bitwise(p_list, p_pool)
    assert fresh.materialized == [0, 1]


# ------------------------------------------------------- vectorized clock

def _ledger_round(m, seed=0):
    rng = np.random.default_rng(seed)
    from repro.core.rounds import RoundResult
    return RoundResult(
        round=0, loss=0.0, round_time_s=0.0,
        clients=[int(c) for c in rng.choice(4096, size=m, replace=False)],
        client_steps=[int(s) for s in rng.integers(1, 5, m)],
        client_step_flops=[float(f) for f in rng.uniform(1e9, 1e12, m)],
        client_step_hbm=[float(h) for h in rng.uniform(1e8, 1e10, m)],
        client_upload_bytes=[int(b) for b in rng.integers(10**6, 10**8, m)],
        upload_bytes=0, download_bytes=m * 7_627_776)


@pytest.mark.parametrize("overlap", [False, True])
def test_sync_round_s_vec_bitwise_matches_loop(monkeypatch, overlap):
    """The numpy fast path is op-for-op the ClientTiming loop: same float64
    numbers, not merely close."""
    rr = _ledger_round(64)
    fleet = make_fleet("crossdevice", 4096, seed=0)
    monkeypatch.setattr(clock, "VECTOR_MIN_CLIENTS", 10**9)
    want = clock.sync_round_s(rr, fleet, overlap=overlap)
    monkeypatch.setattr(clock, "VECTOR_MIN_CLIENTS", 1)
    got = clock.sync_round_s(rr, fleet, overlap=overlap)
    assert got == want                            # bitwise, not approx


# ------------------------------------------------- shard program costing

def test_shard_epoch_cost_multiplicity(clients):
    """The scan-aware analyzer prices the shard program at exactly
    shard x steps x per-step compute (the fold adds no dot FLOPs), which
    is why the round ledger can stay rectangular under any shard size."""
    batches, _ = clients
    sds = batch_struct(batches[0][0])
    one = client_step_cost(CFG, OPT, FedAvg(), sds)
    shard = shard_epoch_cost(CFG, OPT, FedAvg(), sds, shard=3, steps=2)
    assert shard.flops == pytest.approx(3 * 2 * one.flops, rel=1e-6)
    assert shard.hbm_bytes >= 3 * 2 * one.hbm_bytes * 0.5
