"""repro.sim coverage: seeded determinism, the sync degenerate case,
deadline quorum, async staleness bookkeeping, the overlap clock, the
skew-aware async replay, and AsyncFedAvg parity.

The parity contract is the load-bearing one: AsyncFedAvg with no staleness
must be BITWISE equal to FedAvg on both engines, so turning the async axis
on cannot silently perturb the paper's baseline math.  The overlap clock's
contract is an inequality: pipelining can only hide time, never add it
(property-tested over every preset).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import optim
from repro.configs import get_config
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan, RoundResult
from repro.core.strategies import AsyncFedAvg
from repro.core.strategy import FedAvg, make_strategy
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P
from repro.sim import (FLEETS, PRESETS, DeviceProfile, Fleet, client_timing,
                       make_fleet, sample_fleet, simulate, simulate_async,
                       simulate_deadline, simulate_sync, step_time_s,
                       sync_round_s)

CFG = get_config("distilbert-mlm").reduced()
KEY = jax.random.PRNGKey(0)
DOCS = generate_corpus(100, seed=0)


@pytest.fixture(scope="module")
def params0():
    return P.unbox(init_model(KEY, CFG))


@pytest.fixture(scope="module")
def clients():
    ds = make_client_datasets(DOCS, CFG, k=2, skew="iid", batch=2, seq=32)
    return [b[:2] for b in ds["batches"]], ds["sizes"]


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _round(t=0, k=4, steps=3, flops=1e12, hbm=1e9, up=10_000_000,
           down=10_000_000):
    """A synthetic replayable RoundResult (no training needed)."""
    return RoundResult(
        t, 0.0, 0.0, clients=list(range(k)), client_steps=[steps] * k,
        client_step_flops=[flops] * k, client_step_hbm=[hbm] * k,
        client_upload_bytes=[up] * k, upload_bytes=up * k,
        download_bytes=down * k)


# ---------------------------------------------------------------------------
# fleets: seeded determinism
# ---------------------------------------------------------------------------

def test_fleet_sampling_deterministic_in_seed():
    a = make_fleet("edge-mixed", 32, seed=3)
    b = make_fleet("edge-mixed", 32, seed=3)
    c = make_fleet("edge-mixed", 32, seed=4)
    assert [d.name for d in a.devices] == [d.name for d in b.devices]
    assert [d.name for d in a.devices] != [d.name for d in c.devices]
    # dict insertion order must not matter either
    mix = {"phone": 0.5, "laptop": 0.5}
    rmix = {"laptop": 0.5, "phone": 0.5}
    assert (sample_fleet(mix, 16, seed=0).devices
            == sample_fleet(rmix, 16, seed=0).devices)


def test_every_named_fleet_builds():
    for name in FLEETS:
        f = make_fleet(name, 8, seed=0)
        assert len(f) == 8 and sum(f.counts().values()) == 8
    with pytest.raises(ValueError):
        make_fleet("gpu-cloud", 4)


def test_event_ordering_deterministic_in_seed():
    hist = [_round(t, k=6) for t in range(4)]
    fleet = make_fleet("crossdevice", 6, seed=1)   # dropout-heavy
    a = simulate_async(hist, fleet, buffer_size=2, seed=11)
    b = simulate_async(hist, fleet, buffer_size=2, seed=11)
    assert a == b                                   # frozen dataclasses
    s = simulate_sync(hist, fleet, seed=11)
    assert s == simulate_sync(hist, fleet, seed=11)


# ---------------------------------------------------------------------------
# sync: identical devices degenerate to n_steps x step_time + comm
# ---------------------------------------------------------------------------

def test_sync_homogeneous_closed_form():
    dev = PRESETS["a100"]                           # dropout 0 — exact
    k, steps, flops, hbm, up, down = 3, 5, 2e12, 3e9, 8_000_000, 8_000_000
    fleet = Fleet("homog", (dev,) * k)
    rr = _round(k=k, steps=steps, flops=flops, hbm=hbm, up=up, down=down)
    want = (dev.latency_s + down / dev.down_bw
            + steps * step_time_s(flops, hbm, dev)
            + dev.latency_s + up / dev.up_bw)
    rep = simulate_sync([rr], fleet)
    assert rep.rounds[0].round_s == pytest.approx(want, rel=1e-12)
    assert sync_round_s(rr, fleet) == pytest.approx(want, rel=1e-12)
    # the roofline max picks the right side
    assert step_time_s(flops, hbm, dev) == pytest.approx(
        max(flops / dev.peak_flops, hbm / dev.hbm_bw), rel=1e-12)


def test_sync_slowest_client_gates_round():
    fast, slow = PRESETS["a100"], PRESETS["phone"]
    fleet = Fleet("mixed", (fast, dataclasses.replace(slow, dropout=0.0)))
    rr = _round(k=2)
    rep = simulate_sync([rr], fleet)
    per = {x.client: x.total_s for x in rep.rounds[0].timings}
    assert rep.rounds[0].round_s == pytest.approx(per[1], rel=1e-12)
    assert per[1] > per[0]


# ---------------------------------------------------------------------------
# deadline: over-selection never drops below quorum
# ---------------------------------------------------------------------------

def test_deadline_never_drops_below_quorum():
    # 2 fast + 6 phones; a deadline only the fast pair can beat
    devs = (PRESETS["a100"],) * 2 + \
           tuple(dataclasses.replace(PRESETS["phone"], dropout=0.0)
                 for _ in range(6))
    fleet = Fleet("skewed", devs)
    hist = [_round(t, k=8) for t in range(3)]
    fast_s = sync_round_s(_round(k=1), Fleet("f", (PRESETS["a100"],)))
    rep = simulate_deadline(hist, fleet, deadline_s=fast_s * 1.01,
                            quorum_frac=0.75, seed=0)
    for r in rep.rounds:
        assert len(r.clients) >= int(np.ceil(0.75 * 8))
        # the round ran long past the deadline to reach quorum
        assert r.round_s > fast_s * 1.01
        assert set(r.clients) | set(r.dropped) >= set(range(8))


def test_deadline_generous_keeps_everyone_and_closes_early():
    fleet = Fleet("homog", (PRESETS["a100"],) * 4)
    hist = [_round(t, k=4) for t in range(2)]
    sync = simulate_sync(hist, fleet)
    rep = simulate_deadline(hist, fleet, deadline_s=1e6, seed=0)
    assert rep.dropped_total == 0
    assert rep.total_s == pytest.approx(sync.total_s, rel=1e-9)


def test_deadline_over_selection_adds_clients():
    fleet = Fleet("homog", (PRESETS["a100"],) * 8)
    rr = _round(k=4)
    rep = simulate_deadline([rr], fleet, deadline_s=1e6, over_select=2.0,
                            seed=0)
    assert len(rep.rounds[0].clients) == 8       # 4 sampled + 4 extras


# ---------------------------------------------------------------------------
# overlap clock: pipelining can only hide time, never add it
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(flops=st.floats(min_value=1e9, max_value=1e15),
       hbm=st.floats(min_value=1e6, max_value=1e12),
       steps=st.integers(min_value=1, max_value=64),
       nbytes=st.floats(min_value=0.0, max_value=1e10))
def test_overlap_never_slower_on_any_preset(flops, hbm, steps, nbytes):
    """Property: for EVERY device preset and any workload, the pipelined
    round time is <= the sequential phase sum (and >= the longest single
    phase — it cannot hide the bottleneck itself)."""
    for dev in PRESETS.values():
        t = client_timing(0, dev, n_steps=steps, step_flops=flops,
                          step_hbm_bytes=hbm, upload_bytes=nbytes,
                          download_bytes=nbytes)
        assert t.total_overlap_s <= t.total_s * (1 + 1e-12)
        assert t.total_overlap_s >= max(t.down_s, t.compute_s, t.up_s) \
            - 1e-12
        assert t.total(True) == t.total_overlap_s
        assert t.total(False) == t.total_s


def test_overlap_threads_through_every_schedule():
    """Same seed, same fleet: the overlap clock's totals are <= the
    sequential ones on all three server schedules (the dropout noise draws
    are identical, so the inequality holds path-by-path)."""
    hist = [_round(t, k=6) for t in range(4)]
    fleet = make_fleet("edge-mixed", 6, seed=2)
    for mode, kw in (("sync", {}), ("deadline", {"deadline_s": 30.0}),
                     ("async", {"buffer_size": 2})):
        seq = simulate(hist, fleet, mode=mode, seed=5, **kw)
        ov = simulate(hist, fleet, mode=mode, seed=5, overlap=True, **kw)
        assert ov.overlap and not seq.overlap
        assert ov.total_s <= seq.total_s * (1 + 1e-9)


def test_overlap_bounded_by_bottleneck_phase():
    # uplink-starved device: the upload transfer IS the round under overlap
    dev = dataclasses.replace(PRESETS["phone"], dropout=0.0)
    t = client_timing(0, dev, n_steps=1, step_flops=1e9,
                      step_hbm_bytes=1e6, upload_bytes=50_000_000,
                      download_bytes=1_000)
    assert t.total_overlap_s == pytest.approx(
        2 * dev.latency_s + (t.up_s - dev.latency_s), rel=1e-12)


def test_roundplan_overlap_hook(params0, clients):
    batches, sizes = clients
    _, h_seq = FedSession(CFG, optim.adam(1e-4), n_rounds=1,
                          client_sizes=sizes,
                          simulate="uniform-a100").run(params0, batches)
    _, h_ov = FedSession(CFG, optim.adam(1e-4), n_rounds=1,
                         client_sizes=sizes, simulate="uniform-a100",
                         overlap=True).run(params0, batches)
    assert 0 < h_ov[0].sim_round_s <= h_seq[0].sim_round_s
    fleet = make_fleet("uniform-a100", len(batches), seed=0)
    assert h_ov[0].sim_round_s == pytest.approx(
        sync_round_s(h_ov[0], fleet, overlap=True), rel=1e-9)


# ---------------------------------------------------------------------------
# async: buffer flushes, staleness recorded
# ---------------------------------------------------------------------------

def test_async_buffer_and_staleness():
    fast = PRESETS["a100"]
    slow = dataclasses.replace(PRESETS["phone"], dropout=0.0)
    fleet = Fleet("bimodal", (fast, fast, slow))
    hist = [_round(t, k=3) for t in range(6)]
    rep = simulate_async(hist, fleet, buffer_size=2, seed=0)
    assert len(rep.rounds) == 6                   # one agg per history round
    assert all(len(r.clients) == 2 for r in rep.rounds)
    taus = rep.staleness_histogram()
    assert taus.get(0, 0) > 0                     # fast clients stay fresh
    # the slow client's updates arrive stale once versions advance
    assert any(t > 0 for t in taus)
    with pytest.raises(ValueError):
        simulate_async(hist, fleet, buffer_size=0)
    with pytest.raises(ValueError):
        simulate(hist, fleet, mode="warp")


# ---------------------------------------------------------------------------
# async under quantity skew: staleness correlates with data volume
# ---------------------------------------------------------------------------

def _skew_round(t, steps_per_client):
    k = len(steps_per_client)
    return RoundResult(
        t, 0.0, 0.0, clients=list(range(k)),
        client_steps=list(steps_per_client),
        client_step_flops=[1e12] * k, client_step_hbm=[1e9] * k,
        client_upload_bytes=[10_000_000] * k,
        upload_bytes=10_000_000 * k, download_bytes=10_000_000 * k)


def test_async_staleness_shifts_under_quantity_skew():
    """Pinned seeded behavior of the skew-aware replay: on a homogeneous
    dropout-free fleet, threading a quantity-skewed per-epoch step schedule
    through the async simulator (1) changes the staleness histogram vs the
    uniform schedule, (2) extends its tail, and (3) makes each client's
    mean tau increase with its local step count — big-data clients upload
    less often and land staler, which is the behavior the non-IID study
    needs the schedule to expose."""
    fleet = Fleet("homog", (PRESETS["a100"],) * 4)      # dropout 0 — exact
    uni = simulate_async([_skew_round(t, [8] * 4) for t in range(40)],
                         fleet, buffer_size=2, seed=0)
    ske = simulate_async([_skew_round(t, [2, 4, 12, 30]) for t in range(40)],
                         fleet, buffer_size=2, seed=0)
    assert uni.staleness_histogram() == {0: 2, 1: 40, 2: 38}
    assert ske.staleness_histogram() == {0: 11, 1: 43, 2: 15, 3: 4,
                                         4: 5, 5: 2}
    per = {}
    ups = {}
    for r in ske.rounds:
        for c, tau in zip(r.clients, r.staleness):
            per.setdefault(c, []).append(tau)
            ups[c] = ups.get(c, 0) + 1
    mean_tau = [float(np.mean(per[c])) for c in range(4)]
    assert mean_tau == sorted(mean_tau)            # tau grows with steps
    assert ups[0] > ups[3]                         # small client uploads more
    # determinism of the schedule replay
    again = simulate_async([_skew_round(t, [2, 4, 12, 30])
                            for t in range(40)], fleet, buffer_size=2, seed=0)
    assert again == ske


def test_async_client_steps_override_matches_skewed_ledger():
    """client_steps= (the noniid ``steps`` schedule) over a rectangular
    ledger must reproduce the natively-skewed ledger's schedule — that is
    the parallel-engine path (it pads every client to max_steps)."""
    fleet = Fleet("homog", (PRESETS["a100"],) * 4)
    skewed = simulate_async([_skew_round(t, [2, 4, 12, 30])
                             for t in range(12)], fleet, buffer_size=2,
                            seed=3)
    rect = simulate_async([_skew_round(t, [30] * 4) for t in range(12)],
                          fleet, buffer_size=2, seed=3,
                          client_steps=[2, 4, 12, 30])
    assert rect.staleness_histogram() == skewed.staleness_histogram()
    assert [r.clients for r in rect.rounds] == \
        [r.clients for r in skewed.rounds]
    # dict form addresses clients by id
    rect_d = simulate_async([_skew_round(t, [30] * 4) for t in range(12)],
                            fleet, buffer_size=2, seed=3,
                            client_steps={0: 2, 1: 4, 2: 12, 3: 30})
    assert rect_d == rect


# ---------------------------------------------------------------------------
# AsyncFedAvg: staleness-0 bitwise == FedAvg on BOTH engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "parallel"])
def test_asyncfedavg_stale0_bitwise_equals_fedavg(params0, clients, engine):
    batches, sizes = clients
    plan = RoundPlan(n_rounds=2, engine=engine, client_sizes=sizes,
                     telemetry=False)
    p_avg, _ = FedSession(CFG, optim.adam(1e-4), plan,
                          strategy=FedAvg()).run(params0, batches)
    p_asy, _ = FedSession(CFG, optim.adam(1e-4), plan,
                          strategy=AsyncFedAvg()).run(params0, batches)
    assert _maxdiff(p_avg, p_asy) == 0.0


def test_asyncfedavg_staleness_discount_math():
    g = {"w": jnp.zeros((4,), jnp.float32)}
    ups = [{"w": jnp.full((4,), 1.0)}, {"w": jnp.full((4,), 3.0)}]
    s = AsyncFedAvg(alpha=1.0, staleness=(0, 1))   # s(0)=1, s(1)=0.5
    new, _, _ = s.aggregate(g, ups, [1.0, 1.0], s.init_state(g))
    # discounted weighted mean: (1*1 + 0.5*3) / 1.5 = 5/3
    np.testing.assert_allclose(np.asarray(new["w"]), 5.0 / 3.0, rtol=1e-6)
    # stacked layout agrees
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ups)
    new2, _ = s.aggregate_stacked(g, stacked, jnp.ones((2,), jnp.float32),
                                  s.init_state(g))
    np.testing.assert_allclose(np.asarray(new2["w"]), np.asarray(new["w"]),
                               rtol=1e-6)
    # server_lr scales the move toward the discounted mean
    half = AsyncFedAvg(alpha=1.0, staleness=(0, 1), server_lr=0.5)
    new3, _, _ = half.aggregate(g, ups, [1.0, 1.0], half.init_state(g))
    np.testing.assert_allclose(np.asarray(new3["w"]), 0.5 * 5.0 / 3.0,
                               rtol=1e-6)
    assert s.discount(0) == 1.0 and half.discount(1) == 0.5
    assert make_strategy("asyncfedavg", alpha=0.2, staleness=[2]) == \
        AsyncFedAvg(alpha=0.2, staleness=(2,))


# ---------------------------------------------------------------------------
# live hook + replay of a real session
# ---------------------------------------------------------------------------

def test_roundplan_simulate_hook_and_replay(params0, clients):
    batches, sizes = clients
    _, hist = FedSession(CFG, optim.adam(1e-4), n_rounds=2,
                         client_sizes=sizes,
                         simulate="uniform-a100").run(params0, batches)
    fleet = make_fleet("uniform-a100", len(batches), seed=0)
    for h in hist:
        assert h.client_steps == [len(b) for b in batches]
        assert h.client_step_flops and all(f > 0 for f in h.client_step_flops)
        assert h.sim_round_s > 0
        assert h.sim_round_s == pytest.approx(sync_round_s(h, fleet),
                                              rel=1e-9)
    # replaying the recorded history round-trips through every mode
    for mode, kw in (("sync", {}), ("deadline", {"deadline_s": 1.0}),
                     ("async", {"buffer_size": 2})):
        rep = simulate(hist, fleet, mode=mode, **kw)
        assert rep.total_s > 0 and rep.mode == mode
