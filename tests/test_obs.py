"""repro.obs: span tracer invariants (nesting, ring overflow, the
disabled zero-cost fast path, Chrome trace schema round-trip), pinned
exact quantiles, metrics registry semantics, the measured-vs-predicted
drift monitor, sim-span parity with ``ClientTiming`` totals, and the
end-to-end join over a tiny traced ``FedSession``."""

import json
import threading
import time

import jax
import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry, quantile, summary_stats
from repro.obs.trace import NULL_SPAN, PID_MEASURED, PID_SIM, Tracer


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test gets a quiet process-wide tracer and registry, and
    leaves them that way (other test modules share these singletons)."""
    obs.disable()
    obs.get_tracer().clear()
    obs.registry().clear()
    yield
    obs.disable()
    obs.get_tracer().clear()
    obs.registry().clear()


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    tr = Tracer(capacity=16)
    with tr.span("outer", cat="t", round=0):
        time.sleep(0.001)
        with tr.span("inner", cat="t"):
            time.sleep(0.001)
    evs = tr.events()
    # children close before parents: inner is appended first
    assert [e.name for e in evs] == ["inner", "outer"]
    inner, outer = evs
    assert outer.ts_us <= inner.ts_us
    assert outer.ts_us + outer.dur_us >= inner.ts_us + inner.dur_us
    assert outer.args == {"round": 0}
    assert all(e.phase == "X" and e.pid == PID_MEASURED for e in evs)


def test_ring_buffer_overflow_keeps_newest():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert tr.dropped == 6
    assert len(tr) == 4
    assert [e.name for e in tr.events()] == ["s6", "s7", "s8", "s9"]
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 6


def test_disabled_tracer_is_shared_singleton():
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a", x=1), tr.span("b")
    assert s1 is s2 is NULL_SPAN       # no allocation on the fast path
    tr.instant("i")
    tr.add_span("syn", ts_s=0.0, dur_s=1.0)
    assert tr.events() == []
    # module-level convenience hits the same singleton while disabled
    assert obs.span("c", y=2) is NULL_SPAN


def test_disabled_overhead_below_measurement_noise():
    """The acceptance bar: instrumenting a hot path with a disabled
    tracer must cost well under measurement noise.  5us/call is ~100x the
    observed cost of the attribute check + singleton return; a real
    allocation-per-call regression lands far above it."""
    tr = Tracer(enabled=False)
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot", round=1, client=2):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled span costs {per_call*1e6:.2f}us/call"


def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = Tracer(capacity=64)
    with tr.span("work", cat="train", round=3):
        pass
    tr.instant("mark", cat="compile")
    tr.add_span("sim.round", ts_s=1.0, dur_s=0.5, cat="sim", round=3)
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"measured", "simulated"}
    by_name = {e["name"]: e for e in evs if e["ph"] != "M"}
    x = by_name["work"]
    assert x["ph"] == "X" and x["dur"] >= 0 and x["pid"] == PID_MEASURED
    assert x["args"] == {"round": 3}
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    syn = by_name["sim.round"]
    assert syn["pid"] == PID_SIM
    assert syn["ts"] == pytest.approx(1.0e6)
    assert syn["dur"] == pytest.approx(0.5e6)


def test_traced_decorator_and_thread_tracks():
    tr = obs.enable(capacity=128)

    @obs.traced("worker", cat="t")
    def work():
        time.sleep(0.001)

    threads = [threading.Thread(target=work) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    work()
    evs = [e for e in tr.events() if e.name == "worker"]
    assert len(evs) == 4
    assert len({e.tid for e in evs}) >= 2   # one track per thread


def test_enable_resets_and_keeps_identity():
    before = obs.get_tracer()
    tr = obs.enable(capacity=8)
    assert tr is before                     # call sites keep their reference
    with tr.span("x"):
        pass
    obs.disable()
    assert len(tr.events()) == 1            # kept for export after disable


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_quantile_pinned_values():
    # linear interpolation between closest ranks, h = (n-1)q — these exact
    # values must never drift with a numpy upgrade (they don't use numpy)
    assert quantile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.5
    assert quantile([1.0, 2.0, 3.0, 4.0, 5.0], 0.25) == 2.0
    assert quantile([1.0, 2.0], 0.75) == 1.75
    assert quantile([7.0], 0.99) == 7.0
    assert quantile([], 0.5) == 0.0
    assert quantile(list(range(1, 101)), 0.99) == pytest.approx(99.01)
    with pytest.raises(ValueError):
        quantile([1.0], 1.5)
    s = summary_stats([3.0, 1.0, 2.0])
    assert s == {"mean": 2.0, "p50": 2.0, "p99": pytest.approx(2.98)}


def test_serve_percentiles_delegate_to_pinned_rule():
    from repro.serve.metrics import percentiles
    xs = [0.1, 0.5, 0.2, 0.9, 0.3]
    assert percentiles(xs) == summary_stats(xs)
    assert percentiles([]) == {"mean": 0.0, "p50": 0.0, "p99": 0.0}


def test_registry_semantics(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)               # get-or-create: same object
    assert reg.counter("c").value == 3.5
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    reg.gauge("g").set(7)
    reg.gauge("g").set(-2)                  # gauges go down
    assert reg.gauge("g").value == -2.0
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["sum"] == 10.0 and s["p50"] == 2.5
    with pytest.raises(TypeError):
        reg.gauge("c")                      # kind conflict never shadows
    assert reg.names() == ["c", "g", "h"]

    path = reg.export_jsonl(str(tmp_path / "m.jsonl"))
    rows = obs.load_jsonl(path)
    assert [r["name"] for r in rows] == ["c", "g", "h"]   # sorted, stable
    assert rows[0] == {"name": "c", "type": "counter", "value": 3.5}
    assert rows[2]["p99"] == pytest.approx(3.97)


# ---------------------------------------------------------------------------
# Drift
# ---------------------------------------------------------------------------

def test_drift_ratio_pinned_and_warn_rule():
    mon = obs.DriftMonitor(warn_ratio=2.0, metrics=MetricsRegistry())
    r = mon.observe(0, "round", measured_s=1.25, predicted_s=1.0)
    assert r.ratio == pytest.approx(1.25) and not r.warn
    assert mon.observe(1, "round", 2.5, 1.0).warn          # > 2x
    assert mon.observe(2, "round", 0.4, 1.0).warn          # < 1/2x
    assert not mon.observe(3, "round", 0.5, 1.0).warn      # boundary holds
    bad = mon.observe(4, "round", 1.0, 0.0)
    assert bad.ratio is None and bad.warn   # unpriceable round always warns
    assert len(mon.warnings()) == 3
    with pytest.raises(ValueError):
        obs.DriftMonitor(warn_ratio=0.5)


def test_drift_banks_metrics_and_exports(tmp_path):
    reg = MetricsRegistry()
    mon = obs.DriftMonitor(warn_ratio=4.0, metrics=reg)
    mon.observe(0, "round", 1.25, 1.0)
    mon.observe(1, "round", 8.0, 1.0)
    assert reg.counter("drift.rows").value == 2
    assert reg.counter("drift.warnings").value == 1
    assert reg.histogram("drift.round.ratio").count == 2
    path = mon.export(str(tmp_path / "drift.json"))
    doc = json.loads(open(path).read())
    assert doc["n_rows"] == 2 and doc["n_warnings"] == 1
    assert doc["rows"][0]["ratio"] == pytest.approx(1.25)


def test_drift_from_dict_history_with_fleet():
    from repro.sim import make_fleet
    from repro.sim.clock import sync_round_s
    hist = [{"round": t, "clients": [0, 1], "round_time_s": 1.0,
             "client_steps": [2, 2], "client_step_flops": [1e12] * 2,
             "client_step_hbm": [1e9] * 2,
             "client_upload_bytes": [1e6] * 2} for t in range(3)]
    fleet = make_fleet("uniform-a100", 2, seed=0)
    mon = obs.from_history(hist, fleet=fleet, warn_ratio=1e9,
                           metrics=MetricsRegistry())
    assert len(mon.records) == 3
    for t, rec in enumerate(mon.records):
        assert rec.source == "fleet"
        pred = sync_round_s(hist[t], fleet, overlap=False)
        assert rec.ratio == pytest.approx(1.0 / pred)


def test_drift_prediction_precedence():
    rr = {"round": 0, "round_time_s": 2.0, "sim_round_s": 4.0,
          "flops_estimate": 1e12, "hbm_bytes_estimate": 1e9,
          "comm_bytes": 0}
    # recorded sim_round_s beats the device roofline...
    s, src = obs.predicted_round_s(rr, device="a100")
    assert (s, src) == (4.0, "sim_round_s")
    # ...and the roofline prices it when there's no recording
    rr2 = dict(rr, sim_round_s=0.0)
    s2, src2 = obs.predicted_round_s(rr2, device="a100")
    assert s2 > 0 and src2 == "device:a100"
    with pytest.raises(ValueError):
        obs.predicted_round_s(rr2, device="not-a-device")
    assert obs.predicted_round_s(dict(rr2, sim_round_s=0.0)) == (0.0, "none")


# ---------------------------------------------------------------------------
# Sim-span parity
# ---------------------------------------------------------------------------

def _tiny_history(rounds=2, clients=3):
    return [{"round": t, "clients": list(range(clients)),
             "client_steps": [2] * clients,
             "client_step_flops": [1e12] * clients,
             "client_step_hbm": [1e9] * clients,
             "client_upload_bytes": [1e6] * clients}
            for t in range(rounds)]


@pytest.mark.parametrize("overlap", [False, True])
def test_sim_spans_match_client_timing_totals(overlap):
    from repro.sim import emit_spans, make_fleet, simulate
    fleet = make_fleet("edge-mixed", 3, seed=0)
    report = simulate(_tiny_history(), fleet, mode="sync", overlap=overlap)
    tr = obs.enable(capacity=4096)
    n = emit_spans(report, tr)
    evs = tr.events()
    assert n == len(evs)
    rounds = [e for e in evs if e.name == "sim.round"]
    assert len(rounds) == len(report.rounds)
    assert all(e.pid == PID_SIM and e.tid == 0 for e in rounds)
    for rs, ev in zip(report.rounds, rounds):
        assert ev.dur_us / 1e6 == pytest.approx(rs.round_s)
    # every client span's duration is EXACTLY its timing total under the
    # report's clock mode, on its own track
    for rs in report.rounds:
        for tm in rs.timings:
            [ev] = [e for e in evs if e.name == "sim.client"
                    and e.args["round"] == rs.round
                    and e.args["client"] == tm.client]
            assert ev.dur_us / 1e6 == pytest.approx(tm.total(overlap))
            assert ev.tid == tm.client + 1
            phases = [e for e in evs if e.tid == ev.tid
                      and e.args and e.args.get("round") == rs.round
                      and e.name in ("sim.down", "sim.compute", "sim.up")]
            assert len(phases) == 3
            total = sum(e.dur_us for e in phases) / 1e6
            assert total == pytest.approx(tm.down_s + tm.compute_s + tm.up_s)


def test_sim_spans_disabled_tracer_is_noop():
    from repro.sim import emit_spans, make_fleet, simulate
    report = simulate(_tiny_history(), make_fleet("uniform-a100", 3, seed=0),
                      mode="sync")
    assert emit_spans(report, Tracer(enabled=False)) == 0


# ---------------------------------------------------------------------------
# End-to-end: a tiny traced FedSession
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_session():
    from repro import optim
    from repro.configs import get_config
    from repro.core.noniid import make_client_datasets
    from repro.core.rounds import FedSession, RoundPlan
    from repro.data.corpus import generate_corpus
    from repro.models.model import init_model
    from repro.nn import param as P
    from repro.sim import make_fleet

    cfg = get_config("distilbert-mlm").reduced()
    params0 = P.unbox(init_model(jax.random.PRNGKey(0), cfg))
    ds = make_client_datasets(generate_corpus(40, seed=0), cfg, k=3,
                              skew="quantity", batch=2, seq=32)
    batches = [b[:2] for b in ds["batches"]]
    fleet = make_fleet("paper-2080ti", 3, seed=0)
    tr = obs.enable(capacity=65536)
    obs.registry().clear()
    try:
        plan = RoundPlan(n_rounds=2, client_sizes=ds["sizes"],
                         simulate=fleet)
        _, hist = FedSession(cfg, optim.adam(1e-3), plan).run(params0,
                                                              batches)
        events = tr.events()
        reg_snapshot = obs.registry().snapshot()
    finally:
        obs.disable()
    return {"hist": hist, "events": events, "reg": reg_snapshot,
            "fleet": fleet, "tracer_events": events}


def test_session_emits_expected_spans(traced_session):
    names = {e.name for e in traced_session["events"]}
    assert {"train.round", "train.dispatch",
            "train.aggregate"} <= names
    rounds = [e for e in traced_session["events"]
              if e.name == "train.round"]
    assert [e.args["round"] for e in rounds] == [0, 1]
    reg = traced_session["reg"]
    assert reg["train.rounds"]["value"] == 2
    assert reg["train.round_s"]["count"] == 2
    assert reg["train.tokens"]["value"] > 0


def test_session_drift_ratios_within_tolerance(traced_session):
    """The measured-vs-predicted join over a real session: the span the
    tracer recorded and the engine's own perf_counter delta bound the
    same interval, so the two measured paths must agree to a few percent
    — and the fleet predictor prices every round (finite ratio)."""
    hist = traced_session["hist"]

    class _Replay:
        def events(self):
            return traced_session["tracer_events"]

    mon = obs.DriftMonitor(warn_ratio=1e9, metrics=MetricsRegistry())
    for rr in hist:
        mon.observe_round(rr, fleet=traced_session["fleet"],
                          tracer=_Replay())
    assert len(mon.records) == len(hist)
    for rec, rr in zip(mon.records, hist):
        assert rec.source == "fleet" and rec.ratio is not None
        assert rec.predicted_s == pytest.approx(rr.sim_round_s)
        # span-measured vs engine-measured: same interval, <5% apart
        assert rec.measured_s == pytest.approx(rr.round_time_s, rel=0.05)


def test_measured_round_s_falls_back_without_tracer(traced_session):
    rr = traced_session["hist"][0]
    assert obs.measured_round_s(rr) == rr.round_time_s
