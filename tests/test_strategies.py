"""Beyond-paper federated strategies: FedAvgM, FedProx, upload compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core import strategies as S
from repro.core.fedavg import fedavg
from repro.models.model import init_model
from repro.nn import param as P

KEY = jax.random.PRNGKey(0)


def _trees(k=3, seed=0):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(0, 1, (6,)), jnp.float32)}
            for _ in range(k)]


def test_fedavgm_zero_beta_is_fedavg():
    g = _trees(1)[0]
    clients = _trees(3, 1)
    new, st = S.fedavgm_update(g, clients, [1, 1, 1], S.ServerState(),
                               beta=0.0, lr=1.0)
    want = fedavg(clients, [1, 1, 1])
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want["w"]),
                               rtol=1e-6)


def test_fedavgm_momentum_accumulates():
    g = {"w": jnp.zeros((4,))}
    clients = [{"w": jnp.ones((4,))}]
    st = S.ServerState()
    new1, st = S.fedavgm_update(g, clients, [1], st, beta=0.9)
    new2, st = S.fedavgm_update(new1, [{"w": new1["w"] + 1.0}], [1], st,
                                beta=0.9)
    # second step's momentum includes 0.9 * first delta
    assert float(new2["w"][0] - new1["w"][0]) > 1.0


def test_quantize8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    d = {"w": jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)}
    dq, nbytes = S.quantize8(d)
    err = float(jnp.max(jnp.abs(dq["w"] - d["w"])))
    scale = float(jnp.max(jnp.abs(d["w"]))) / 127
    assert err <= scale * 0.51 + 1e-7
    assert nbytes == 256 + 4                     # 1B/entry + scale
    assert nbytes < S.dense_bytes(d)


def test_topk_zero_delta_counts_minimum():
    # all-zero leaf (e.g. a frozen layer's delta): threshold is 0, which
    # "keeps" everything — accounting must not bill the whole leaf
    d = {"w": jnp.zeros((100,), jnp.float32)}
    _, nbytes = S.topk_sparsify(d, frac=0.1)
    assert nbytes == 8


def test_topk_keeps_largest():
    d = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0], jnp.float32)}
    # k = ceil(0.34 * 6) = 3: the third survivor is 0.2
    sp, nbytes = S.topk_sparsify(d, frac=0.34)
    w = np.asarray(sp["w"])
    assert w[1] == -5.0 and w[3] == 3.0 and w[2] == 0.2
    assert np.count_nonzero(w) == 3
    assert nbytes == 3 * 8


def test_compressed_fedavg_identity_compressor():
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)}
    clients = [{"w": jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)}
               for _ in range(2)]
    a, b_dense = S.compressed_fedavg(g, clients, [1, 2])
    want = fedavg(clients, [1, 2])
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-6)
    a8, b_q = S.compressed_fedavg(g, clients, [1, 2], compressor=S.quantize8)
    assert b_q < b_dense / 3                      # ~4x smaller upload
    np.testing.assert_allclose(np.asarray(a8["w"]), np.asarray(want["w"]),
                               atol=0.06)


def test_fedprox_step_pulls_toward_anchor():
    cfg = get_config("distilbert-mlm").reduced().replace(n_layers=2)
    params = P.unbox(init_model(KEY, cfg))
    anchor = params
    opt = optim.sgd(1e-2)
    # huge mu and a zero-information batch: the prox term dominates, so a
    # step from a perturbed point must move BACK toward the anchor
    step = jax.jit(S.make_fedprox_step(cfg, opt, mu=100.0, clip_norm=0.0))
    rng = np.random.default_rng(0)
    B, Sq = 2, 8
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, Sq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, Sq)), jnp.int32),
        "loss_mask": jnp.ones((B, Sq), jnp.float32),
    }
    perturbed = jax.tree.map(lambda p: p + 0.1, params)
    o = P.unbox(opt.init(perturbed))
    d_before = float(S.proximal_penalty(perturbed, anchor))
    p1, _, m = step(perturbed, o, anchor, batch)
    d_after = float(S.proximal_penalty(p1, anchor))
    assert d_after < d_before
    assert float(m["prox"]) > 0


# ---------------------------------------------------------------------------
# Property tests: the compression laws (hypothesis; deterministic shim
# fallback in tests/_hyp.py when the real library is absent)
# ---------------------------------------------------------------------------

from _hyp import given, settings, st  # noqa: E402

from repro.core import strategy as ST  # noqa: E402


def _distinct_magnitudes(seed, n):
    """Values with pairwise-distinct |.| so the exact-count law has no
    threshold ties (tie behavior is pinned separately below)."""
    rng = np.random.default_rng(seed)
    mags = np.cumsum(rng.uniform(0.1, 1.0, n))     # strictly increasing > 0
    signs = rng.choice([-1.0, 1.0], n)
    return jnp.asarray(rng.permutation(mags * signs), jnp.float32)


@settings(max_examples=25)
@given(n=st.integers(min_value=1, max_value=97),
       frac=st.floats(min_value=0.01, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2**31))
def test_topk_exact_count_law(n, frac, seed):
    """topk_sparsify keeps EXACTLY topk_count(n, frac) = ceil(frac*n)
    entries when magnitudes are distinct — and the eager compressor, the
    trace-safe compressor, and the static byte accounting all agree."""
    d = {"w": _distinct_magnitudes(seed, n)}
    k = ST.topk_count(n, frac)
    assert k == min(n, max(1, int(np.ceil(frac * n))))

    sp, nbytes = S.topk_sparsify(d, frac=frac)
    w = np.asarray(sp["w"])
    assert np.count_nonzero(w) == k
    # survivors are exactly the k largest magnitudes
    keep = np.argsort(-np.abs(np.asarray(d["w"])))[:k]
    assert set(np.flatnonzero(w)) == set(keep.tolist())
    np.testing.assert_array_equal(w[keep], np.asarray(d["w"])[keep])
    # engine parity: jit/trace-safe compressor selects the same entries
    np.testing.assert_array_equal(
        np.asarray(ST.topk_compress(d, frac)["w"]), w)
    # byte-accounting parity: eager exact count == static k-based count
    assert nbytes == ST.topk_bytes(d, frac) == k * 8
    assert nbytes == ST.exact_kept_bytes(sp)


def test_topk_tie_stability():
    """The >= threshold rule keeps ALL entries tied at the k-th magnitude
    (may exceed k), identically in both compressors, and the exact-count
    accounting bills the survivors, not k."""
    d = {"w": jnp.asarray([2.0, -2.0, 2.0, 1.0, -0.5, 0.25], jnp.float32)}
    sp, nbytes = S.topk_sparsify(d, frac=0.34)     # k = 3; |2.0| tied x3
    w = np.asarray(sp["w"])
    np.testing.assert_array_equal(w, [2.0, -2.0, 2.0, 0.0, 0.0, 0.0])
    assert nbytes == 3 * 8
    np.testing.assert_array_equal(
        np.asarray(ST.topk_compress(d, 0.34)["w"]), w)
    # tie straddling the cut: k = 2 but all three tied entries survive
    sp2, nbytes2 = S.topk_sparsify(d, frac=0.3)
    w2 = np.asarray(sp2["w"])
    np.testing.assert_array_equal(w2, [2.0, -2.0, 2.0, 0.0, 0.0, 0.0])
    assert nbytes2 == 3 * 8 == ST.exact_kept_bytes(sp2)
    assert ST.topk_bytes(d, 0.3) == 2 * 8          # static law stays at k


@settings(max_examples=25)
@given(n=st.integers(min_value=1, max_value=257),
       scale_exp=st.integers(min_value=-6, max_value=4),
       seed=st.integers(min_value=0, max_value=2**31))
def test_quantize8_roundtrip_law(n, scale_exp, seed):
    """dequantize(quantize8(d)) is within scale/2 of d elementwise, where
    scale = max|d| / 127 — at every magnitude order."""
    rng = np.random.default_rng(seed)
    d = {"w": jnp.asarray(rng.normal(0, 10.0 ** scale_exp, n), jnp.float32)}
    dq, nbytes = S.quantize8(d)
    scale = max(float(jnp.max(jnp.abs(d["w"]))), 1e-12) / 127.0
    err = float(jnp.max(jnp.abs(dq["w"] - d["w"])))
    assert err <= scale * 0.5 * (1 + 1e-5) + 1e-12
    assert nbytes == n + 4                         # 1 B/entry + fp32 scale
    # trace-safe engine round trip is identical
    np.testing.assert_array_equal(np.asarray(ST.int8_compress(d)["w"]),
                                  np.asarray(dq["w"]))
    assert ST.int8_bytes(d) == nbytes


def test_quantize8_zero_delta():
    d = {"w": jnp.zeros((32,), jnp.float32)}
    dq, nbytes = S.quantize8(d)
    np.testing.assert_array_equal(np.asarray(dq["w"]), np.zeros(32))
    assert nbytes == 32 + 4


def test_topk_single_entry_leaf():
    # n = 1: every frac keeps the single entry (k clamped to [1, n])
    for frac in (0.01, 0.5, 1.0):
        d = {"w": jnp.asarray([3.5], jnp.float32)}
        sp, nbytes = S.topk_sparsify(d, frac=frac)
        assert float(sp["w"][0]) == 3.5
        assert nbytes == 8 == ST.topk_bytes(d, frac)


def test_topk_multi_leaf_tree_accounting():
    # per-leaf k: ceil is applied leaf-wise, not over the concatenation
    d = {"a": _distinct_magnitudes(0, 10), "b": _distinct_magnitudes(1, 3)}
    sp, nbytes = S.topk_sparsify(d, frac=0.5)
    assert np.count_nonzero(np.asarray(sp["a"])) == 5
    assert np.count_nonzero(np.asarray(sp["b"])) == 2
    assert nbytes == (5 + 2) * 8 == ST.topk_bytes(d, 0.5)
