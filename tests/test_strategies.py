"""Beyond-paper federated strategies: FedAvgM, FedProx, upload compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core import strategies as S
from repro.core.fedavg import fedavg
from repro.models.model import init_model
from repro.nn import param as P

KEY = jax.random.PRNGKey(0)


def _trees(k=3, seed=0):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(0, 1, (6,)), jnp.float32)}
            for _ in range(k)]


def test_fedavgm_zero_beta_is_fedavg():
    g = _trees(1)[0]
    clients = _trees(3, 1)
    new, st = S.fedavgm_update(g, clients, [1, 1, 1], S.ServerState(),
                               beta=0.0, lr=1.0)
    want = fedavg(clients, [1, 1, 1])
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want["w"]),
                               rtol=1e-6)


def test_fedavgm_momentum_accumulates():
    g = {"w": jnp.zeros((4,))}
    clients = [{"w": jnp.ones((4,))}]
    st = S.ServerState()
    new1, st = S.fedavgm_update(g, clients, [1], st, beta=0.9)
    new2, st = S.fedavgm_update(new1, [{"w": new1["w"] + 1.0}], [1], st,
                                beta=0.9)
    # second step's momentum includes 0.9 * first delta
    assert float(new2["w"][0] - new1["w"][0]) > 1.0


def test_quantize8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    d = {"w": jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)}
    dq, nbytes = S.quantize8(d)
    err = float(jnp.max(jnp.abs(dq["w"] - d["w"])))
    scale = float(jnp.max(jnp.abs(d["w"]))) / 127
    assert err <= scale * 0.51 + 1e-7
    assert nbytes == 256 + 4                     # 1B/entry + scale
    assert nbytes < S.dense_bytes(d)


def test_topk_zero_delta_counts_minimum():
    # all-zero leaf (e.g. a frozen layer's delta): threshold is 0, which
    # "keeps" everything — accounting must not bill the whole leaf
    d = {"w": jnp.zeros((100,), jnp.float32)}
    _, nbytes = S.topk_sparsify(d, frac=0.1)
    assert nbytes == 8


def test_topk_keeps_largest():
    d = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0], jnp.float32)}
    sp, nbytes = S.topk_sparsify(d, frac=0.34)    # keep 2 of 6
    w = np.asarray(sp["w"])
    assert w[1] == -5.0 and w[3] == 3.0
    assert np.count_nonzero(w) == 2
    assert nbytes == 2 * 8


def test_compressed_fedavg_identity_compressor():
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)}
    clients = [{"w": jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)}
               for _ in range(2)]
    a, b_dense = S.compressed_fedavg(g, clients, [1, 2])
    want = fedavg(clients, [1, 2])
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-6)
    a8, b_q = S.compressed_fedavg(g, clients, [1, 2], compressor=S.quantize8)
    assert b_q < b_dense / 3                      # ~4x smaller upload
    np.testing.assert_allclose(np.asarray(a8["w"]), np.asarray(want["w"]),
                               atol=0.06)


def test_fedprox_step_pulls_toward_anchor():
    cfg = get_config("distilbert-mlm").reduced().replace(n_layers=2)
    params = P.unbox(init_model(KEY, cfg))
    anchor = params
    opt = optim.sgd(1e-2)
    # huge mu and a zero-information batch: the prox term dominates, so a
    # step from a perturbed point must move BACK toward the anchor
    step = jax.jit(S.make_fedprox_step(cfg, opt, mu=100.0, clip_norm=0.0))
    rng = np.random.default_rng(0)
    B, Sq = 2, 8
    batch = {
        "tokens": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, Sq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(5, cfg.vocab_size, (B, Sq)), jnp.int32),
        "loss_mask": jnp.ones((B, Sq), jnp.float32),
    }
    perturbed = jax.tree.map(lambda p: p + 0.1, params)
    o = P.unbox(opt.init(perturbed))
    d_before = float(S.proximal_penalty(perturbed, anchor))
    p1, _, m = step(perturbed, o, anchor, batch)
    d_after = float(S.proximal_penalty(p1, anchor))
    assert d_after < d_before
    assert float(m["prox"]) > 0
