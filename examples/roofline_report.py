"""Render the roofline report from the dry-run artifacts.

Human-readable view of benchmarks/results/dryrun/: per (arch x shape) the
three roofline terms, the dominant bottleneck, and — where hillclimbed
variants exist (tagged artifacts) — the baseline->optimized delta.

    PYTHONPATH=src python examples/roofline_report.py
    (run `python -m repro.launch.dryrun --all` first to generate artifacts)
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline import RESULTS, table  # noqa: E402


def _load_tagged():
    out = {}
    for p in glob.glob(os.path.join(RESULTS, "*__pod1__*.json")):
        name = os.path.basename(p)[:-5]
        arch, shape, _, tag = name.split("__", 3)
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            out.setdefault((arch, shape), {})[tag] = rec
    return out


def main():
    rows = table(pods=1)
    if not rows:
        raise SystemExit("no artifacts: run `python -m repro.launch.dryrun --all`")
    tagged = _load_tagged()
    print(f"{'arch':22s} {'shape':12s} {'bound':6s} {'dominant s':>11s} "
          f"{'optimized s':>12s} {'gain':>7s}  via")
    for r in rows:
        if r.get("status") == "ERROR":
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        best, via = None, ""
        for tag, rec in tagged.get((r["arch"], r["shape"]), {}).items():
            rl = rec["roofline_s"]
            d = max(rl["compute"], rl["memory"], rl["collective"])
            if best is None or d < best:
                best, via = d, tag
        if best is not None:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['bottleneck'][:6]:6s} "
                  f"{dom:11.3e} {best:12.3e} {dom / best:6.1f}x  {via}")
        else:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['bottleneck'][:6]:6s} "
                  f"{dom:11.3e} {'-':>12s} {'-':>7s}")
    n = sum(1 for r in rows if r.get("status") != "ERROR")
    print(f"\n{n} (arch x shape) pairs lowered+compiled on the 16x16 mesh "
          f"(and again on 2x16x16 — see *__pod2.json).")


if __name__ == "__main__":
    main()
