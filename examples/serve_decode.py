"""Batched serving example: prefill + greedy decode on any zoo arch,
including the SSM/hybrid state-cache paths and the sliding-window ring cache.

Prompt/batch construction comes from ``repro.serve.requests`` (shared with
the serving CLI); throughput uses the unified definition — generated tokens
INCLUDE the one the prefill logits produce, over the prefill+decode interval.
Logit finiteness is accumulated across the whole decode (``FiniteTrace``),
so a mid-sequence NaN reports the step it first appeared.

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b
    PYTHONPATH=src python examples/serve_decode.py --arch qwen2-7b --window 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.models.steps import make_prefill_step, make_serve_step
from repro.nn import param as P
from repro.serve import (FiniteTrace, generated_tokens, prompt_batch,
                         tokens_per_s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window variant (ring KV cache)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    if cfg.arch_type == "mlm":
        raise SystemExit("mlm is encoder-only (no decode)")

    params = P.unbox(init_model(jax.random.PRNGKey(0), cfg))
    cache_len = (min(args.window, args.prompt_len + args.tokens)
                 if args.window else args.prompt_len + args.tokens)
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    serve = jax.jit(make_serve_step(cfg))

    rng = np.random.default_rng(0)
    batch = prompt_batch(cfg, args.batch, args.prompt_len, rng)

    ftrace = FiniteTrace()
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    ftrace.update(logits)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for _ in range(args.tokens - 1):
        logits, cache = serve(params, {"tokens": tok}, cache)
        ftrace.update(logits)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    n_tokens = generated_tokens(args.batch, args.tokens)
    print(f"{cfg.name} ({cfg.arch_type}): cache_len={cache_len} "
          f"generated {n_tokens} tokens "
          f"{tokens_per_s(n_tokens, dt):.1f} tok/s")
    print("tokens[0]:", np.asarray(jnp.concatenate(toks, 1))[0][:12])
    ftrace.assert_finite(f"{cfg.name} decode")
    print("OK")


if __name__ == "__main__":
    main()
