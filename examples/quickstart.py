"""Quickstart: federated domain-adaptive pre-training in ~40 lines.

Builds the paper's setting end to end on CPU: a synthetic biomedical corpus,
2 clients with quantity skew, DistilBERT-MLM (reduced), 3 FedAvg rounds with
FFDAPT layer freezing, and a held-out eval.

    PYTHONPATH=src python examples/quickstart.py          # ~2 min
    PYTHONPATH=src python examples/quickstart.py --fast   # CI-sized
"""

import sys

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession, RoundPlan
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P

FAST = "--fast" in sys.argv

# 1. the model: the paper's own backbone, reduced for CPU
cfg = get_config("distilbert-mlm").reduced()
params = P.unbox(init_model(jax.random.PRNGKey(42), cfg))

# 2. the data: synthetic biomedical corpus, partitioned with quantity skew
from repro.data.corpus import split_holdout
docs, held_docs = split_holdout(generate_corpus(60 if FAST else 200, seed=42))
ds = make_client_datasets(docs, cfg, k=2, skew="quantity", batch=2, seq=32)
print("client sizes (Eq. 8):", ds["sizes"],
      "| quantity sigma:", round(ds["stats"]["quantity"]["sigma"], 1))

# 3. FFDAPT: FedAvg rounds with the rotating layer-freeze schedule
batches = [b[:2 if FAST else 6] for b in ds["batches"]]
plan = RoundPlan(n_rounds=2 if FAST else 5, engine="sequential",
                 client_sizes=ds["sizes"], ffdapt=FFDAPTConfig(gamma=1.0))
params, hist = FedSession(cfg, optim.adam(5e-4), plan).run(params, batches)
for h in hist:
    print(f"round {h.round}: loss {h.loss:.4f} "
          f"({h.round_time_s:.1f}s, {h.upload_bytes / 2**20:.1f}MB up, "
          f"{h.tokens_per_s:.0f} tok/s) frozen windows {h.windows}")

# 4. held-out evaluation
eval_step = jax.jit(make_eval_step(cfg))
held = make_client_datasets(held_docs, cfg, k=1,
                            batch=2, seq=32)["batches"][0][:2]
loss = float(np.mean([float(eval_step(params, b)["loss"]) for b in held]))
print(f"held-out MLM loss: {loss:.4f}")
assert np.isfinite(loss)
print("OK")
