"""Fleet study: one real federated session, many simulated deployments.

Trains a small FDAPT session once (the numbers are real — losses, ledger,
per-client replay fields), then replays its round history on several device
fleets under the three server schedules:

  * sync FedAvg          — the round waits for the slowest client;
  * deadline + over-select — stragglers are dropped (never below quorum);
  * buffered async (FedBuff) — aggregate every K uploads; the observed
    staleness schedule is fed back into ``AsyncFedAvg`` to run the learning
    math the schedule implies.

    PYTHONPATH=src python examples/fleet_study.py [--clients 4] [--rounds 3]
"""

import argparse

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.core.strategies import AsyncFedAvg
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.nn import param as P
from repro.sim import make_fleet, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="deadline seconds (default: 1.2x the homogeneous "
                         "sync round)")
    ap.add_argument("--buffer", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--overlap", action="store_true",
                    help="pipelined clock: download/compute and "
                         "compute/upload overlap")
    ap.add_argument("--calibrated", action="store_true",
                    help="measurement-calibrated device registry "
                         "(repro.sim.calibrate) instead of datasheet presets")
    args = ap.parse_args()

    cfg = get_config("distilbert-mlm").reduced()
    docs = generate_corpus(160, seed=args.seed)
    ds = make_client_datasets(docs, cfg, k=args.clients, skew="quantity",
                              batch=2, seq=32, seed=args.seed)
    batches = [b[:args.steps] for b in ds["batches"]]
    params = P.unbox(init_model(jax.random.PRNGKey(args.seed), cfg))

    print(f"training: {args.clients} clients x {args.rounds} rounds "
          f"(quantity skew, steps {[len(b) for b in batches]})")
    params, hist = FedSession(cfg, optim.adam(5e-4), n_rounds=args.rounds,
                              client_sizes=ds["sizes"]).run(params, batches)
    for h in hist:
        print(f"  round {h.round}  loss {h.loss:.4f}  "
              f"{h.flops_estimate / 1e9:.1f} GFLOP  "
              f"comm {h.comm_bytes / 2**20:.0f} MB")

    # baseline deadline: a bit above the homogeneous sync round time
    base = simulate(hist, make_fleet("uniform-a100", args.clients,
                                     seed=args.seed,
                                     calibrated=args.calibrated),
                    mode="sync", overlap=args.overlap)
    deadline = args.deadline or 1.2 * base.mean_round_s

    # per-client step schedule (quantity skew): threading it into the async
    # replay makes staleness correlate with data volume.  ds["steps"] is the
    # FULL per-epoch schedule — the simulated deployment runs whole epochs
    # even though the trained session above truncated to --steps for speed.
    steps = ds["steps"]
    print(f"\n{'fleet':14s} {'sync_s':>9s} {'deadline_s':>10s} "
          f"{'dropped':>7s} {'async_s':>9s} {'stale(tau:n)':>14s}")
    for name in ("uniform-a100", "paper-2080ti", "silo-mixed", "edge-mixed",
                 "crossdevice"):
        fleet = make_fleet(name, args.clients, seed=args.seed,
                           calibrated=args.calibrated)
        sync = simulate(hist, fleet, mode="sync", seed=args.seed,
                        overlap=args.overlap)
        dl = simulate(hist, fleet, mode="deadline",
                      deadline_s=deadline, seed=args.seed,
                      overlap=args.overlap)
        asy = simulate(hist, fleet, mode="async", buffer_size=args.buffer,
                       seed=args.seed, overlap=args.overlap,
                       client_steps=steps)
        taus = ",".join(f"{t}:{n}" for t, n in
                        sorted(asy.staleness_histogram().items()))
        print(f"{name:14s} {sync.total_s:9.1f} {dl.total_s:10.1f} "
              f"{dl.dropped_total:7d} {asy.total_s:9.1f} {taus:>14s}")

    # close the loop: run the async schedule's staleness through the
    # AsyncFedAvg learning math on the slowest fleet
    fleet = make_fleet("edge-mixed", args.clients, seed=args.seed,
                       calibrated=args.calibrated)
    asy = simulate(hist, fleet, mode="async", buffer_size=args.buffer,
                   seed=args.seed, overlap=args.overlap, client_steps=steps)
    # the skew-aware replay's signature: mean staleness per client rises
    # with its local step count (big-data clients upload less often)
    per_client_tau = {}
    for r in asy.rounds:
        for c, tau in zip(r.clients, r.staleness):
            per_client_tau.setdefault(c, []).append(tau)
    corr = {c: (steps[c], float(np.mean(ts)))
            for c, ts in sorted(per_client_tau.items())}
    print("\nclient -> (local steps/epoch, mean staleness) on edge-mixed:")
    print("  " + "  ".join(f"{c}:({s},{t:.2f})" for c, (s, t) in
                           corr.items()))
    taus = tuple(tau for r in asy.rounds for tau in r.staleness)
    strat = AsyncFedAvg(alpha=0.5, staleness=taus or (0,))
    params2 = P.unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
    _, hist2 = FedSession(cfg, optim.adam(5e-4), n_rounds=args.rounds,
                          client_sizes=ds["sizes"],
                          strategy=strat).run(params2, batches)
    print(f"\nasync learning math (edge-mixed schedule, "
          f"taus={list(taus)}, s(tau)={[round(strat.discount(t), 3) for t in sorted(set(taus))]}):")
    for a, b in zip(hist, hist2):
        print(f"  round {a.round}  fedavg loss {a.loss:.4f}  "
              f"asyncfedavg loss {b.loss:.4f}")


if __name__ == "__main__":
    main()
