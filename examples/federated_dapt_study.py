"""The paper's empirical study at smoke scale (Table 2 analogue).

Grid: {centralized, FDAPT, FFDAPT} x {IID, quantity, length, vocab} x
{2, 8 clients} on DistilBERT-MLM, reporting held-out masked-LM loss instead
of downstream F1 (no PubMed/BioASQ offline — see DESIGN.md §8).

    PYTHONPATH=src python examples/federated_dapt_study.py [--clients 2]
"""

import argparse

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.ffdapt import FFDAPTConfig
from repro.core.noniid import make_client_datasets
from repro.core.rounds import FedSession
from repro.data.corpus import generate_corpus
from repro.models.model import init_model
from repro.models.steps import make_eval_step
from repro.nn import param as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=[2])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--docs", type=int, default=160)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("distilbert-mlm").reduced()
    params0 = P.unbox(init_model(jax.random.PRNGKey(42), cfg))
    from repro.data.corpus import split_holdout
    docs, held_docs = split_holdout(generate_corpus(args.docs, seed=0))
    eval_step = jax.jit(make_eval_step(cfg))
    held = make_client_datasets(held_docs, cfg, k=1,
                                batch=2, seq=32)["batches"][0][:3]

    def eval_loss(p):
        return float(np.mean([float(eval_step(p, b)["loss"]) for b in held]))

    print(f"{'setting':34s} {'eval loss':>9s}")
    print(f"{'original (no DAPT)':34s} {eval_loss(params0):9.4f}")

    cen = make_client_datasets(docs, cfg, k=1, batch=2, seq=32)
    p, _ = FedSession(cfg, optim.adam(5e-4), n_rounds=args.rounds).run(
        params0, [cen["batches"][0][:args.steps * 2]])
    print(f"{'centralized':34s} {eval_loss(p):9.4f}")

    for k in args.clients:
        for skew in ("iid", "quantity", "length", "vocab"):
            ds = make_client_datasets(docs, cfg, k=k, skew=skew,
                                      batch=2, seq=32)
            bs = [b[:args.steps] for b in ds["batches"]]
            for ffd, tag in ((None, "FDAPT"), (FFDAPTConfig(), "FFDAPT")):
                p, _ = FedSession(cfg, optim.adam(5e-4),
                                  n_rounds=args.rounds,
                                  client_sizes=ds["sizes"],
                                  ffdapt=ffd).run(params0, bs)
                name = f"{tag} {k}c {skew}"
                print(f"{name:34s} {eval_loss(p):9.4f}")


if __name__ == "__main__":
    main()
